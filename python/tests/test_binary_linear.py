"""L1 correctness: fused 1-bit dequant matmul kernel vs oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import binary_linear
from compile.kernels.ref import binary_linear_ref, haar_inv_ref


def make_inputs(n, m, b, seed):
    r = np.random.RandomState(seed)
    signs = np.sign(r.randn(n, m)).astype("float32")
    signs[signs == 0] = 1.0
    alpha = np.abs(r.randn(n, 2)).astype("float32") + 0.01
    mu = (0.1 * r.randn(n, 2)).astype("float32")
    x = r.randn(m, b).astype("float32")
    return tuple(map(jnp.asarray, (signs, alpha, mu, x)))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 150),
    half_m=st.integers(1, 64),
    b=st.integers(1, 9),
    block=st.sampled_from([16, 64]),
    seed=st.integers(0, 10_000),
)
def test_matches_ref(n, half_m, b, block, seed):
    signs, alpha, mu, x = make_inputs(n, 2 * half_m, b, seed)
    got = binary_linear(signs, alpha, mu, x, block_n=block)
    want = binary_linear_ref(signs, alpha, mu, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_equals_explicit_reconstruction():
    """The kernel must equal: dense W = HaarInv(alpha*s+mu), then W @ x."""
    signs, alpha, mu, x = make_inputs(64, 32, 4, 0)
    h = 16
    band = jnp.concatenate([jnp.zeros(h, jnp.int32), jnp.ones(h, jnp.int32)])
    coeff = alpha[:, band] * signs + mu[:, band]
    w = haar_inv_ref(coeff)
    want = w @ x
    got = binary_linear(signs, alpha, mu, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_zero_mu_scales_linearly():
    signs, alpha, mu, x = make_inputs(32, 16, 2, 1)
    mu = jnp.zeros_like(mu)
    y1 = binary_linear(signs, alpha, mu, x)
    y2 = binary_linear(signs, 2.0 * alpha, mu, x)
    np.testing.assert_allclose(np.asarray(y2), 2 * np.asarray(y1), rtol=1e-5, atol=1e-4)
