"""L1 correctness: blocked causal attention kernel vs oracle + causality."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import attention
from compile.kernels.ref import attention_ref


def qkv(h, s, d, seed):
    r = np.random.RandomState(seed)
    return tuple(jnp.asarray(r.randn(h, s, d).astype("float32")) for _ in range(3))


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(1, 6),
    s=st.sampled_from([16, 64, 128]),
    d=st.sampled_from([8, 32, 64]),
    seed=st.integers(0, 10_000),
)
def test_matches_ref(h, s, d, seed):
    q, k, v = qkv(h, s, d, seed)
    got = attention(q, k, v, block_q=min(64, s))
    want = attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_causality():
    """Perturbing future keys/values must not change earlier outputs."""
    q, k, v = qkv(2, 64, 16, 0)
    base = np.asarray(attention(q, k, v))
    k2 = k.at[:, 40:].set(k[:, 40:] + 100.0)
    v2 = v.at[:, 40:].set(-v[:, 40:])
    pert = np.asarray(attention(q, k2, v2))
    np.testing.assert_allclose(pert[:, :40], base[:, :40], rtol=1e-5, atol=1e-5)
    assert np.abs(pert[:, 40:] - base[:, 40:]).max() > 1e-3


def test_first_position_is_value():
    """Output at t=0 attends only to itself: o[0] == v[0]."""
    q, k, v = qkv(3, 16, 8, 1)
    out = np.asarray(attention(q, k, v))
    np.testing.assert_allclose(out[:, 0], np.asarray(v)[:, 0], rtol=1e-5, atol=1e-5)
