"""L1 correctness: Pallas Haar kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; the invariants are
  (1) kernel == oracle elementwise,
  (2) inv(fwd(x)) == x (biorthogonal exact reconstruction),
  (3) band energies behave (low band carries the mean structure).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import haar_fwd, haar_fwd_cols, haar_inv, haar_inv_cols
from compile.kernels.ref import haar_fwd_ref, haar_inv_ref

DTYPES = ["float32", "bfloat16"]


def rand(shape, dtype, seed):
    x = np.random.RandomState(seed).randn(*shape).astype("float32")
    return jnp.asarray(x).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 130),
    half_m=st.integers(1, 65),
    block=st.sampled_from([8, 64, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_fwd_matches_ref(n, half_m, block, seed):
    x = rand((n, 2 * half_m), "float32", seed % 10_000)
    got = haar_fwd(x, block_rows=block)
    want = haar_fwd_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 130),
    half_m=st.integers(1, 65),
    seed=st.integers(0, 2**31 - 1),
)
def test_roundtrip(n, half_m, seed):
    x = rand((n, 2 * half_m), "float32", seed % 10_000)
    back = haar_inv(haar_fwd(x))
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-6)


@pytest.mark.parametrize("dtype", DTYPES)
def test_dtypes(dtype):
    x = rand((32, 64), dtype, 0)
    got = haar_fwd(x)
    want = haar_fwd_ref(x)
    assert got.dtype == x.dtype
    np.testing.assert_allclose(
        np.asarray(got, "float32"), np.asarray(want, "float32"), atol=1e-2
    )


def test_inv_matches_ref():
    c = rand((17, 42), "float32", 3)
    np.testing.assert_allclose(
        np.asarray(haar_inv(c)), np.asarray(haar_inv_ref(c)), atol=0
    )


def test_constant_row_has_zero_high_band():
    x = jnp.ones((4, 16), jnp.float32) * 3.5
    c = np.asarray(haar_fwd(x))
    np.testing.assert_allclose(c[:, :8], 3.5)
    np.testing.assert_allclose(c[:, 8:], 0.0)


def test_cols_variant_is_transpose():
    x = rand((32, 48), "float32", 7)
    got = haar_fwd_cols(x)
    want = haar_fwd_ref(x.T).T
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0)
    back = haar_inv_cols(got)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-6)
