"""AOT bridge: HLO-text export works on the micro config and is well-formed."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import export_hlo, to_hlo_text
from compile.common import CONFIGS
from compile.kernels.binary_linear import binary_linear
from compile.model import init_params, make_nll_fn

CFG = CONFIGS["micro"]


def test_hlo_text_well_formed():
    lowered = jax.jit(lambda x, y: (x @ y + 1.0,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32), jax.ShapeDtypeStruct((4, 4), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and "HloModule" in text
    # Text (not proto) keeps ids small enough for xla_extension 0.5.1.
    assert "f32[4,4]" in text


def test_export_nll_micro():
    tok = jax.ShapeDtypeStruct((2, CFG.seq_len), jnp.int32)
    specs = [jax.ShapeDtypeStruct(CFG.param_shape(n), jnp.float32) for n in CFG.param_order()]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "nll.hlo.txt")
        n = export_hlo(make_nll_fn(CFG, use_pallas=False), (tok, *specs), path)
        assert n > 1000
        text = open(path).read()
    assert "ENTRY" in text
    # One parameter per weight + the token arg in the ENTRY computation
    # (non-entry computations also contain parameter() lines, so >=).
    entry = text[text.index("ENTRY"):]
    n_entry_params = entry.count("parameter(")
    assert n_entry_params == len(CFG.param_order()) + 1


def test_export_binary_gemm_kernel():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bg.hlo.txt")
        export_hlo(
            lambda s, a, u, x: (binary_linear(s, a, u, x),),
            (
                jax.ShapeDtypeStruct((32, 16), jnp.float32),
                jax.ShapeDtypeStruct((32, 2), jnp.float32),
                jax.ShapeDtypeStruct((32, 2), jnp.float32),
                jax.ShapeDtypeStruct((16, 3), jnp.float32),
            ),
            path,
        )
        assert "ENTRY" in open(path).read()


def test_exported_fn_executes_in_jax():
    """The exact lowered computation must be numerically sane when executed."""
    p = init_params(CFG, jax.random.PRNGKey(0))
    fn = make_nll_fn(CFG, use_pallas=False)
    tokens = jnp.asarray(np.random.RandomState(0).randint(0, 256, (2, CFG.seq_len)), jnp.int32)
    flat = [p[n] for n in CFG.param_order()]
    (out,) = jax.jit(fn)(tokens, *flat)
    assert out.shape == (2, CFG.seq_len - 1)
    assert np.isfinite(np.asarray(out)).all()
