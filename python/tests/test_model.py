"""L2 correctness: model shapes, NLL semantics, trainability (micro config)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.common import CONFIGS
from compile.model import (
    flatten_params,
    forward,
    init_params,
    mean_nll,
    nll,
    unflatten_params,
)
from compile.train import train

CFG = CONFIGS["micro"]


def params_and_tokens(batch=3, seed=0):
    p = init_params(CFG, jax.random.PRNGKey(seed))
    t = jnp.asarray(
        np.random.RandomState(seed).randint(0, CFG.vocab, (batch, CFG.seq_len)),
        jnp.int32,
    )
    return p, t


def test_shapes():
    p, t = params_and_tokens()
    assert forward(CFG, p, t).shape == (3, CFG.seq_len, CFG.vocab)
    assert nll(CFG, p, t).shape == (3, CFG.seq_len - 1)


def test_pallas_and_ref_paths_agree():
    p, t = params_and_tokens()
    a = forward(CFG, p, t, use_pallas=True)
    b = forward(CFG, p, t, use_pallas=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)


def test_nll_is_positive_and_near_uniform_at_init():
    p, t = params_and_tokens()
    m = float(mean_nll(CFG, p, t))
    assert 0 < m < 8
    # Random init should be within a nat or so of uniform ln(256) = 5.55
    assert abs(m - np.log(CFG.vocab)) < 1.5


def test_param_flatten_roundtrip():
    p, _ = params_and_tokens()
    flat = flatten_params(CFG, p)
    assert len(flat) == len(CFG.param_order())
    back = unflatten_params(CFG, flat)
    for k in p:
        np.testing.assert_array_equal(np.asarray(p[k]), np.asarray(back[k]))


def test_causal_dependency():
    """Changing token t must not change logits before t."""
    p, t = params_and_tokens(batch=1)
    base = np.asarray(forward(CFG, p, t))
    t2 = t.at[0, 10].set((t[0, 10] + 1) % CFG.vocab)
    pert = np.asarray(forward(CFG, p, t2))
    np.testing.assert_allclose(pert[0, :10], base[0, :10], rtol=1e-5, atol=1e-5)
    assert np.abs(pert[0, 10:] - base[0, 10:]).max() > 1e-6


def test_training_reduces_loss():
    data = b"abcabcabcabc" * 500
    params, log = train(CFG, data, steps=30, batch=4, lr_max=1e-2, log_every=29, log_fn=lambda s: None)
    first, last = log[0][1], log[-1][1]
    assert last < first - 0.5, f"loss did not drop: {first} -> {last}"
