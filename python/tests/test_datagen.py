"""Data substrate: determinism, task-file roundtrip, corpus statistics."""

import os
import tempfile

import numpy as np

from compile import datagen


def test_language_deterministic():
    a, b = datagen.Language(seed=1), datagen.Language(seed=1)
    assert a.nouns == b.nouns and a.verbs == b.verbs
    c = datagen.Language(seed=2)
    assert a.nouns != c.nouns


def test_corpora_deterministic_and_distinct():
    lang = datagen.Language()
    kinds = ["train", "c4s", "wiki2s", "ptbs"]
    blobs = {k: datagen.gen_corpus(lang, k, 20_000) for k in kinds}
    again = {k: datagen.gen_corpus(lang, k, 20_000) for k in kinds}
    for k in kinds:
        assert blobs[k] == again[k], f"{k} not deterministic"
        assert len(blobs[k]) == 20_000
    # registers must differ
    assert blobs["c4s"] != blobs["wiki2s"] != blobs["ptbs"]
    # ptbs carries <unk>; wiki2s carries headings
    assert b"<unk>" in blobs["ptbs"]
    assert b"= " in blobs["wiki2s"]


def test_corpus_is_ascii():
    lang = datagen.Language()
    blob = datagen.gen_corpus(lang, "c4s", 10_000)
    arr = np.frombuffer(blob, np.uint8)
    assert arr.max() < 128


def test_task_items_have_valid_answers():
    lang = datagen.Language()
    for fam in datagen.TASK_FAMILIES:
        items = datagen.make_task_items(lang, fam, 12)
        assert len(items) == 12
        for prompt, opts, correct in items:
            assert 2 <= len(opts) <= 4
            assert 0 <= correct < len(opts)
            assert len(prompt) > 0
            assert len(set(opts)) > 1, f"{fam}: degenerate options"


def test_task_file_roundtrip():
    lang = datagen.Language()
    items = datagen.make_task_items(lang, "piqa_s", 7)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.bin")
        datagen.write_task_file(path, items)
        back = datagen.read_task_file(path)
    assert back == items


def test_answer_position_not_biased():
    """Correct answers must not all sit at index 0 (NLL scorer would cheat)."""
    lang = datagen.Language()
    positions = []
    for fam in datagen.TASK_FAMILIES:
        if fam == "boolq_s":  # fixed yes/no order by construction
            continue
        for _, _, c in datagen.make_task_items(lang, fam, 30):
            positions.append(c)
    assert 0.2 < np.mean([p > 0 for p in positions]) < 0.8
