"""Build-time pretraining of the byte-level GPT on the synthetic corpus.

A few hundred Adam steps are enough to give the weights the structure the
quantizers care about (anisotropic rows, activation-correlated columns) and
to make perplexity/QA evaluation meaningful. Runs once under `make
artifacts`; the loss curve is logged for EXPERIMENTS.md.
"""

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import TRAIN_SEED, ModelConfig
from .model import init_params, mean_nll


def sample_batch(rng: np.random.Generator, data: np.ndarray, batch: int, seq: int):
    starts = rng.integers(0, len(data) - seq - 1, size=batch)
    return np.stack([data[s : s + seq] for s in starts]).astype(np.int32)


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()}, "t": jnp.zeros((), jnp.int32)}


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(1, 2))
def train_step(cfg: ModelConfig, params, opt, tokens, lr):
    loss, grads = jax.value_and_grad(lambda p: mean_nll(cfg, p, tokens, use_pallas=False))(params)
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()))
    scale = jnp.minimum(1.0, 1.0 / (gnorm + 1e-6))
    t = opt["t"] + 1
    b1, b2, eps = 0.9, 0.95, 1e-8
    new_m, new_v, new_p = {}, {}, {}
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)
    for k, g in grads.items():
        g = g * scale
        m = b1 * opt["m"][k] + (1 - b1) * g
        v = b2 * opt["v"][k] + (1 - b2) * g * g
        new_m[k], new_v[k] = m, v
        new_p[k] = params[k] - lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
    return new_p, {"m": new_m, "v": new_v, "t": t}, loss


def train(cfg: ModelConfig, data: bytes, steps: int = 300, batch: int = 8,
          lr_max: float = 3e-3, log_every: int = 20, log_fn=print):
    """Train and return (params, loss_log[(step, loss)])."""
    arr = np.frombuffer(data, dtype=np.uint8)
    rng = np.random.default_rng(TRAIN_SEED)
    params = init_params(cfg, jax.random.PRNGKey(TRAIN_SEED))
    opt = adam_init(params)
    log = []
    t0 = time.time()
    for step in range(1, steps + 1):
        warm = min(1.0, step / 30.0)
        cos = 0.5 * (1 + np.cos(np.pi * step / steps))
        lr = lr_max * warm * (0.1 + 0.9 * cos)
        tokens = jnp.asarray(sample_batch(rng, arr, batch, cfg.seq_len))
        params, opt, loss = train_step(cfg, params, opt, tokens, jnp.float32(lr))
        if step == 1 or step % log_every == 0 or step == steps:
            l = float(loss)
            log.append((step, l))
            log_fn(f"step {step:4d}  loss {l:.4f}  lr {lr:.2e}  {time.time()-t0:.1f}s")
    return params, log
