"""AOT build path: data -> train -> export weights + HLO-text artifacts.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Everything is cached: re-running is a no-op unless inputs changed
(`make artifacts` guards with a stamp file as well).

Outputs under --out-dir (default ../artifacts):
  data/{train,c4s,wiki2s,ptbs}.bin        byte corpora
  tasks/<family>.bin                      QA task files (9 families)
  weights/model_tiny.{bin,json}           trained f32 weights + metadata
  weights/ckpt_tiny.npz                   training checkpoint (build cache)
  hlo/nll_tiny.hlo.txt                    NLL eval entry (Pallas attention)
  hlo/nll_tiny_ref.hlo.txt                NLL eval entry (jnp attention)
  hlo/logits_tiny.hlo.txt                 full-logits entry (generation)
  hlo/binary_gemm.hlo.txt                 fused 1-bit dequant matmul kernel
  hlo/haar_fwd.hlo.txt, haar_roundtrip.hlo.txt
  manifest.json, train_log_tiny.txt
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .common import CONFIGS, EVAL_BATCH, ModelConfig
from . import datagen
from .kernels.binary_linear import binary_linear
from .kernels.haar import haar_fwd, haar_inv
from .model import flatten_params, make_logits_fn, make_nll_fn
from .train import train

CORPora_SIZES = {"train": 1_000_000, "c4s": 65_536, "wiki2s": 65_536, "ptbs": 65_536}
TASK_ITEMS = 40
TRAIN_STEPS = int(os.environ.get("HBLLM_TRAIN_STEPS", "300"))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_hlo(fn, args, path):
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def build_data(out, log):
    os.makedirs(f"{out}/data", exist_ok=True)
    os.makedirs(f"{out}/tasks", exist_ok=True)
    lang = datagen.Language()
    for kind, size in CORPora_SIZES.items():
        path = f"{out}/data/{kind}.bin"
        if not os.path.exists(path):
            data = datagen.gen_corpus(lang, kind, size)
            with open(path, "wb") as f:
                f.write(data)
            log(f"data/{kind}.bin: {size} bytes")
    for fam in datagen.TASK_FAMILIES:
        path = f"{out}/tasks/{fam}.bin"
        if not os.path.exists(path):
            items = datagen.make_task_items(lang, fam, TASK_ITEMS)
            datagen.write_task_file(path, items)
            log(f"tasks/{fam}.bin: {len(items)} items")


def build_weights(out, cfg: ModelConfig, log):
    os.makedirs(f"{out}/weights", exist_ok=True)
    ckpt = f"{out}/weights/ckpt_{cfg.name}.npz"
    if os.path.exists(ckpt):
        raw = np.load(ckpt)
        params = {k: jnp.asarray(raw[k]) for k in raw.files}
        log(f"loaded cached checkpoint {ckpt}")
    else:
        with open(f"{out}/data/train.bin", "rb") as f:
            data = f.read()
        lines = []

        def tee(msg):
            lines.append(msg)
            log(msg)

        t0 = time.time()
        params, loss_log = train(cfg, data, steps=TRAIN_STEPS, log_fn=tee)
        tee(f"trained {cfg.name} ({cfg.n_params()/1e6:.2f}M params) in {time.time()-t0:.1f}s")
        np.savez(ckpt, **{k: np.asarray(v) for k, v in params.items()})
        with open(f"{out}/train_log_{cfg.name}.txt", "w") as f:
            f.write("\n".join(lines) + "\n")

    # Raw f32 little-endian in canonical order + JSON metadata.
    meta = {"config": cfg.to_json_dict(), "dtype": "f32", "tensors": {}}
    offset = 0
    with open(f"{out}/weights/model_{cfg.name}.bin", "wb") as f:
        for name in cfg.param_order():
            arr = np.asarray(params[name], dtype="<f4")
            assert arr.shape == cfg.param_shape(name)
            meta["tensors"][name] = {"offset": offset, "shape": list(arr.shape)}
            f.write(arr.tobytes())
            offset += arr.size
    meta["total_elements"] = offset
    with open(f"{out}/weights/model_{cfg.name}.json", "w") as f:
        json.dump(meta, f, indent=1)
    log(f"weights/model_{cfg.name}.bin: {offset} f32 elements")
    return params


def build_hlo(out, cfg: ModelConfig, params, log):
    os.makedirs(f"{out}/hlo", exist_ok=True)
    tok_spec = jax.ShapeDtypeStruct((EVAL_BATCH, cfg.seq_len), jnp.int32)
    param_specs = [
        jax.ShapeDtypeStruct(cfg.param_shape(n), jnp.float32) for n in cfg.param_order()
    ]

    exports = [
        (f"nll_{cfg.name}.hlo.txt", make_nll_fn(cfg, use_pallas=True), (tok_spec, *param_specs)),
        (f"nll_{cfg.name}_ref.hlo.txt", make_nll_fn(cfg, use_pallas=False), (tok_spec, *param_specs)),
        (f"logits_{cfg.name}.hlo.txt", make_logits_fn(cfg, use_pallas=False), (tok_spec, *param_specs)),
    ]
    for fname, fn, args in exports:
        path = f"{out}/hlo/{fname}"
        if not os.path.exists(path):
            n = export_hlo(fn, args, path)
            log(f"hlo/{fname}: {n} chars")

    # Kernel-level artifacts (integration-tested from Rust).
    n, m, b = 512, 512, 8
    kpath = f"{out}/hlo/binary_gemm.hlo.txt"
    if not os.path.exists(kpath):
        export_hlo(
            lambda s, a, u, x: (binary_linear(s, a, u, x),),
            (
                jax.ShapeDtypeStruct((n, m), jnp.float32),
                jax.ShapeDtypeStruct((n, 2), jnp.float32),
                jax.ShapeDtypeStruct((n, 2), jnp.float32),
                jax.ShapeDtypeStruct((m, b), jnp.float32),
            ),
            kpath,
        )
        log("hlo/binary_gemm.hlo.txt")
    hpath = f"{out}/hlo/haar_fwd.hlo.txt"
    if not os.path.exists(hpath):
        export_hlo(
            lambda x: (haar_fwd(x),),
            (jax.ShapeDtypeStruct((256, 512), jnp.float32),),
            hpath,
        )
        log("hlo/haar_fwd.hlo.txt")
    rpath = f"{out}/hlo/haar_roundtrip.hlo.txt"
    if not os.path.exists(rpath):
        export_hlo(
            lambda x: (haar_inv(haar_fwd(x)),),
            (jax.ShapeDtypeStruct((256, 512), jnp.float32),),
            rpath,
        )
        log("hlo/haar_roundtrip.hlo.txt")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--config", default="tiny", choices=list(CONFIGS))
    args = ap.parse_args()
    out = args.out_dir
    os.makedirs(out, exist_ok=True)
    cfg = CONFIGS[args.config]

    def log(msg):
        print(f"[aot] {msg}", flush=True)

    t0 = time.time()
    build_data(out, log)
    params = build_weights(out, cfg, log)
    build_hlo(out, cfg, params, log)

    manifest = {
        "config": cfg.name,
        "eval_batch": EVAL_BATCH,
        "corpora": list(CORPora_SIZES),
        "task_families": datagen.TASK_FAMILIES,
        "entry_points": {
            "nll": f"hlo/nll_{cfg.name}.hlo.txt",
            "nll_ref": f"hlo/nll_{cfg.name}_ref.hlo.txt",
            "logits": f"hlo/logits_{cfg.name}.hlo.txt",
            "binary_gemm": "hlo/binary_gemm.hlo.txt",
            "haar_fwd": "hlo/haar_fwd.hlo.txt",
            "haar_roundtrip": "hlo/haar_roundtrip.hlo.txt",
        },
        "weights": {cfg.name: f"weights/model_{cfg.name}.json"},
    }
    with open(f"{out}/manifest.json", "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
