"""Shared configuration for the HBLLM build path (L1/L2).

Everything here is build-time only: the Rust runtime reads the exported
`model_<cfg>.json` metadata instead of importing this module.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    """Byte-level GPT configuration.

    The architecture is deliberately minimal and exactly replicated by the
    pure-Rust forward in `rust/src/model/` (used for calibration capture):
    learned token+position embeddings, pre-RMSNorm blocks, causal MHA,
    tanh-GELU MLP, untied unembedding, no biases.
    """

    name: str
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    vocab: int = 256  # byte-level

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_order(self):
        """Canonical flat ordering of parameters.

        This order defines the positional argument list of every exported
        HLO entry point and the layout of the weight binary. The Rust side
        reads the same list from model_<cfg>.json.
        """
        names = ["tok_emb", "pos_emb"]
        for i in range(self.n_layers):
            names += [
                f"l{i}.ln1",
                f"l{i}.wq",
                f"l{i}.wk",
                f"l{i}.wv",
                f"l{i}.wo",
                f"l{i}.ln2",
                f"l{i}.w1",
                f"l{i}.w2",
            ]
        names += ["ln_f", "unemb"]
        return names

    def param_shape(self, name: str):
        d, f, v, s = self.d_model, self.d_ff, self.vocab, self.seq_len
        if name == "tok_emb":
            return (v, d)
        if name == "pos_emb":
            return (s, d)
        if name == "unemb":
            return (d, v)
        if name == "ln_f":
            return (d,)
        base = name.split(".")[-1]
        return {
            "ln1": (d,),
            "ln2": (d,),
            "wq": (d, d),
            "wk": (d, d),
            "wv": (d, d),
            "wo": (d, d),
            "w1": (d, f),
            "w2": (f, d),
        }[base]

    def n_params(self) -> int:
        total = 0
        for n in self.param_order():
            c = 1
            for dim in self.param_shape(n):
                c *= dim
            total += c
        return total

    def to_json_dict(self):
        d = asdict(self)
        d["d_head"] = self.d_head
        d["param_order"] = self.param_order()
        d["param_shapes"] = {n: list(self.param_shape(n)) for n in self.param_order()}
        return d


CONFIGS = {
    # trained at build time; drives all e2e experiments
    "tiny": ModelConfig("tiny", d_model=256, n_layers=4, n_heads=4, d_ff=1024, seq_len=128),
    # larger sweep points for Table 3/4 scaling (quantized but not trained by default)
    "small": ModelConfig("small", d_model=384, n_layers=6, n_heads=6, d_ff=1536, seq_len=128),
    "base": ModelConfig("base", d_model=512, n_layers=8, n_heads=8, d_ff=2048, seq_len=128),
    # micro config for fast unit tests only
    "micro": ModelConfig("micro", d_model=32, n_layers=1, n_heads=2, d_ff=64, seq_len=16),
}

# Batch size baked into the exported eval entry points. The Rust evaluator
# pads the final partial batch.
EVAL_BATCH = 8

# Calibration / data-generation seeds (deterministic build).
DATA_SEED = 20250711
TRAIN_SEED = 7
