"""L2: the byte-level GPT used for all HBLLM experiments (build-time JAX).

The forward is written so that the pure-Rust replica in `rust/src/model/`
(used for calibration-activation capture) matches it op-for-op in f32:
learned token+position embeddings, pre-RMSNorm blocks, causal MHA through the
L1 Pallas attention kernel, tanh-GELU MLP, untied unembedding, no biases.

Exported entry points (see aot.py):
  * nll(tokens, *params)    -> per-position next-token NLL [B, S-1]
  * logits(tokens, *params) -> full logits [B, S, V]
"""

import functools

import jax
import jax.numpy as jnp

from .common import ModelConfig
from .kernels.attention import attention as pallas_attention

RMS_EPS = 1e-5
_GELU_C = 0.7978845608028654  # sqrt(2/pi)


def rmsnorm(x, g):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + RMS_EPS) * g


def gelu_tanh(x):
    return 0.5 * x * (1.0 + jnp.tanh(_GELU_C * (x + 0.044715 * x * x * x)))


def init_params(cfg: ModelConfig, key):
    """Scaled-normal init; returns {name: array} in cfg.param_order() order."""
    params = {}
    keys = jax.random.split(key, len(cfg.param_order()))
    for k, name in zip(keys, cfg.param_order()):
        shape = cfg.param_shape(name)
        if len(shape) == 1:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0]
            std = 0.02 if name in ("tok_emb", "pos_emb") else 1.0 / jnp.sqrt(fan_in)
            params[name] = std * jax.random.normal(k, shape, jnp.float32)
    return params


def flatten_params(cfg: ModelConfig, params):
    return [params[n] for n in cfg.param_order()]


def unflatten_params(cfg: ModelConfig, flat):
    return dict(zip(cfg.param_order(), flat))


def _attend(cfg: ModelConfig, x, wq, wk, wv, wo, use_pallas: bool):
    """x: [B, S, D]."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    def split_heads(t):
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3)  # [B,h,S,dh]

    q, k, v = (split_heads(x @ w) for w in (wq, wk, wv))
    if use_pallas:
        # Kernel signature is [h, s, d]; fold batch into heads.
        qf = q.reshape(b * h, s, dh)
        kf = k.reshape(b * h, s, dh)
        vf = v.reshape(b * h, s, dh)
        of = pallas_attention(qf, kf, vf)
        o = of.reshape(b, h, s, dh)
    else:
        from .kernels.ref import attention_ref

        o = jax.vmap(attention_ref)(q.reshape(b, h, s, dh), k, v)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, d)
    return o @ wo


def forward(cfg: ModelConfig, params, tokens, use_pallas: bool = True):
    """tokens: i32 [B, S] -> logits f32 [B, S, V]."""
    x = params["tok_emb"][tokens] + params["pos_emb"][None, : tokens.shape[1]]
    for i in range(cfg.n_layers):
        p = lambda n: params[f"l{i}.{n}"]  # noqa: E731
        hx = rmsnorm(x, p("ln1"))
        x = x + _attend(cfg, hx, p("wq"), p("wk"), p("wv"), p("wo"), use_pallas)
        hx = rmsnorm(x, p("ln2"))
        x = x + gelu_tanh(hx @ p("w1")) @ p("w2")
    x = rmsnorm(x, params["ln_f"])
    return x @ params["unemb"]


def nll(cfg: ModelConfig, params, tokens, use_pallas: bool = True):
    """Per-position next-token negative log likelihood: [B, S-1]."""
    logits = forward(cfg, params, tokens, use_pallas)[:, :-1]
    targets = tokens[:, 1:]
    logz = jax.nn.logsumexp(logits, axis=-1)
    tgt_logit = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return logz - tgt_logit


def mean_nll(cfg: ModelConfig, params, tokens, use_pallas: bool = True):
    return jnp.mean(nll(cfg, params, tokens, use_pallas))


# ---------------------------------------------------------------------------
# Positional-arg wrappers for AOT export (weights as explicit HLO parameters
# so the Rust side can swap quantized weights without re-lowering).
# ---------------------------------------------------------------------------

def make_nll_fn(cfg: ModelConfig, use_pallas: bool = True):
    def fn(tokens, *flat):
        return (nll(cfg, unflatten_params(cfg, list(flat)), tokens, use_pallas),)

    return fn


def make_logits_fn(cfg: ModelConfig, use_pallas: bool = True):
    def fn(tokens, *flat):
        return (forward(cfg, unflatten_params(cfg, list(flat)), tokens, use_pallas),)

    return fn


@functools.partial(jax.jit, static_argnums=(0,))
def jit_mean_nll(cfg: ModelConfig, params, tokens):
    return mean_nll(cfg, params, tokens, use_pallas=False)
