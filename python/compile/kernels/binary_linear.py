"""L1 Pallas kernel: fused 1-bit Haar dequantization + matmul (the hot path).

This is the deployment kernel of HBLLM (§3.6 + §4.5): weights live as Haar-
domain sign bits plus per-row per-band (alpha, mu); reconstruction is a local
2-tap synthesis, so it fuses into the tile load and feeds the matmul unit
directly — the paper's O(d) inverse-transform argument.

TPU mapping: each grid step loads a [BLOCK_N, m] sign panel + the matching
alpha/mu column pair into VMEM, reconstructs W in-register (VPU: one fma +
butterfly), then issues an MXU matmul against the resident x panel. A global
orthogonal transform (FrameQuant) cannot tile this way: every output tile
would need all d columns of the inverse rotation.

interpret=True (CPU PJRT); lowers to plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 64


def _binary_linear_kernel(s_ref, a_ref, u_ref, x_ref, o_ref):
    s = s_ref[...]  # [bn, m] signs (+-1)
    a = a_ref[...]  # [bn, 2]
    u = u_ref[...]  # [bn, 2]
    x = x_ref[...]  # [m, b]
    m = s.shape[-1]
    h = m // 2
    # Dequantize per band, then inline Haar synthesis:
    #   w[2k]   = lo[k] + hi[k]
    #   w[2k+1] = lo[k] - hi[k]
    lo = a[:, 0:1] * s[:, :h] + u[:, 0:1]
    hi = a[:, 1:2] * s[:, h:] + u[:, 1:2]
    w = jnp.stack([lo + hi, lo - hi], axis=-1).reshape(s.shape[0], m)
    o_ref[...] = jnp.dot(w, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_n",))
def binary_linear(signs, alpha, mu, x, block_n: int = DEFAULT_BLOCK_N):
    """Compute HaarInv(alpha * signs + mu) @ x without materializing W in HBM.

    signs: [n, m] floats in {-1, +1}; alpha, mu: [n, 2]; x: [m, b] -> [n, b].
    """
    n, m = signs.shape
    b = x.shape[1]
    assert m % 2 == 0 and x.shape[0] == m
    block_n = min(block_n, n)
    pad = (-n) % block_n
    if pad:
        z = jnp.zeros((pad, m), signs.dtype)
        signs = jnp.concatenate([signs, z], axis=0)
        alpha = jnp.concatenate([alpha, jnp.zeros((pad, 2), alpha.dtype)], axis=0)
        mu = jnp.concatenate([mu, jnp.zeros((pad, 2), mu.dtype)], axis=0)
    grid = (signs.shape[0] // block_n,)
    out = pl.pallas_call(
        _binary_linear_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 2), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 2), lambda i: (i, 0)),
            pl.BlockSpec((m, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, b), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((signs.shape[0], b), jnp.float32),
        interpret=True,
    )(signs, alpha, mu, x)
    return out[:n]
