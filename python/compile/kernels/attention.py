"""L1 Pallas kernel: blocked causal attention (used by the L2 forward).

Flash-style query blocking with the full K/V panel resident per head: at the
sequence lengths this model targets (<=128) K/V fit comfortably in VMEM, so
the online-softmax rescaling loop is unnecessary — each grid step computes an
exact softmax over the causally-masked logits of one query block. Grid is
(heads, q_blocks); numerics match `ref.attention_ref` to f32 tolerance.

interpret=True (CPU PJRT); lowers to plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 64


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_q: int, seq: int):
    qi = pl.program_id(1)
    q = q_ref[...][0]  # [bq, d]
    k = k_ref[...][0]  # [s, d]
    v = v_ref[...][0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], jnp.float32))
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
    k_pos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    logits = jnp.where(k_pos <= q_pos, logits, -1e30)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    o_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)[None]


@functools.partial(jax.jit, static_argnames=("block_q",))
def attention(q, k, v, block_q: int = DEFAULT_BLOCK_Q):
    """Causal attention. q,k,v: [h, s, d] -> [h, s, d]."""
    h, s, d = q.shape
    block_q = min(block_q, s)
    assert s % block_q == 0, f"seq {s} must be a multiple of block_q {block_q}"
    grid = (h, s // block_q)
    kern = functools.partial(_attn_kernel, block_q=block_q, seq=s)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((1, s, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((1, s, d), lambda hi, qi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), jnp.float32),
        interpret=True,
    )(q, k, v)
