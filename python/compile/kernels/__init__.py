# L1: Pallas kernels for HBLLM compute hot-spots.
from . import ref  # noqa: F401
from .attention import attention  # noqa: F401
from .binary_linear import binary_linear  # noqa: F401
from .haar import haar_fwd, haar_fwd_cols, haar_inv, haar_inv_cols  # noqa: F401
