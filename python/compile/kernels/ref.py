"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

The Haar convention follows the paper §3.6 exactly: analysis kernels
[1/2, 1/2] (low) and [1/2, -1/2] (high) with stride 2, synthesis
w[2k] = l[k] + h[k], w[2k+1] = l[k] - h[k]. This pair is biorthogonal
(H_inv @ H = I) though not orthonormal; the quantizer only needs exact
invertibility, which `test_haar_kernel.py` asserts to float32 exactness.
"""

import jax.numpy as jnp


def haar_fwd_ref(x):
    """1-level 1D Haar along the last axis. Last dim must be even.

    Returns [..., m] with low coefficients in [..., :m//2], high in the rest.
    """
    lo = (x[..., 0::2] + x[..., 1::2]) * 0.5
    hi = (x[..., 0::2] - x[..., 1::2]) * 0.5
    return jnp.concatenate([lo, hi], axis=-1)


def haar_inv_ref(c):
    """Inverse of `haar_fwd_ref`."""
    m = c.shape[-1]
    lo, hi = c[..., : m // 2], c[..., m // 2 :]
    even = lo + hi
    odd = lo - hi
    out = jnp.stack([even, odd], axis=-1)
    return out.reshape(*c.shape[:-1], m)


def binary_linear_ref(signs, alpha, mu, x):
    """Dequantize row-Haar 1-bit weights and multiply.

    signs: [n, m] in {-1, +1} (float), Haar-domain sign bits.
    alpha: [n, 2] per-row scale, one per frequency band (low, high).
    mu:    [n, 2] per-row shared mean, one per band.
    x:     [m, b] activations.

    Reconstructs C[i, j] = alpha[i, band(j)] * signs[i, j] + mu[i, band(j)],
    W = HaarInv_row(C), returns W @ x  ->  [n, b].
    """
    n, m = signs.shape
    h = m // 2
    band = jnp.concatenate([jnp.zeros(h, jnp.int32), jnp.ones(h, jnp.int32)])
    a = alpha[:, band]  # [n, m]
    u = mu[:, band]
    coeff = a * signs + u
    w = haar_inv_ref(coeff)
    return w @ x


def attention_ref(q, k, v):
    """Causal softmax attention. q,k,v: [h, s, d]. Returns [h, s, d]."""
    s = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    mask = jnp.tril(jnp.ones((s, s), bool))
    logits = jnp.where(mask[None, :, :], logits, jnp.asarray(-1e30, q.dtype))
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)
