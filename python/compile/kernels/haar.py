"""L1 Pallas kernels: 1-level 1D Haar analysis/synthesis along rows.

TPU mapping (DESIGN.md §Hardware-Adaptation): the transform is a 2-tap
stencil, so each VMEM tile of the input produces the matching tiles of both
sub-bands with no cross-tile halo along rows — the BlockSpec streams
[BLOCK_ROWS, m] row panels HBM->VMEM and the butterfly runs entirely on the
VPU. Always lowered with interpret=True here (CPU PJRT cannot execute
Mosaic custom-calls); interpret mode lowers to plain HLO.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 64


def _haar_fwd_kernel(x_ref, o_ref):
    x = x_ref[...]
    lo = (x[:, 0::2] + x[:, 1::2]) * 0.5
    hi = (x[:, 0::2] - x[:, 1::2]) * 0.5
    o_ref[...] = jnp.concatenate([lo, hi], axis=-1)


def _haar_inv_kernel(c_ref, o_ref):
    c = c_ref[...]
    m = c.shape[-1]
    lo, hi = c[:, : m // 2], c[:, m // 2 :]
    out = jnp.stack([lo + hi, lo - hi], axis=-1).reshape(c.shape[0], m)
    o_ref[...] = out


def _rows_call(kernel, x, block_rows):
    n, m = x.shape
    assert m % 2 == 0, f"Haar needs an even trailing dim, got {m}"
    block_rows = min(block_rows, n)
    # Pad rows up to a multiple of the block; extra rows are discarded.
    pad = (-n) % block_rows
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, m), x.dtype)], axis=0)
    grid = (x.shape[0] // block_rows,)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, m), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def haar_fwd(x, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Row-wise Haar analysis: [n, m] -> [n, m] (low half ++ high half)."""
    return _rows_call(_haar_fwd_kernel, x, block_rows)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def haar_inv(c, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Row-wise Haar synthesis: exact inverse of `haar_fwd`."""
    return _rows_call(_haar_inv_kernel, c, block_rows)


def haar_fwd_cols(x, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Column-wise analysis (pairs adjacent rows), via transpose."""
    return haar_fwd(x.T, block_rows=block_rows).T


def haar_inv_cols(c, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Column-wise synthesis."""
    return haar_inv(c.T, block_rows=block_rows).T
