# HBLLM build path (L1 kernels + L2 model + AOT).
