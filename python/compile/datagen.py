"""Synthetic language, corpora and QA tasks (stand-ins for C4/Wiki2/PTB + the
9 zero-shot benchmarks; see DESIGN.md §Substitutions).

One Zipfian vocabulary with part-of-speech structure and a small template
grammar generates all text. Three eval corpora shift the mixture the way the
paper's three perplexity sets differ in register:

  * c4s    — diverse templates, noisy punctuation, web-ish.
  * wiki2s — longer declarative sentences, headings, lower temperature.
  * ptbs   — short sentences, frequent <unk> substitution.

QA task families mirror the mechanics of the paper's 9 benchmarks: every item
is (prompt, options, correct-index) and is scored by comparing option NLLs,
exactly like lm-eval-harness does for multiple-choice tasks. Correct options
continue the synthetic grammar; distractors violate it in family-specific
ways (shuffled words, wrong word class, inconsistent entity, corrupted
endings, rare-word swaps, ...).

Binary task format (read by rust/src/eval/tasks.rs):
  file  := header item*
  header:= u32 magic 0x48425154 ("HBQT"), u32 n_items
  item  := u16 prompt_len, prompt bytes,
           u8 n_options, u8 correct_idx,
           n_options * (u16 len, bytes)
"""

import random
import struct

from .common import DATA_SEED

TASK_MAGIC = 0x48425154

CONSONANTS = "bcdfghjklmnpqrstvwz"
VOWELS = "aeiou"


def _make_word(rng, syllables):
    return "".join(rng.choice(CONSONANTS) + rng.choice(VOWELS) for _ in range(syllables))


class Language:
    """Deterministic synthetic language: Zipf vocab split into POS classes."""

    def __init__(self, seed=DATA_SEED, vocab_size=1200):
        rng = random.Random(seed)
        words = []
        seen = set()
        while len(words) < vocab_size:
            w = _make_word(rng, rng.randint(1, 4))
            if w not in seen:
                seen.add(w)
                words.append(w)
        self.rng_seed = seed
        # POS classes: determiners(5), nouns(45%), verbs(25%), adjectives(20%), adverbs(rest)
        self.det = ["ta", "ku", "mo", "se", "ri"]
        n = vocab_size
        self.nouns = words[: int(0.45 * n)]
        self.verbs = words[int(0.45 * n) : int(0.70 * n)]
        self.adjs = words[int(0.70 * n) : int(0.90 * n)]
        self.advs = words[int(0.90 * n) :]

    def _zipf(self, rng, pool, temp=1.0):
        # Zipf-like sampling: rank r with p ~ 1/r^temp via inverse CDF trick.
        u = rng.random()
        r = int(len(pool) * (u ** (1.0 + temp)))
        return pool[min(r, len(pool) - 1)]

    def noun_phrase(self, rng, temp=1.0):
        parts = [rng.choice(self.det)]
        if rng.random() < 0.55:
            parts.append(self._zipf(rng, self.adjs, temp))
        parts.append(self._zipf(rng, self.nouns, temp))
        return parts

    def verb_phrase(self, rng, temp=1.0):
        parts = [self._zipf(rng, self.verbs, temp)]
        if rng.random() < 0.35:
            parts.append(self._zipf(rng, self.advs, temp))
        return parts

    def sentence(self, rng, temp=1.0, min_clauses=1, max_clauses=2):
        words = []
        for c in range(rng.randint(min_clauses, max_clauses)):
            if c:
                words.append(rng.choice(["and", "but", "so"]))
            words += self.noun_phrase(rng, temp)
            words += self.verb_phrase(rng, temp)
            words += self.noun_phrase(rng, temp)
        return words

    def paragraph(self, rng, n_sents, temp=1.0, short=False):
        out = []
        for _ in range(n_sents):
            ws = self.sentence(rng, temp, 1, 1 if short else 3)
            out.append(" ".join(ws) + ".")
        return " ".join(out)


def gen_corpus(lang: Language, kind: str, n_bytes: int, seed_offset=0) -> bytes:
    rng = random.Random(DATA_SEED + 1000 + seed_offset + sum(map(ord, kind)))
    chunks = []
    size = 0
    while size < n_bytes:
        k = rng.choice(["c4s", "wiki2s", "ptbs"]) if kind == "train" else kind
        if k == "c4s":
            text = lang.paragraph(rng, rng.randint(2, 6), temp=1.0)
            if rng.random() < 0.3:
                text = text.replace(".", rng.choice([".", "!", "?", "..."]), 1)
            text += "\n"
        elif k == "wiki2s":
            if rng.random() < 0.12:
                text = "= " + " ".join(lang.noun_phrase(rng, 0.6)) + " =\n"
            else:
                text = lang.paragraph(rng, rng.randint(4, 8), temp=0.6) + "\n"
        elif k == "ptbs":
            text = lang.paragraph(rng, rng.randint(1, 3), temp=0.9, short=True)
            ws = text.split(" ")
            for i in range(len(ws)):
                if rng.random() < 0.04:
                    ws[i] = "<unk>"
            text = " ".join(ws) + "\n"
        else:
            raise ValueError(kind)
        b = text.encode("utf-8")
        chunks.append(b)
        size += len(b)
    return b"".join(chunks)[:n_bytes]


# ---------------------------------------------------------------------------
# QA task families (9, mirroring the paper's benchmark list)
# ---------------------------------------------------------------------------

def _corrupt_shuffle(rng, words):
    w = list(words)
    while len(w) > 1:
        rng.shuffle(w)
        if w != list(words):
            break
    return w


def _item_continuation(lang, rng, n_distract, corrupt):
    """Prompt = sentence prefix; correct = grammatical continuation."""
    ws = lang.sentence(rng, 1.0, 2, 3)
    cut = rng.randint(len(ws) // 3, 2 * len(ws) // 3)
    prompt = " ".join(ws[:cut]) + " "
    good = " ".join(ws[cut:]) + "."
    options = [good]
    for _ in range(n_distract):
        options.append(corrupt(rng, ws[cut:]))
    order = list(range(len(options)))
    rng.shuffle(order)
    correct = order.index(0)
    return prompt, [options[i] for i in order], correct


def make_task_items(lang: Language, family: str, n_items: int, seed_offset=0):
    rng = random.Random(DATA_SEED + 2000 + seed_offset + sum(map(ord, family)))
    items = []
    for _ in range(n_items):
        if family == "piqa_s":
            # 2 options; distractor = word-shuffled continuation
            items.append(_item_continuation(
                lang, rng, 1, lambda r, w: " ".join(_corrupt_shuffle(r, w)) + "."))
        elif family == "copa_s":
            # cause->effect: correct effect reuses the subject noun
            np1 = lang.noun_phrase(rng)
            vp = lang.verb_phrase(rng)
            obj = lang.noun_phrase(rng)
            prompt = " ".join(np1 + vp + obj) + " so "
            good = " ".join(np1 + lang.verb_phrase(rng)) + "."
            bad = " ".join(lang.noun_phrase(rng) + [rng.choice(lang.nouns)]) + "."
            opts = [good, bad]
            order = [0, 1] if rng.random() < 0.5 else [1, 0]
            items.append((prompt, [opts[i] for i in order], order.index(0)))
        elif family == "boolq_s":
            # statement-repetition consistency: after seeing a sentence and
            # its verbatim restart, the true continuation is the original
            # tail; the distractor is the tail of an unrelated sentence
            ws = lang.sentence(rng, 1.0, 1, 1)
            other = lang.sentence(rng, 1.0, 1, 1)
            cut = max(1, len(ws) // 2)
            prompt = " ".join(ws) + ". " + " ".join(ws[:cut]) + " "
            good = " ".join(ws[cut:]) + "."
            bad = " ".join(other[cut:] if len(other) > cut else other) + "."
            opts = [good, bad]
            order = [0, 1] if rng.random() < 0.5 else [1, 0]
            items.append((prompt, [opts[i] for i in order], order.index(0)))
        elif family == "winogrande_s":
            # entity consistency: correct continuation repeats the earlier noun
            noun = rng.choice(lang.nouns)
            other = rng.choice(lang.nouns)
            det = rng.choice(lang.det)
            vp1 = lang.verb_phrase(rng)
            vp2 = lang.verb_phrase(rng)
            prompt = f"{det} {noun} {' '.join(vp1)} and {det} "
            items.append((prompt, [f"{noun} {' '.join(vp2)}.", f"{other} {' '.join(vp2)}."], 0))
        elif family == "arc_e_s":
            # word-class agreement in the verb slot: a common verb vs a
            # common noun (frequency-matched so byte statistics don't give
            # the answer away — only positional grammar does)
            np = lang.noun_phrase(rng)
            prompt = " ".join(np) + " "
            good = rng.choice(lang.verbs[:200])
            bad = rng.choice(lang.nouns[:200])
            opts = [good + ".", bad + "."]
            order = [0, 1] if rng.random() < 0.5 else [1, 0]
            items.append((prompt, [opts[i] for i in order], order.index(0)))
        elif family == "arc_c_s":
            # harder: common verb vs rare verb (frequency sensitivity)
            np = lang.noun_phrase(rng)
            prompt = " ".join(np) + " "
            good = lang.verbs[rng.randint(0, 30)]
            bad = lang.verbs[rng.randint(len(lang.verbs) - 30, len(lang.verbs) - 1)]
            opts = [good + ".", bad + "."]
            order = [0, 1] if rng.random() < 0.5 else [1, 0]
            items.append((prompt, [opts[i] for i in order], order.index(0)))
        elif family == "hellaswag_s":
            # 4 options: 1 good + 3 shuffled corruptions
            items.append(_item_continuation(
                lang, rng, 3, lambda r, w: " ".join(_corrupt_shuffle(r, w)) + "."))
        elif family == "obqa_s":
            # 4 options: good, shuffled, wrong-class, rare-word
            ws = lang.sentence(rng, 1.0, 2, 2)
            cut = len(ws) // 2
            prompt = " ".join(ws[:cut]) + " "
            good = " ".join(ws[cut:]) + "."
            shuf = " ".join(_corrupt_shuffle(rng, ws[cut:])) + "."
            other = lang.sentence(rng, 1.0, 2, 2)
            alt = " ".join(_corrupt_shuffle(rng, other[: len(ws) - cut])) + "."
            rare = " ".join(lang.nouns[rng.randint(len(lang.nouns) - 40, len(lang.nouns) - 1)]
                            for _ in ws[cut:]) + "."
            opts = [good, shuf, alt, rare]
            order = list(range(4))
            rng.shuffle(order)
            items.append((prompt, [opts[i] for i in order], order.index(0)))
        elif family == "lambada_s":
            # long context; predict the final word (true vs random noun)
            para = lang.paragraph(rng, 3, temp=0.8)
            ws = lang.sentence(rng, 0.8, 1, 1)
            prompt = para + " " + " ".join(ws[:-1]) + " "
            good = ws[-1] + "."
            bad = rng.choice(lang.nouns) + "."
            opts = [good, bad]
            order = [0, 1] if rng.random() < 0.5 else [1, 0]
            items.append((prompt, [opts[i] for i in order], order.index(0)))
        else:
            raise ValueError(family)
    return items


TASK_FAMILIES = [
    "piqa_s", "boolq_s", "obqa_s", "winogrande_s", "arc_e_s",
    "arc_c_s", "hellaswag_s", "copa_s", "lambada_s",
]


def write_task_file(path, items):
    with open(path, "wb") as f:
        f.write(struct.pack("<II", TASK_MAGIC, len(items)))
        for prompt, options, correct in items:
            pb = prompt.encode("utf-8")
            f.write(struct.pack("<H", len(pb)))
            f.write(pb)
            f.write(struct.pack("<BB", len(options), correct))
            for o in options:
                ob = o.encode("utf-8")
                f.write(struct.pack("<H", len(ob)))
                f.write(ob)


def read_task_file(path):
    with open(path, "rb") as f:
        magic, n = struct.unpack("<II", f.read(8))
        assert magic == TASK_MAGIC
        items = []
        for _ in range(n):
            (plen,) = struct.unpack("<H", f.read(2))
            prompt = f.read(plen).decode("utf-8")
            nopt, correct = struct.unpack("<BB", f.read(2))
            opts = []
            for _ in range(nopt):
                (olen,) = struct.unpack("<H", f.read(2))
                opts.append(f.read(olen).decode("utf-8"))
            items.append((prompt, opts, correct))
    return items
