//! The HTTP/SSE front-end, end to end (no artifacts needed): SSE
//! generation pinned byte-identical to the TCP `gen` path and to a direct
//! in-process decode — plain and speculative — plus the JSON score/stats
//! endpoints, the `err kv exhausted` → recovery path over SSE, the TCP
//! `prio` verb, and the 4xx error surface. Wire spec: `docs/API.md`.

use hbllm::coordinator::{http, serve, BatcherConfig, Priority};
use hbllm::engine::{self, Backend, NativeBackend, PackedModel, SpecConfig};
use hbllm::model::testing::micro_weights;
use hbllm::util::json::Json;
use hbllm::util::rng::Pcg32;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};

fn packed_micro(seed: u64) -> NativeBackend {
    let w = micro_weights(seed);
    NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1)
}

/// One raw HTTP request on its own connection; returns (status, body).
fn http_request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: test\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut resp = String::new();
    BufReader::new(stream).read_to_string(&mut resp).unwrap();
    let (head, body) = resp.split_once("\r\n\r\n").expect("no header/body separator");
    let status: u16 = head.split_whitespace().nth(1).unwrap().parse().unwrap();
    (status, body.to_string())
}

/// Parse an SSE body into (event, data) pairs.
fn parse_events(body: &str) -> Vec<(String, String)> {
    let mut events = Vec::new();
    let mut ev = String::new();
    for line in body.lines() {
        if let Some(e) = line.strip_prefix("event: ") {
            ev = e.to_string();
        } else if let Some(d) = line.strip_prefix("data: ") {
            events.push((ev.clone(), d.to_string()));
        }
    }
    events
}

/// Drive a TCP `gen` (optionally `prio`-prefixed) and collect the
/// streamed bytes; asserts the `done <n>` terminator.
fn tcp_generate(addr: SocketAddr, line_out: &str, n_new: usize) -> Vec<u8> {
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut stream = stream;
    stream.write_all(line_out.as_bytes()).unwrap();
    let mut toks: Vec<u8> = Vec::new();
    let mut line = String::new();
    loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let t = line.trim_end();
        if let Some(b) = t.strip_prefix("tok ") {
            toks.push(b.parse().unwrap());
        } else {
            assert_eq!(t, format!("done {n_new}"), "bad terminator: {t:?}");
            break;
        }
    }
    toks
}

/// The acceptance pin: for the same prompt/seed, the SSE stream from
/// `POST /v1/generate` carries exactly the token payload sequence the TCP
/// `gen` verb streams — and both match a direct in-process greedy decode.
#[test]
fn sse_generation_matches_tcp_byte_for_byte() {
    let seed = 71;
    let n_new = 6;
    let mut be = packed_micro(seed);
    be.set_lanes(2);
    let (tcp_l, tcp_addr) = serve::bind("127.0.0.1:0").unwrap();
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    let tcp_client = std::thread::spawn(move || {
        tcp_generate(tcp_addr, &format!("gen {n_new} 0 0 ta ki\n"), n_new)
    });
    let http_client = std::thread::spawn(move || {
        let mut toks: Vec<u8> = Vec::new();
        let n = http::client_generate(
            &format!("http://{http_addr}"),
            "ta ki",
            n_new,
            0.0,
            0,
            Priority::Interactive,
            |b| toks.push(b),
        )
        .unwrap();
        assert_eq!(n, n_new);
        toks
    });

    serve::serve_fronts(
        vec![serve::FrontEnd::line(tcp_l, Some(1)), http::HttpConn::front_end(http_l, Some(1))],
        &mut be,
        BatcherConfig::default(),
    )
    .unwrap();
    let tcp_toks = tcp_client.join().unwrap();
    let http_toks = http_client.join().unwrap();
    assert_eq!(http_toks, tcp_toks, "SSE and TCP streams diverged");

    let mut solo = packed_micro(seed);
    let mut rng = Pcg32::seeded(0);
    let want = engine::generate(&mut solo, b"ta ki", n_new, 0.0, &mut rng).unwrap();
    assert_eq!(
        &want[b"ta ki".len()..],
        &http_toks[..],
        "served stream diverged from direct decode"
    );
}

/// Same pin with a speculative lane: `--spec-k` must not change a single
/// byte on either front-end (the frequency cascade only reschedules).
#[test]
fn sse_spec_lane_matches_tcp_and_plain_decode() {
    let seed = 72;
    let n_new = 8;
    let mut be = packed_micro(seed);
    be.set_lanes(2);
    let eff = be.set_spec(SpecConfig::with_k(3));
    assert!(eff.enabled, "native backend must accept the draft config");
    let (tcp_l, tcp_addr) = serve::bind("127.0.0.1:0").unwrap();
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    let tcp_client = std::thread::spawn(move || {
        tcp_generate(tcp_addr, &format!("gen {n_new} 0 0 ta kivo\n"), n_new)
    });
    let http_client = std::thread::spawn(move || {
        let mut toks: Vec<u8> = Vec::new();
        http::client_generate(
            &format!("http://{http_addr}"),
            "ta kivo",
            n_new,
            0.0,
            0,
            Priority::Interactive,
            |b| toks.push(b),
        )
        .unwrap();
        toks
    });

    serve::serve_fronts(
        vec![serve::FrontEnd::line(tcp_l, Some(1)), http::HttpConn::front_end(http_l, Some(1))],
        &mut be,
        BatcherConfig { spec: eff, ..Default::default() },
    )
    .unwrap();
    let tcp_toks = tcp_client.join().unwrap();
    let http_toks = http_client.join().unwrap();
    assert_eq!(http_toks, tcp_toks, "speculative SSE diverged from speculative TCP");

    // the reference is a *plain* greedy decode: speculation must be
    // byte-invisible
    let mut solo = packed_micro(seed);
    let mut rng = Pcg32::seeded(0);
    let want = engine::generate(&mut solo, b"ta kivo", n_new, 0.0, &mut rng).unwrap();
    assert_eq!(&want[b"ta kivo".len()..], &http_toks[..], "speculation changed served bytes");
}

/// KV exhaustion over SSE: an arena too small for the request streams an
/// `event: error` / `data: kv exhausted` terminal frame (mirroring the
/// TCP `err kv exhausted` line), and a fitting request on a fresh
/// connection completes afterwards — the eviction released every block.
#[test]
fn kv_exhaustion_over_sse_reports_error_event_and_recovers() {
    let seed = 73;
    let mut be = packed_micro(seed);
    be.set_lanes(2);
    be.set_kv_blocks(Some(1), Some(4)); // one 4-token block total
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    let client = std::thread::spawn(move || {
        // 4-byte prompt + 6 tokens needs 3 blocks; only 1 exists
        let (status, body) = http_request(
            http_addr,
            "POST",
            "/v1/generate",
            r#"{"prompt": "abcd", "max_new": 6}"#,
        );
        assert_eq!(status, 200);
        let events = parse_events(&body);
        let toks = events.iter().filter(|(e, _)| e == "tok").count();
        assert!(toks < 6, "over-long sequence was never evicted");
        assert_eq!(
            events.last().map(|(e, d)| (e.as_str(), d.as_str())),
            Some(("error", "kv exhausted")),
            "wrong terminal frame: {events:?}"
        );
        // eviction released the arena: a fitting request completes
        let (status, body) = http_request(
            http_addr,
            "POST",
            "/v1/generate",
            r#"{"prompt": "ab", "max_new": 2}"#,
        );
        assert_eq!(status, 200);
        let events = parse_events(&body);
        assert_eq!(
            events.last().map(|(e, d)| (e.as_str(), d.as_str())),
            Some(("done", "2")),
            "server wedged after kv eviction: {events:?}"
        );
        assert_eq!(events.iter().filter(|(e, _)| e == "tok").count(), 2);
    });

    serve::serve_fronts(
        vec![http::HttpConn::front_end(http_l, Some(2))],
        &mut be,
        BatcherConfig::default(),
    )
    .unwrap();
    client.join().unwrap();
}

/// `POST /v1/score`: per-line results in request order, empty input as
/// the TCP error string, ppl/nll agreeing with a direct in-process score.
#[test]
fn score_endpoint_scores_lines_and_flags_empty_input() {
    let seed = 74;
    let mut be = packed_micro(seed);
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    let client = std::thread::spawn(move || {
        let (status, body) = http_request(
            http_addr,
            "POST",
            "/v1/score",
            r#"{"texts": ["ta kivo remo", "   ", "so lute"]}"#,
        );
        assert_eq!(status, 200, "score failed: {body}");
        Json::parse(&body).unwrap()
    });
    serve::serve_fronts(
        vec![http::HttpConn::front_end(http_l, Some(1))],
        &mut be,
        BatcherConfig::default(),
    )
    .unwrap();
    let resp = client.join().unwrap();
    let results = resp.get("results").and_then(Json::as_arr).expect("results array");
    assert_eq!(results.len(), 3);

    // same backend state ⇒ same scores as a direct call
    let mut reference = packed_micro(seed);
    let want = serve::score_texts(
        &mut reference,
        &[b"ta kivo remo".to_vec(), b"so lute".to_vec()],
    );
    for (res, want) in [&results[0], &results[2]].iter().zip(&want) {
        let ppl = res.get("ppl").and_then(Json::as_f64).expect("ppl field");
        let nll = res.get("nll").and_then(Json::as_f64).expect("nll field");
        let w = *want.as_ref().unwrap();
        assert!((ppl - w).abs() < 1e-9, "ppl {ppl} != direct {w}");
        assert!((nll - w.ln()).abs() < 1e-9, "nll is not ln(ppl)");
    }
    assert_eq!(
        results[1].get("error").and_then(Json::as_str),
        Some("empty input"),
        "whitespace-only line not rejected: {:?}",
        results[1]
    );
}

/// `GET /v1/stats` reports the lane count, paged-KV geometry and the
/// (idle) queue state as JSON.
#[test]
fn stats_endpoint_reports_kv_geometry_and_queues() {
    let seed = 75;
    let mut be = packed_micro(seed);
    be.set_lanes(3);
    be.set_kv_blocks(Some(9), Some(4));
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    let client = std::thread::spawn(move || {
        http::client_stats(&format!("http://{http_addr}")).unwrap()
    });
    serve::serve_fronts(
        vec![http::HttpConn::front_end(http_l, Some(1))],
        &mut be,
        BatcherConfig::default(),
    )
    .unwrap();
    let st = client.join().unwrap();
    assert_eq!(st.get("lanes").and_then(Json::as_usize), Some(3));
    assert_eq!(st.get("active").and_then(Json::as_usize), Some(0));
    assert_eq!(st.get("queued").and_then(Json::as_usize), Some(0));
    assert_eq!(st.at(&["kv", "total_blocks"]).and_then(Json::as_usize), Some(9));
    assert_eq!(st.at(&["kv", "block_len"]).and_then(Json::as_usize), Some(4));
    assert_eq!(st.at(&["kv", "free_blocks"]).and_then(Json::as_usize), Some(9));
    // native backend always reports the spec surface; disabled by default
    assert_eq!(st.at(&["spec", "enabled"]), Some(&Json::Bool(false)));
    assert!(st.get("clients").and_then(Json::as_arr).is_some_and(|c| c.is_empty()));
}

/// The TCP `prio` verb: a batch-priority `gen` completes normally, bad
/// levels and non-gen tails are usage errors, and the connection stays
/// usable throughout.
#[test]
fn tcp_prio_verb_parses_and_generates() {
    let seed = 76;
    let n_new = 4;
    let mut be = packed_micro(seed);
    be.set_lanes(2);
    let (tcp_l, tcp_addr) = serve::bind("127.0.0.1:0").unwrap();

    let client = std::thread::spawn(move || {
        let stream = TcpStream::connect(tcp_addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut stream = stream;
        let mut line = String::new();
        let mut req = |s: &str, line: &mut String| {
            stream.write_all(s.as_bytes()).unwrap();
            line.clear();
            reader.read_line(line).unwrap();
        };
        // unknown level and non-gen tails are usage errors
        req("prio urgent gen 4 0 0 ta\n", &mut line);
        assert!(line.starts_with("err usage: prio"), "bad level accepted: {line:?}");
        req("prio batch ppl ta kivo\n", &mut line);
        assert!(line.starts_with("err usage: prio"), "prio must prefix gen only: {line:?}");
        // a batch-priority generation streams like any other
        stream.write_all(format!("prio batch gen {n_new} 0 0 ta ki\n").as_bytes()).unwrap();
        let mut toks: Vec<u8> = Vec::new();
        loop {
            line.clear();
            reader.read_line(&mut line).unwrap();
            let t = line.trim_end();
            if let Some(b) = t.strip_prefix("tok ") {
                toks.push(b.parse().unwrap());
            } else {
                assert_eq!(t, format!("done {n_new}"), "bad terminator: {t:?}");
                break;
            }
        }
        // scoring still works on the same connection
        req("ppl ta kivo remo\n", &mut line);
        assert!(line.starts_with("ppl "), "connection unusable after prio gen: {line:?}");
        toks
    });

    serve::serve_on(tcp_l, &mut be, BatcherConfig::default(), Some(1)).unwrap();
    let toks = client.join().unwrap();
    let mut solo = packed_micro(seed);
    let mut rng = Pcg32::seeded(0);
    let want = engine::generate(&mut solo, b"ta ki", n_new, 0.0, &mut rng).unwrap();
    assert_eq!(&want[b"ta ki".len()..], &toks[..], "prio gen diverged from plain gen");
}

/// `/v1/stats` and `/v1/metrics` read the same atomics: after a
/// generation completes, the stats JSON's `totals` object and the
/// Prometheus exposition report identical cumulative counts.
#[test]
fn stats_totals_agree_with_prometheus_exposition() {
    let seed = 78;
    let mut be = packed_micro(seed);
    be.set_lanes(2);
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    let client = std::thread::spawn(move || {
        let url = format!("http://{http_addr}");
        let mut toks = 0usize;
        let n = http::client_generate(&url, "ta ki", 4, 0.0, 0, Priority::Interactive, |_| {
            toks += 1;
        })
        .unwrap();
        assert_eq!((n, toks), (4, 4));
        // scrape both views after the request is fully terminal (the
        // engine records the Done outcome before the client sees it)
        let st = http::client_stats(&url).unwrap();
        let text = http::client_metrics(&url).unwrap();
        (st, text)
    });
    serve::serve_fronts(
        vec![http::HttpConn::front_end(http_l, Some(3))],
        &mut be,
        BatcherConfig::default(),
    )
    .unwrap();
    let (st, text) = client.join().unwrap();

    // sum every series of a family in the exposition text
    let sample = |name: &str| -> f64 {
        text.lines()
            .filter(|l| !l.starts_with('#'))
            .filter_map(|l| l.rsplit_once(' '))
            .filter(|(k, _)| *k == name || k.starts_with(&format!("{name}{{")))
            .map(|(_, v)| v.parse::<f64>().unwrap())
            .sum()
    };
    let total = |k: &str| st.at(&["totals", k]).and_then(Json::as_f64).unwrap();
    assert_eq!(total("requests_started"), 1.0);
    assert_eq!(total("requests_started"), sample("hbllm_requests_started_total"));
    assert_eq!(total("requests_finished"), sample("hbllm_requests_finished_total"));
    assert_eq!(total("tokens"), 4.0);
    assert_eq!(total("tokens"), sample("hbllm_tokens_total"));
    assert_eq!(total("evictions"), sample("hbllm_evictions_total"));
    assert!(st.get("uptime_ms").and_then(Json::as_f64).unwrap() >= 0.0);
    // the exposition is the documented text format
    assert!(text.contains("# TYPE hbllm_requests_started_total counter"), "{text}");
    assert!(text.contains("# TYPE hbllm_ttft_us histogram"), "{text}");
    assert!(text.ends_with('\n'));
}

/// The HTTP error surface: unknown endpoints are 404, wrong methods 405,
/// malformed bodies and unknown priorities 400 — all as JSON `error`
/// objects, all without wedging the engine.
#[test]
fn http_error_surface_is_4xx_json() {
    let seed = 77;
    let mut be = packed_micro(seed);
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    let client = std::thread::spawn(move || {
        let cases: Vec<(u16, String)> = vec![
            http_request(http_addr, "POST", "/v1/nope", "{}"),
            http_request(http_addr, "GET", "/v1/generate", ""),
            http_request(http_addr, "POST", "/v1/generate", "not json"),
            http_request(http_addr, "POST", "/v1/generate", r#"{"prompt": "x"}"#),
            http_request(
                http_addr,
                "POST",
                "/v1/generate",
                r#"{"prompt": "x", "max_new": 2, "priority": "urgent"}"#,
            ),
            http_request(http_addr, "POST", "/v1/score", r#"{"lines": []}"#),
        ];
        cases
    });
    serve::serve_fronts(
        vec![http::HttpConn::front_end(http_l, Some(6))],
        &mut be,
        BatcherConfig::default(),
    )
    .unwrap();
    let cases = client.join().unwrap();
    let want = [404, 405, 400, 400, 400, 400];
    for ((status, body), want) in cases.iter().zip(want) {
        assert_eq!(*status, want, "body: {body}");
        let j = Json::parse(body).expect("error responses are JSON");
        assert!(j.get("error").is_some(), "no error field in {body}");
    }
}
