//! Chaos/soak harness for the serving stack: a deterministic-seed client
//! fleet throws mixed traffic at a live server — TCP and HTTP generation
//! at both priorities, scoring, speculative lanes, random disconnects,
//! slow readers, malformed frames, bad verbs, oversized bodies — and the
//! serving metrics (`GET /v1/metrics`) are the witness that nothing
//! leaked or wedged:
//!
//! * every admitted request terminates: `started − finished == 0` at
//!   drain, with the per-outcome split obeying the structural identities
//!   (`abandoned == client_gone evictions`, `error == kv_exhausted +
//!   decode_error evictions`);
//! * no KV block leaks: the pool reports `free == total` after drain and
//!   the `hbllm_kv_blocks_used` gauge reads 0;
//! * the batch tier is admitted under interactive load (batch anchors
//!   complete with `done`);
//! * histogram totals are consistent with the counters (`tokens ==
//!   ttft.count + inter_token.count`) and the exposition itself is
//!   well-formed (cumulative buckets, `+Inf` terminal, `_count`
//!   agreement);
//! * every SSE stream that ends any way but a delivered `done` is
//!   counted by `hbllm_http_streams_aborted_total` — exactly the planned
//!   disconnects, nothing else;
//! * `/v1/stats` totals and the Prometheus text agree at drain.
//!
//! The fleet is planned up front from a fixed [`Pcg32`] seed so the
//! connection budgets handed to `serve_fronts` are exact and the run is
//! reproducible. `chaos_soak_long` is the same fleet at soak scale,
//! `#[ignore]`d for tier-1 (run with `cargo test -- --ignored`).
//!
//! `trace_wave_meets_slos_and_exports_ordered_timelines` drives a
//! deterministic sequential wave against a `--trace`-enabled server and
//! is the latency regression gate: it checks the [`SloSpec`] bounds
//! through [`Histogram::quantile`] (scaled by `HBLLM_SLO_SCALE` for slow
//! runners) and verifies `GET /v1/trace` returns well-formed,
//! correctly-ordered span timelines — the structural invariants are
//! asserted unscaled.

use hbllm::coordinator::{http, serve, BatcherConfig, RouterConfig, SloSpec};
use hbllm::engine::{Backend, NativeBackend, PackedModel, SpecConfig};
use hbllm::model::testing::micro_weights;
use hbllm::util::json::Json;
use hbllm::util::rng::Pcg32;
use std::collections::{BTreeMap, BTreeSet};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

fn packed_micro(seed: u64) -> NativeBackend {
    let w = micro_weights(seed);
    NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1)
}

/// Small jitter (derived from the plan seed) so client threads interleave
/// differently across actions while the *plan* stays deterministic.
fn jitter(rng: &mut Pcg32) -> Duration {
    Duration::from_millis(rng.next_u64() % 25)
}

fn words(rng: &mut Pcg32) -> String {
    const W: [&str; 8] = ["ta", "kivo", "remo", "so", "lute", "pamo", "ne", "du"];
    let n = 2 + (rng.next_u64() % 3) as usize;
    (0..n).map(|_| W[(rng.next_u64() % W.len() as u64) as usize]).collect::<Vec<_>>().join(" ")
}

// ---------------------------------------------------------------------------
// Wire helpers
// ---------------------------------------------------------------------------

/// Read one `Content-Length`-framed HTTP response off `reader` (leaves the
/// connection usable for keep-alive).
fn read_framed(reader: &mut BufReader<TcpStream>) -> (u16, String) {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("bad status line {line:?}"))
        .parse()
        .unwrap();
    let mut clen = 0usize;
    loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let t = line.trim_end();
        if t.is_empty() {
            break;
        }
        let low = t.to_ascii_lowercase();
        if let Some(v) = low.strip_prefix("content-length:") {
            clen = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; clen];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

/// One raw HTTP exchange on its own connection, reading the response by
/// its framing (NOT to EOF — the malformed/oversized paths leave the
/// server draining our unsent bytes, so reading to EOF would deadlock).
fn raw_request(addr: SocketAddr, raw: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    read_framed(&mut reader)
}

/// A well-formed request built from parts (JSON in, framed response out).
fn http_request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    raw_request(
        addr,
        &format!(
            "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// Parse an SSE body into (event, data) pairs.
fn parse_events(body: &str) -> Vec<(String, String)> {
    let mut events = Vec::new();
    let mut ev = String::new();
    for line in body.lines() {
        if let Some(e) = line.strip_prefix("event: ") {
            ev = e.to_string();
        } else if let Some(d) = line.strip_prefix("data: ") {
            events.push((ev.clone(), d.to_string()));
        }
    }
    events
}

/// Read a full SSE stream (server closes the connection after the
/// terminal frame, so EOF is the delimiter here) and return the raw SSE
/// body, optionally sleeping between lines to emulate a slow reader.
/// The raw form keeps the `id:` lines that [`parse_events`] skips.
fn read_sse_raw(addr: SocketAddr, body: &str, per_line_delay: Duration) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    let req = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream);
    let mut text = String::new();
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line).unwrap() == 0 {
            break;
        }
        text.push_str(&line);
        if !per_line_delay.is_zero() {
            std::thread::sleep(per_line_delay);
        }
    }
    let (head, sse) = text.split_once("\r\n\r\n").expect("no header/body separator");
    assert!(head.starts_with("HTTP/1.1 200"), "generate refused: {head}");
    sse.to_string()
}

/// [`read_sse_raw`] parsed into (event, data) pairs.
fn read_sse(addr: SocketAddr, body: &str, per_line_delay: Duration) -> Vec<(String, String)> {
    parse_events(&read_sse_raw(addr, body, per_line_delay))
}

/// Drive one TCP line-protocol exchange and collect the generation
/// stream; `read_limit` caps how many lines are read before the client
/// hangs up mid-stream (None = read to the terminator).
fn tcp_gen(
    addr: SocketAddr,
    line_out: &str,
    read_limit: Option<usize>,
    per_line_delay: Duration,
) -> Option<usize> {
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut stream = stream;
    stream.write_all(line_out.as_bytes()).unwrap();
    let mut line = String::new();
    let mut read = 0usize;
    loop {
        if let Some(limit) = read_limit {
            if read >= limit {
                return None; // chaos: vanish mid-stream
            }
        }
        line.clear();
        if reader.read_line(&mut line).unwrap() == 0 {
            panic!("stream ended without a terminator");
        }
        read += 1;
        let t = line.trim_end();
        if let Some(n) = t.strip_prefix("done ") {
            return Some(n.parse().unwrap());
        }
        assert!(t.starts_with("tok "), "unexpected line {t:?}");
        if !per_line_delay.is_zero() {
            std::thread::sleep(per_line_delay);
        }
    }
}

/// One scoring/err line over TCP; returns the response line.
fn tcp_line(addr: SocketAddr, line_out: &str) -> String {
    let stream = TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut stream = stream;
    stream.write_all(line_out.as_bytes()).unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    line
}

// ---------------------------------------------------------------------------
// Exposition parsing + validation
// ---------------------------------------------------------------------------

/// Parse the exposition's sample lines into `full_key -> value`.
fn parse_metrics(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let (key, val) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line {line:?}"));
        let v: f64 = val.parse().unwrap_or_else(|_| panic!("bad value in {line:?}"));
        out.insert(key.to_string(), v);
    }
    out
}

fn metric(m: &BTreeMap<String, f64>, key: &str) -> f64 {
    *m.get(key).unwrap_or_else(|| panic!("metric {key:?} missing from exposition"))
}

/// Sum every series of `family` whose key contains all of `needles`.
fn metric_sum(m: &BTreeMap<String, f64>, family: &str, needles: &[&str]) -> f64 {
    m.iter()
        .filter(|(k, _)| {
            (k.as_str() == family || k.starts_with(&format!("{family}{{")))
                && needles.iter().all(|n| k.contains(n))
        })
        .map(|(_, v)| v)
        .sum()
}

/// Structural validity of the Prometheus text format: every family has
/// HELP+TYPE before its samples, histogram bucket runs are cumulative
/// (non-decreasing), terminate with `le="+Inf"`, and agree with their
/// `_count` line.
fn validate_exposition(text: &str) {
    let mut typed: BTreeMap<String, String> = BTreeMap::new();
    let mut bucket_run: Vec<f64> = Vec::new();
    let mut inf_total: Option<f64> = None;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let (name, kind) = rest.split_once(' ').expect("TYPE line");
            typed.insert(name.to_string(), kind.to_string());
            continue;
        }
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let (key, val) = line.rsplit_once(' ').expect("sample line");
        let v: f64 = val.parse().expect("sample value");
        let name = key.split('{').next().unwrap();
        let base = name
            .strip_suffix("_bucket")
            .or_else(|| name.strip_suffix("_sum"))
            .or_else(|| name.strip_suffix("_count"))
            .filter(|b| typed.get(*b).map(String::as_str) == Some("histogram"))
            .unwrap_or(name);
        assert!(typed.contains_key(base), "sample {key:?} precedes its # TYPE line");
        if name.ends_with("_bucket") && typed.get(base).map(String::as_str) == Some("histogram") {
            if let Some(last) = bucket_run.last() {
                assert!(v >= *last, "non-cumulative bucket run at {key:?}: {v} < {last}");
            }
            bucket_run.push(v);
            if key.contains("le=\"+Inf\"") {
                inf_total = Some(v);
                bucket_run.clear();
            }
        } else {
            assert!(
                bucket_run.is_empty(),
                "bucket run for {base} ended without le=\"+Inf\" (at {key:?})"
            );
            if name.ends_with("_count")
                && typed.get(base).map(String::as_str) == Some("histogram")
            {
                let inf = inf_total.take().unwrap_or_else(|| {
                    panic!("{key:?} has no preceding +Inf bucket")
                });
                assert_eq!(v, inf, "{key:?} disagrees with its +Inf bucket");
            }
        }
    }
    assert!(bucket_run.is_empty(), "exposition ended mid-bucket-run");
    assert!(!typed.is_empty(), "empty exposition");
}

// ---------------------------------------------------------------------------
// Supervisor: keep-alive polling for drain, then the final scrape
// ---------------------------------------------------------------------------

/// Poll `/v1/stats` on ONE keep-alive connection until the engine is
/// drained (`active == 0 && queued == 0 && started == finished ==
/// expected_started`), then poll `/v1/metrics` on the same connection
/// until the front-end connection gauges settle (tcp 0, http 1 — the
/// scraper itself). Returns the final (stats, metrics-text) pair, read
/// back to back so the two views describe the same quiescent state.
fn drain_and_scrape(addr: SocketAddr, expected_started: u64) -> (Json, String) {
    let stream = TcpStream::connect(addr).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    let mut get = |path: &str, reader: &mut BufReader<TcpStream>| {
        writer
            .write_all(format!("GET {path} HTTP/1.1\r\nHost: t\r\n\r\n").as_bytes())
            .unwrap();
        read_framed(reader)
    };
    let deadline = Instant::now() + Duration::from_secs(60);
    let stats = loop {
        let (status, body) = get("/v1/stats", &mut reader);
        assert_eq!(status, 200, "stats poll failed: {body}");
        let j = Json::parse(&body).unwrap();
        let active = j.get("active").and_then(Json::as_usize).unwrap();
        let queued = j.get("queued").and_then(Json::as_usize).unwrap();
        let started = j.at(&["totals", "requests_started"]).and_then(Json::as_f64).unwrap();
        let finished = j.at(&["totals", "requests_finished"]).and_then(Json::as_f64).unwrap();
        if active == 0 && queued == 0 && started == finished && started == expected_started as f64
        {
            break j;
        }
        assert!(
            Instant::now() < deadline,
            "engine failed to drain: active={active} queued={queued} started={started} finished={finished} (expected {expected_started})"
        );
        std::thread::sleep(Duration::from_millis(10));
    };
    // totals can no longer move (no live work, every client joined); wait
    // only for the session threads' connection guards to drop
    let text = loop {
        let (status, text) = get("/v1/metrics", &mut reader);
        assert_eq!(status, 200);
        let m = parse_metrics(&text);
        if metric(&m, "hbllm_connections_active{front=\"tcp\"}") == 0.0
            && metric(&m, "hbllm_connections_active{front=\"http\"}") == 1.0
        {
            break text;
        }
        assert!(Instant::now() < deadline, "connection gauges never settled:\n{text}");
        std::thread::sleep(Duration::from_millis(10));
    };
    (stats, text)
}

// ---------------------------------------------------------------------------
// The fleet
// ---------------------------------------------------------------------------

/// Per wave: 7 TCP connections, 13 HTTP connections, 9 admitted
/// generation requests (4 TCP + 5 HTTP), of which 2 are batch-tier
/// anchors and 1 is a zero-token request.
const TCP_CONNS_PER_WAVE: usize = 7;
const HTTP_CONNS_PER_WAVE: usize = 13;
const GENS_PER_WAVE: u64 = 9;
const ZERO_TOKEN_PER_WAVE: u64 = 1;
const BATCH_DONE_PER_WAVE: u64 = 2;
/// Tokens the guaranteed-completing anchors stream per wave:
/// TCP 5 + 4 + 6, HTTP 5 + 3 + 6 + 0.
const ANCHOR_TOKENS_PER_WAVE: u64 = 29;

fn spawn_wave(
    rng: &mut Pcg32,
    tcp_addr: SocketAddr,
    http_addr: SocketAddr,
    clients: &mut Vec<std::thread::JoinHandle<()>>,
) {
    let mut go = |d: Duration, f: Box<dyn FnOnce() + Send>| {
        clients.push(std::thread::spawn(move || {
            std::thread::sleep(d);
            f()
        }));
    };
    let (p1, p2, p3, p4) = (words(rng), words(rng), words(rng), words(rng));
    let (q1, q2, q3) = (words(rng), words(rng), words(rng));
    let sample_seed = rng.next_u64();

    // --- TCP fleet (7 connections) ---
    go(jitter(rng), Box::new(move || {
        let n = tcp_gen(tcp_addr, &format!("gen 5 0 0 {p1}\n"), None, Duration::ZERO);
        assert_eq!(n, Some(5), "interactive TCP anchor did not complete");
    }));
    go(jitter(rng), Box::new(move || {
        let n = tcp_gen(tcp_addr, &format!("prio batch gen 4 0 0 {p2}\n"), None, Duration::ZERO);
        assert_eq!(n, Some(4), "batch TCP anchor starved");
    }));
    go(jitter(rng), Box::new(move || {
        let resp = tcp_line(tcp_addr, &format!("ppl {q1}\n"));
        assert!(resp.starts_with("ppl "), "ppl verb broke: {resp:?}");
    }));
    go(jitter(rng), Box::new(move || {
        let resp = tcp_line(tcp_addr, &format!("{q2}\n"));
        assert!(resp.starts_with("ppl "), "legacy scoring broke: {resp:?}");
    }));
    go(jitter(rng), Box::new(move || {
        let resp = tcp_line(tcp_addr, "prio urgent gen 3 0 0 x\n");
        assert!(resp.starts_with("err usage: prio"), "bad verb accepted: {resp:?}");
    }));
    go(jitter(rng), Box::new(move || {
        // slow reader: the engine must not block on our read pace
        let n = tcp_gen(
            tcp_addr,
            &format!("gen 6 0 0 {p3}\n"),
            None,
            Duration::from_millis(3),
        );
        assert_eq!(n, Some(6), "slow TCP reader starved out");
    }));
    go(jitter(rng), Box::new(move || {
        // disconnect mid-stream: sampled long generation, read one line,
        // vanish — the engine must evict and free the lane
        tcp_gen(
            tcp_addr,
            &format!("gen 60 0.5 {sample_seed} {p4}\n"),
            Some(1),
            Duration::ZERO,
        );
    }));

    // --- HTTP fleet (12 connections) ---
    let (h1, h2, h3) = (words(rng), words(rng), words(rng));
    go(jitter(rng), Box::new(move || {
        let mut toks = 0usize;
        let n = http::client_generate(
            &format!("http://{http_addr}"),
            &h1,
            5,
            0.0,
            0,
            hbllm::coordinator::Priority::Interactive,
            |_| toks += 1,
        )
        .unwrap();
        assert_eq!((n, toks), (5, 5), "interactive HTTP anchor did not complete");
    }));
    go(jitter(rng), Box::new(move || {
        // read_sse (EOF-delimited), NOT read_framed: an SSE response has
        // no Content-Length, so framed reading would miss every event
        let events = read_sse(
            http_addr,
            &format!(r#"{{"prompt": "{h2}", "max_new": 3, "priority": "batch"}}"#),
            Duration::ZERO,
        );
        assert_eq!(
            events.last().map(|(e, d)| (e.as_str(), d.as_str())),
            Some(("done", "3")),
            "batch HTTP anchor starved: {events:?}"
        );
    }));
    go(jitter(rng), Box::new(move || {
        let (status, body) = http_request(
            http_addr,
            "POST",
            "/v1/score",
            &format!(r#"{{"texts": ["{h3}", "", "re mo"]}}"#),
        );
        assert_eq!(status, 200, "score failed: {body}");
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("results").and_then(Json::as_arr).map(Vec::len), Some(3));
    }));
    go(jitter(rng), Box::new(move || {
        let (status, body) = http_request(http_addr, "GET", "/v1/stats", "");
        assert_eq!(status, 200);
        assert!(Json::parse(&body).unwrap().get("lanes").is_some());
    }));
    go(jitter(rng), Box::new(move || {
        let (status, _) = http_request(http_addr, "POST", "/v1/generate", "not json");
        assert_eq!(status, 400);
    }));
    go(jitter(rng), Box::new(move || {
        let (status, _) = http_request(http_addr, "GET", "/v1/generate", "");
        assert_eq!(status, 405);
    }));
    go(jitter(rng), Box::new(move || {
        let (status, _) = http_request(http_addr, "GET", "/v1/nope", "");
        assert_eq!(status, 404);
    }));
    go(jitter(rng), Box::new(move || {
        // tracing is off on this server: the endpoint must say so (404)
        // rather than serve an empty recorder
        let (status, body) = http_request(http_addr, "GET", "/v1/trace", "");
        assert_eq!(status, 404, "trace must 404 when disabled: {body}");
    }));
    go(jitter(rng), Box::new(move || {
        // unusable framing: the server answers 400 and hangs up
        let (status, _) = raw_request(
            http_addr,
            "POST /v1/score HTTP/1.1\r\nHost: t\r\nContent-Length: xyz\r\n\r\n",
        );
        assert_eq!(status, 400);
    }));
    go(jitter(rng), Box::new(move || {
        // hostile Content-Length: 413 without sizing an allocation
        let (status, _) = raw_request(
            http_addr,
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: 9999999\r\n\r\n",
        );
        assert_eq!(status, 413);
    }));
    go(jitter(rng), Box::new(move || {
        // SSE disconnect: read the head plus a couple of frames, vanish
        let mut stream = TcpStream::connect(http_addr).unwrap();
        let body = r#"{"prompt": "ta ki", "max_new": 80}"#;
        let req = format!(
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        stream.write_all(req.as_bytes()).unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        for _ in 0..6 {
            line.clear();
            if reader.read_line(&mut line).unwrap() == 0 {
                break;
            }
        }
        // dropping the socket here is the chaos
    }));
    go(jitter(rng), Box::new(move || {
        let events = read_sse(
            http_addr,
            r#"{"prompt": "so lu", "max_new": 6}"#,
            Duration::from_millis(3),
        );
        assert_eq!(
            events.last().map(|(e, d)| (e.as_str(), d.as_str())),
            Some(("done", "6")),
            "slow SSE reader starved out: {events:?}"
        );
    }));
    go(jitter(rng), Box::new(move || {
        // zero-token request: terminal immediately, still one full
        // started/finished lifecycle in the metrics
        let events =
            read_sse(http_addr, r#"{"prompt": "zz", "max_new": 0}"#, Duration::ZERO);
        assert_eq!(
            events.last().map(|(e, d)| (e.as_str(), d.as_str())),
            Some(("done", "0")),
            "zero-token request misbehaved: {events:?}"
        );
    }));
}

/// Run `waves` of the chaos fleet against one server and verify every
/// invariant the module docs list. The arena is sized to the worst case,
/// so the only legal evictions are client-gone ones.
fn run_chaos_fleet(model_seed: u64, plan_seed: u64, waves: usize) {
    let mut be = packed_micro(model_seed);
    be.set_lanes(3);
    let block_len = 4usize;
    let blocks = 3 * hbllm::engine::paged::blocks_for(be.seq(), block_len);
    be.set_kv_blocks(Some(blocks), Some(block_len));
    let eff = be.set_spec(SpecConfig::with_k(2));
    assert!(eff.enabled, "native backend must accept the draft config");
    let (tcp_l, tcp_addr) = serve::bind("127.0.0.1:0").unwrap();
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    let mut rng = Pcg32::seeded(plan_seed);
    let mut clients = Vec::new();
    for _ in 0..waves {
        spawn_wave(&mut rng, tcp_addr, http_addr, &mut clients);
    }
    let w = waves as u64;
    let expected_started = GENS_PER_WAVE * w;
    let supervisor = std::thread::spawn(move || {
        for c in clients {
            c.join().expect("chaos client panicked");
        }
        drain_and_scrape(http_addr, expected_started)
    });

    serve::serve_fronts(
        vec![
            serve::FrontEnd::line(tcp_l, Some(TCP_CONNS_PER_WAVE * waves)),
            http::HttpConn::front_end(http_l, Some(HTTP_CONNS_PER_WAVE * waves + 1)),
        ],
        &mut be,
        BatcherConfig { spec: eff, ..Default::default() },
    )
    .unwrap();
    let (stats, text) = supervisor.join().unwrap();
    let m = parse_metrics(&text);
    validate_exposition(&text);

    // --- lifecycle: every admitted request terminates ---
    let started = metric_sum(&m, "hbllm_requests_started_total", &[]);
    let finished = metric_sum(&m, "hbllm_requests_finished_total", &[]);
    assert_eq!(started, expected_started as f64, "admission count drifted");
    assert_eq!(started, finished, "requests leaked: started {started} != finished {finished}");
    let done = metric_sum(&m, "hbllm_requests_finished_total", &["outcome=\"done\""]);
    let abandoned =
        metric_sum(&m, "hbllm_requests_finished_total", &["outcome=\"abandoned\""]);
    let errored = metric_sum(&m, "hbllm_requests_finished_total", &["outcome=\"error\""]);
    assert_eq!(done + abandoned + errored, started);
    // structural identities between outcomes and evictions
    assert_eq!(
        abandoned,
        metric(&m, "hbllm_evictions_total{cause=\"client_gone\"}"),
        "abandoned requests and client-gone evictions disagree"
    );
    assert_eq!(errored, 0.0, "worst-case arena must never exhaust: {errored} errors");
    assert_eq!(metric(&m, "hbllm_evictions_total{cause=\"kv_exhausted\"}"), 0.0);
    assert_eq!(metric(&m, "hbllm_evictions_total{cause=\"decode_error\"}"), 0.0);

    // --- the batch tier was admitted under interactive load ---
    assert_eq!(
        metric(&m, "hbllm_requests_finished_total{priority=\"batch\",outcome=\"done\"}"),
        (BATCH_DONE_PER_WAVE * w) as f64,
        "batch anchors starved"
    );

    // --- histogram/counter consistency ---
    let tokens = metric_sum(&m, "hbllm_tokens_total", &[]);
    let ttft = metric_sum(&m, "hbllm_ttft_us_count", &[]);
    let inter = metric_sum(&m, "hbllm_inter_token_us_count", &[]);
    assert_eq!(tokens, ttft + inter, "latency histograms lost tokens");
    assert!(tokens >= (ANCHOR_TOKENS_PER_WAVE * w) as f64, "anchors under-produced: {tokens}");
    // every admitted request but the zero-token ones crossed the queue
    assert_eq!(
        metric_sum(&m, "hbllm_queue_wait_us_count", &[]),
        (expected_started - ZERO_TOKEN_PER_WAVE * w) as f64,
    );
    assert!(metric_sum(&m, "hbllm_sweep_us_count", &[]) > 0.0, "no sweeps timed");

    // --- speculative lane saw greedy traffic ---
    assert!(metric(&m, "hbllm_spec_rounds_total") > 0.0, "spec lane never engaged");
    assert_eq!(
        metric(&m, "hbllm_spec_drafted_total"),
        metric(&m, "hbllm_spec_accepted_total") + metric(&m, "hbllm_spec_rejected_total"),
    );

    // --- front-end accounting: exact planned error counts ---
    assert_eq!(metric_sum(&m, "hbllm_http_requests_total", &["status=\"400\""]), (2 * w) as f64);
    // /v1/nope plus the trace-disabled probe
    assert_eq!(metric_sum(&m, "hbllm_http_requests_total", &["status=\"404\""]), (2 * w) as f64);
    assert_eq!(metric_sum(&m, "hbllm_http_requests_total", &["status=\"405\""]), w as f64);
    assert_eq!(metric_sum(&m, "hbllm_http_requests_total", &["status=\"413\""]), w as f64);
    assert_eq!(
        metric(
            &m,
            "hbllm_http_requests_total{method=\"POST\",path=\"/v1/generate\",status=\"200\"}"
        ),
        (5 * w) as f64,
    );
    assert_eq!(metric(&m, "hbllm_tcp_requests_total{verb=\"gen\"}"), (4 * w) as f64);
    assert_eq!(metric(&m, "hbllm_tcp_requests_total{verb=\"ppl\"}"), w as f64);
    assert_eq!(metric(&m, "hbllm_tcp_requests_total{verb=\"legacy\"}"), w as f64);
    assert_eq!(metric(&m, "hbllm_tcp_requests_total{verb=\"bad\"}"), w as f64);
    // exactly the planned SSE disconnect aborts its stream each wave;
    // every other HTTP stream verified `done` delivery client-side
    assert_eq!(
        metric(&m, "hbllm_http_streams_aborted_total"),
        w as f64,
        "aborted-stream accounting drifted"
    );

    // --- gauges at drain: nothing held, nothing leaked ---
    assert_eq!(metric(&m, "hbllm_active_lanes"), 0.0);
    assert_eq!(metric_sum(&m, "hbllm_queued_requests", &[]), 0.0);
    assert_eq!(metric(&m, "hbllm_kv_blocks_used"), 0.0, "KV blocks leaked");
    assert_eq!(metric(&m, "hbllm_kv_blocks_total"), blocks as f64);
    let hwm = metric(&m, "hbllm_kv_blocks_used_hwm");
    assert!(hwm >= 1.0 && hwm <= blocks as f64, "implausible KV high-water {hwm}");

    // --- /v1/stats and /v1/metrics agree on the same quiescent state ---
    let t = |k: &str| stats.at(&["totals", k]).and_then(Json::as_f64).unwrap();
    assert_eq!(t("requests_started"), started);
    assert_eq!(t("requests_finished"), finished);
    assert_eq!(t("tokens"), tokens);
    assert_eq!(t("evictions"), metric_sum(&m, "hbllm_evictions_total", &[]));
    assert!(stats.get("uptime_ms").and_then(Json::as_f64).unwrap() >= 0.0);

    // --- and the pool itself confirms the gauge ---
    let st = be.kv_stats().expect("metered backend");
    assert_eq!(st.free_blocks, st.total_blocks, "KvBlockPool leaked blocks");
    assert!(st.used_hwm >= 1, "no block was ever allocated?");
}

/// Tier-1 chaos smoke: one full wave of mixed adversarial traffic.
#[test]
fn chaos_fleet_drains_clean_and_metrics_agree() {
    run_chaos_fleet(91, 0x5eed_c4a0, 1);
}

/// The same fleet at soak scale. `#[ignore]`d for tier-1; CI runs it in
/// the scheduled soak job (`cargo test --release -- --ignored`).
#[test]
#[ignore = "soak scale; run explicitly or via the CI soak job"]
fn chaos_soak_long() {
    run_chaos_fleet(92, 0x5eed_50a1, 4);
}

/// The latency regression gate: a known deterministic wave — three
/// sequential interactive requests (3 tokens each) then one batch
/// request (2 tokens) — against a `--trace`-enabled server.
///
/// * at least two latency SLOs are asserted through
///   [`Histogram::quantile`] via [`SloSpec::check`] (interactive p99
///   TTFT, batch p99 queue-wait, interactive p99 inter-token), scaled by
///   `HBLLM_SLO_SCALE` so slow shared runners gate on proportionally
///   relaxed bounds;
/// * `GET /v1/trace` returns well-formed, correctly-ordered span
///   timelines for the wave — the structural invariants (span order,
///   monotone starts, first-token/ttft agreement, exemplar ordering) are
///   asserted UNSCALED: they must hold however slow the machine is;
/// * every SSE frame carries a monotonically numbered `id:` line.
#[test]
fn trace_wave_meets_slos_and_exports_ordered_timelines() {
    let mut be = packed_micro(95);
    be.set_lanes(2);
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    let n_gens = 4u64;
    let supervisor = std::thread::spawn(move || {
        // sequential clients: each waits for its `done` before the next
        // connects, so request ids, ring order, and span shapes are
        // fully deterministic (one active lane at a time)
        for i in 0..3 {
            let body = format!(r#"{{"prompt": "ta kivo t{i}", "max_new": 3}}"#);
            let sse = read_sse_raw(http_addr, &body, Duration::ZERO);
            let ids: Vec<u64> = sse
                .lines()
                .filter_map(|l| l.strip_prefix("id: "))
                .map(|v| v.parse().unwrap())
                .collect();
            assert_eq!(ids, vec![0, 1, 2, 3], "SSE ids must number frames from 0:\n{sse}");
            let events = parse_events(&sse);
            assert_eq!(
                events.last().map(|(e, d)| (e.as_str(), d.as_str())),
                Some(("done", "3")),
                "interactive request {i} failed: {events:?}"
            );
        }
        let events = read_sse(
            http_addr,
            r#"{"prompt": "so lu", "max_new": 2, "priority": "batch"}"#,
            Duration::ZERO,
        );
        assert_eq!(
            events.last().map(|(e, d)| (e.as_str(), d.as_str())),
            Some(("done", "2")),
            "batch request failed: {events:?}"
        );
        // drain first so every timeline is recorded before the scrape
        let (stats, text) = drain_and_scrape(http_addr, n_gens);
        let (status, trace_body) = http_request(http_addr, "GET", "/v1/trace", "");
        assert_eq!(status, 200, "trace endpoint refused: {trace_body}");
        let (status, chrome_body) =
            http_request(http_addr, "GET", "/v1/trace?format=chrome", "");
        assert_eq!(status, 200, "chrome export refused: {chrome_body}");
        (stats, text, trace_body, chrome_body)
    });

    let metrics = serve::serve_fronts(
        // 4 generations + the drain poller + the two trace scrapes
        vec![http::HttpConn::front_end(http_l, Some(n_gens as usize + 3))],
        &mut be,
        BatcherConfig { trace: 8, ..Default::default() },
    )
    .unwrap();
    let (stats, text, trace_body, chrome_body) = supervisor.join().unwrap();
    validate_exposition(&text);
    let m = parse_metrics(&text);
    assert_eq!(
        metric(&m, "hbllm_http_streams_aborted_total"),
        0.0,
        "no stream in this wave disconnects"
    );

    // --- SLO gates through Histogram::quantile (scaled for CI) ---
    let slo = SloSpec::interactive_first(2_000_000.0, 500_000.0).from_env();
    let violations = slo.check(&metrics);
    assert!(violations.is_empty(), "SLO violations: {violations:?}");
    let ttft = &metrics.tier(0).ttft_us;
    let (p50, p99) = (ttft.quantile(0.5).unwrap(), ttft.quantile(0.99).unwrap());
    assert!(p50 <= p99, "quantiles must be monotone in q: p50 {p50} > p99 {p99}");
    assert!(
        metrics.tier(1).queue_wait_us.quantile(0.99).is_some(),
        "the batch request must leave queue-wait mass to gate on"
    );
    // /v1/stats exposes the same quantiles for dashboards
    assert!(
        stats.at(&["latency", "interactive", "ttft_us", "p99"]).and_then(Json::as_f64).is_some(),
        "/v1/stats latency section missing: {stats:?}"
    );

    // --- /v1/trace: well-formed, correctly-ordered timelines ---
    let j = Json::parse(&trace_body).unwrap();
    let recent = j.get("recent").and_then(Json::as_arr).expect("recent array");
    assert_eq!(recent.len(), n_gens as usize, "the ring must hold the whole wave");
    let name = |s: &Json| s.get("name").and_then(Json::as_str).unwrap().to_string();
    for (i, tl) in recent.iter().enumerate() {
        // ids were minted in admission order and the ring is oldest-first
        assert_eq!(tl.get("id").and_then(Json::as_f64), Some((i + 1) as f64));
        let want_prio = if i < 3 { "interactive" } else { "batch" };
        assert_eq!(tl.get("priority").and_then(Json::as_str), Some(want_prio));
        assert_eq!(tl.get("outcome").and_then(Json::as_str), Some("done"));
        let spans = tl.get("spans").and_then(Json::as_arr).expect("spans array");
        // one active request at a time: admission, a prefill sweep that
        // yields the first token, one plain sweep per remaining token
        let want: &[&str] = if i < 3 {
            &["enqueue", "admit", "prefill", "first_token", "sweep", "sweep", "finish"]
        } else {
            &["enqueue", "admit", "prefill", "first_token", "sweep", "finish"]
        };
        let names: Vec<String> = spans.iter().map(&name).collect();
        assert_eq!(names, want, "timeline {i} span catalog drifted");
        let mut prev = 0.0;
        for s in spans {
            let start = s.get("start_us").and_then(Json::as_f64).expect("start_us");
            assert!(s.get("dur_us").and_then(Json::as_f64).is_some(), "dur_us missing");
            assert!(start >= prev, "span starts must be monotone: {start} < {prev}");
            prev = start;
        }
        // first_token span and ttft_us travel together
        assert!(
            tl.get("ttft_us").and_then(Json::as_f64).is_some(),
            "completed generation lost its ttft"
        );
    }

    // exemplars pin the slowest TTFTs, slowest first
    let ex = j.get("exemplars").and_then(Json::as_arr).expect("exemplars array");
    assert_eq!(ex.len(), n_gens as usize, "all four completions carry a ttft");
    let tt: Vec<f64> =
        ex.iter().map(|t| t.get("ttft_us").and_then(Json::as_f64).unwrap()).collect();
    assert!(tt.windows(2).all(|w| w[0] >= w[1]), "exemplars must be slowest-first: {tt:?}");

    // --- ?format=chrome: flat complete-event array, one tid per request ---
    let c = Json::parse(&chrome_body).unwrap();
    let events = c.as_arr().expect("chrome trace is a flat event array");
    assert!(!events.is_empty());
    for e in events.iter() {
        assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
        assert!(e.get("name").and_then(Json::as_str).is_some());
        assert!(e.get("ts").and_then(Json::as_f64).is_some());
        assert!(e.get("dur").and_then(Json::as_f64).is_some());
    }
    let tids: BTreeSet<u64> = events
        .iter()
        .map(|e| e.get("tid").and_then(Json::as_f64).unwrap() as u64)
        .collect();
    assert_eq!(tids, (1..=n_gens).collect::<BTreeSet<u64>>(), "one lane per request id");
}

/// Repeated-prefix client waves against a prefix-cache-enabled server:
/// one seeding request, then a wave of requests extending its prompt.
/// At drain the hit/miss counters must account for every admission
/// exactly (hits + misses == started), the only blocks still "used" are
/// the ones the prompt cache legitimately retains (2 shared prefix
/// blocks + one private tail per cached extension), and the shutdown
/// flush returns the pool to `free == total` — no leak, no stale
/// sharing.
#[test]
fn repeated_prefix_waves_drain_clean_with_consistent_hit_counters() {
    let mut be = packed_micro(94);
    be.set_lanes(2);
    let block_len = 4usize;
    let blocks = 2 * hbllm::engine::paged::blocks_for(be.seq(), block_len);
    be.set_kv_blocks(Some(blocks), Some(block_len));
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    // wave 1 (1 request) seeds the cache; wave 2 (3 requests) extends
    // the same 8-byte prompt, so every wave-2 admission is a hit
    let wave2: [(&str, usize); 3] =
        [("ta kivo r", 3), ("ta kivo re", 2), ("ta kivo rem", 1)];
    let n_gens = 1 + wave2.len() as u64;
    let supervisor = std::thread::spawn(move || {
        let events =
            read_sse(http_addr, r#"{"prompt": "ta kivo ", "max_new": 4}"#, Duration::ZERO);
        assert_eq!(
            events.last().map(|(e, d)| (e.as_str(), d.as_str())),
            Some(("done", "4")),
            "seed request failed: {events:?}"
        );
        let clients: Vec<_> = wave2
            .iter()
            .map(|&(prompt, max_new)| {
                std::thread::spawn(move || {
                    let body = format!(r#"{{"prompt": "{prompt}", "max_new": {max_new}}}"#);
                    let events = read_sse(http_addr, &body, Duration::ZERO);
                    let want = max_new.to_string();
                    assert_eq!(
                        events.last().map(|(e, d)| (e.as_str(), d.as_str())),
                        Some(("done", want.as_str())),
                        "prefix-extending request failed: {events:?}"
                    );
                })
            })
            .collect();
        for c in clients {
            c.join().expect("prefix client panicked");
        }
        drain_and_scrape(http_addr, n_gens)
    });

    serve::serve_fronts(
        vec![http::HttpConn::front_end(http_l, Some(n_gens as usize + 1))],
        &mut be,
        BatcherConfig { prefix_cache: 8, ..Default::default() },
    )
    .unwrap();
    let (stats, text) = supervisor.join().unwrap();
    let m = parse_metrics(&text);
    validate_exposition(&text);

    // every admission is exactly one hit or one miss; only the seeding
    // request (empty cache) can miss, every extension must hit
    let hits = metric(&m, "hbllm_prefix_cache_hits_total");
    let misses = metric(&m, "hbllm_prefix_cache_misses_total");
    assert_eq!((hits, misses), (wave2.len() as f64, 1.0), "hit/miss split drifted");
    assert_eq!(
        hits + misses,
        metric_sum(&m, "hbllm_requests_started_total", &[]),
        "admissions escaped the hit/miss accounting"
    );
    let t = |k: &str| stats.at(&["totals", k]).and_then(Json::as_f64).unwrap();
    assert_eq!(t("prefix_cache_hits"), hits, "/v1/stats disagrees with the exposition");
    assert_eq!(t("prefix_cache_misses"), misses);

    // at drain the only resident blocks are the cache's: the 2-block
    // shared prefix plus one private tail per cached extension (lanes
    // themselves hold nothing)
    assert_eq!(metric(&m, "hbllm_kv_blocks_used"), (2 + wave2.len()) as f64);
    assert_eq!(metric(&m, "hbllm_shared_blocks"), 2.0, "shared-prefix refcounts drifted");
    assert_eq!(
        stats.at(&["kv", "shared_blocks"]).and_then(Json::as_f64),
        Some(2.0),
        "/v1/stats kv.shared_blocks disagrees"
    );
    assert!(
        stats.at(&["kv", "shared_hwm"]).and_then(Json::as_f64).unwrap() >= 2.0,
        "shared high-water mark never rose"
    );

    // the shutdown flush returned every cache-held block to the pool
    let st = be.kv_stats().expect("metered backend");
    assert_eq!(st.free_blocks, st.total_blocks, "prefix cache leaked blocks at shutdown");
    assert_eq!(st.shared_blocks, 0, "stale shared refcounts after flush");
}

/// An arena too small for any single request: every generation is
/// admitted, stalls or decodes briefly, and terminates as `done` or
/// `err kv exhausted` — never hangs, never leaks a block, and the
/// eviction/outcome identities hold at drain.
#[test]
fn undersized_kv_arena_leaks_no_blocks() {
    let mut be = packed_micro(93);
    be.set_lanes(2);
    be.set_kv_blocks(Some(2), Some(4)); // every request below needs 3
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    let n_gens = 4u64;
    let mut clients = Vec::new();
    for i in 0..n_gens {
        clients.push(std::thread::spawn(move || {
            let events = read_sse(
                http_addr,
                &format!(r#"{{"prompt": "abcd", "max_new": 6, "seed": {i}}}"#),
                Duration::ZERO,
            );
            match events.last().map(|(e, d)| (e.as_str(), d.as_str())) {
                Some(("done", _)) | Some(("error", "kv exhausted")) => {}
                other => panic!("request {i} ended badly: {other:?} ({events:?})"),
            }
        }));
    }
    let supervisor = std::thread::spawn(move || {
        for c in clients {
            c.join().expect("chaos client panicked");
        }
        drain_and_scrape(http_addr, n_gens)
    });

    serve::serve_fronts(
        vec![http::HttpConn::front_end(http_l, Some(n_gens as usize + 1))],
        &mut be,
        BatcherConfig::default(),
    )
    .unwrap();
    let (_, text) = supervisor.join().unwrap();
    let m = parse_metrics(&text);
    validate_exposition(&text);

    assert_eq!(metric_sum(&m, "hbllm_requests_started_total", &[]), n_gens as f64);
    assert_eq!(
        metric_sum(&m, "hbllm_requests_started_total", &[]),
        metric_sum(&m, "hbllm_requests_finished_total", &[]),
        "a starved request never terminated"
    );
    assert_eq!(
        metric_sum(&m, "hbllm_requests_finished_total", &["outcome=\"error\""]),
        metric(&m, "hbllm_evictions_total{cause=\"kv_exhausted\"}"),
        "every error must be a kv eviction here"
    );
    assert_eq!(metric(&m, "hbllm_kv_blocks_used"), 0.0, "KV blocks leaked");
    let hwm = metric(&m, "hbllm_kv_blocks_used_hwm");
    assert!(hwm <= 2.0, "high-water {hwm} exceeds the 2-block arena");
    let st = be.kv_stats().expect("metered backend");
    assert_eq!(st.free_blocks, st.total_blocks, "KvBlockPool leaked blocks");
}

// ---------------------------------------------------------------------------
// Router chaos: replica death + replacement under a live wave
// ---------------------------------------------------------------------------

mod router_util;

/// Re-exec entry point for the worker processes the router wave spawns
/// (see `tests/router_util`); a no-op under a normal test run.
#[test]
fn worker_process_entry() {
    router_util::worker_entry_if_requested();
}

/// One routed TCP generation that tolerates the documented failure mode:
/// `Ok(tokens)` for a clean finish, `Err(line)` carrying the terminal
/// error line otherwise (callers pin it to `err aborted`).
fn routed_gen(addr: SocketAddr, line_out: &str) -> Result<usize, String> {
    let t = router_util::tcp_transcript(addr, line_out);
    let last = t.lines().last().unwrap_or("").to_string();
    match last.strip_prefix("done ") {
        Some(n) => Ok(n.parse().unwrap()),
        None => Err(last),
    }
}

/// Chaos for the router tier, against real worker processes: a mixed
/// TCP + SSE wave is in flight when one replica is SIGKILLed, and a
/// replacement is enrolled through `POST /v1/workers` afterwards.
/// Conservation laws, not schedules: every client observes exactly one
/// terminal and the only failure any client may see is the documented
/// retryable `aborted`; the replacement really takes sticky traffic;
/// surviving workers end balanced (`started == finished`) and hand back
/// their whole KV arena; the router's exposition agrees with its fleet
/// stats once the connection gauges quiesce.
#[test]
fn router_chaos_replica_death_and_replacement_conserve_requests() {
    let envs = [("HBLLM_TEST_WORKER_SEED", "63")];
    let mut victim = router_util::spawn_worker(&envs);
    let w1 = router_util::spawn_worker(&envs);
    let victim_addr = victim.addr();
    let cfg = RouterConfig::default();
    let (rt_tcp, rt_http) =
        router_util::start_router(vec![victim_addr.clone(), w1.addr()], cfg);
    router_util::wait_for_stats(rt_http, Duration::from_secs(5), |j| {
        j.get("healthy") == Some(&Json::Num(2.0))
    });
    let fleet = [victim_addr.clone(), w1.addr()];
    let to_victim = router_util::find_sticky_prompt(&fleet, 0, cfg.sticky_prefix);
    let to_survivor = router_util::find_sticky_prompt(&fleet, 1, cfg.sticky_prefix);

    // wave 1: sticky traffic to both replicas on both fronts, plus one
    // client that vanishes mid-stream, while the victim dies under it
    let mut tcp_clients = Vec::new();
    for i in 0..8usize {
        let prompt = if i % 2 == 0 { to_victim.clone() } else { to_survivor.clone() };
        tcp_clients
            .push(std::thread::spawn(move || routed_gen(rt_tcp, &format!("gen 3 0 0 {prompt}\n"))));
    }
    let mut sse_clients = Vec::new();
    for i in 0..4usize {
        let prompt = if i % 2 == 0 { to_victim.clone() } else { to_survivor.clone() };
        sse_clients.push(std::thread::spawn(move || {
            read_sse(rt_http, &format!(r#"{{"prompt": "{prompt}", "max_new": 3}}"#), Duration::ZERO)
        }));
    }
    let vanish_prompt = to_victim.clone();
    let vanisher = std::thread::spawn(move || {
        tcp_gen(rt_tcp, &format!("gen 3 0 0 {vanish_prompt}\n"), Some(1), Duration::ZERO)
    });
    std::thread::sleep(Duration::from_millis(4));
    victim.kill(); // SIGKILL, somewhere inside the wave

    let (mut done, mut aborted) = (0u64, 0u64);
    for c in tcp_clients {
        match c.join().expect("tcp client panicked") {
            Ok(n) => {
                assert_eq!(n, 3);
                done += 1;
            }
            Err(term) => {
                assert_eq!(term, "err aborted", "undocumented TCP failure leaked to a client");
                aborted += 1;
            }
        }
    }
    for c in sse_clients {
        let events = c.join().expect("sse client panicked");
        match events.last().map(|(e, d)| (e.as_str(), d.as_str())) {
            Some(("done", "3")) => done += 1,
            Some(("error", "aborted")) => aborted += 1,
            other => panic!("undocumented SSE terminal {other:?} ({events:?})"),
        }
    }
    assert!(vanisher.join().expect("vanisher panicked").is_none());
    assert_eq!(done + aborted, 12, "a client lost its terminal");

    // the fleet heals: the replacement enrolls through the management
    // endpoint, is immediately placeable, and takes its sticky traffic
    let w2 = router_util::spawn_worker(&envs);
    let (st, body) =
        http_request(rt_http, "POST", "/v1/workers", &format!(r#"{{"add": "{}"}}"#, w2.addr()));
    assert_eq!(st, 200, "worker enrollment failed: {body}");
    assert_eq!(
        Json::parse(&body).unwrap().get("healthy"),
        Some(&Json::Num(2.0)),
        "fleet after enrollment should be the survivor + the replacement: {body}"
    );
    let healthy = [w1.addr(), w2.addr()];
    let to_new = router_util::find_sticky_prompt(&healthy, 1, cfg.sticky_prefix);
    for i in 0..4usize {
        let r = routed_gen(rt_tcp, &format!("gen 3 0 0 {to_new}\n"));
        assert_eq!(r, Ok(3), "post-heal request {i} failed");
    }
    let started = |j: &Json| j.at(&["totals", "requests_started"]).and_then(Json::as_f64).unwrap();
    assert_eq!(
        started(&router_util::stats(w2.http)),
        4.0,
        "sticky wave 2 missed the replacement worker"
    );

    // router accounting: gauges quiesce (the scrape itself is the one
    // live HTTP connection), the dead replica is down, the exposition
    // and the fleet stats tell the same story
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        let (st, text) = http_request(rt_http, "GET", "/v1/metrics", "");
        assert_eq!(st, 200);
        let m = parse_metrics(&text);
        if metric(&m, "hbllm_router_connections_active{front=\"tcp\"}") == 0.0
            && metric(&m, "hbllm_router_connections_active{front=\"http\"}") == 1.0
        {
            assert_eq!(
                metric(&m, &format!("hbllm_router_worker_up{{worker=\"{victim_addr}\"}}")),
                0.0
            );
            assert_eq!(
                metric(&m, &format!("hbllm_router_worker_up{{worker=\"{}\"}}", w1.addr())),
                1.0
            );
            assert_eq!(
                metric(&m, &format!("hbllm_router_worker_up{{worker=\"{}\"}}", w2.addr())),
                1.0
            );
            // requests: 8 + 1 vanisher + 4 post-heal on TCP, 4 SSE
            assert_eq!(metric(&m, "hbllm_router_requests_total{front=\"tcp\"}"), 13.0);
            assert_eq!(metric(&m, "hbllm_router_requests_total{front=\"http\"}"), 4.0);
            // a replay is invisible to its client, so retries can never
            // exceed the requests that were in flight around the kill
            let retries = metric(&m, "hbllm_router_retries_total");
            assert!(retries <= 13.0, "retry storm: {retries}");
            let j = router_util::stats(rt_http);
            assert_eq!(j.get("retries"), Some(&Json::Num(retries)));
            assert_eq!(j.get("healthy"), Some(&Json::Num(2.0)));
            break;
        }
        assert!(Instant::now() < deadline, "router connection gauges never quiesced");
        std::thread::sleep(Duration::from_millis(20));
    }

    // conservation at drain: each surviving worker balanced, whole
    // arena back (the vanished client's generation still finishes
    // server-side, so started == finished must converge on its own)
    for w in [w1, w2] {
        let addr = w.http;
        router_util::wait_for_stats(addr, Duration::from_secs(5), |j| {
            let t = |k: &str| j.at(&["totals", k]).and_then(Json::as_f64).unwrap_or(-1.0);
            t("requests_started") >= 0.0 && t("requests_started") == t("requests_finished")
        });
        router_util::assert_clean_drain(w);
    }
}
