//! Cross-layer integration tests: Rust substrate vs the AOT HLO artifacts
//! through PJRT. These require `make artifacts` to have run; they skip
//! gracefully (with a loud marker) if artifacts are missing.

use hbllm::coordinator::{serve, BatcherConfig, QuantJobConfig};
use hbllm::data::Corpus;
use hbllm::engine::BackendKind;
use hbllm::model::{forward, nll_from_logits};
use hbllm::pipeline::{EvalScope, Session};
use hbllm::quant;
use hbllm::runtime::Runtime;
use hbllm::tensor::Matrix;
use hbllm::util::rng::Pcg32;
use std::path::PathBuf;

const XLA: BackendKind = BackendKind::Xla { pallas: false };

fn artifacts_root() -> Option<PathBuf> {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if root.join("manifest.json").exists() {
        Some(root)
    } else {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        None
    }
}

#[test]
fn haar_hlo_matches_rust() {
    let Some(root) = artifacts_root() else { return };
    let rt = Runtime::new(&root).unwrap();
    let exe = rt.load("hlo/haar_fwd.hlo.txt").unwrap();
    let mut rng = Pcg32::seeded(1);
    let w = Matrix::from_fn(256, 512, |_, _| rng.normal_f32());
    let lit = xla::Literal::vec1(&w.data).reshape(&[256, 512]).unwrap();
    let out = exe.run(&[lit]).unwrap();
    let got = out[0].to_vec::<f32>().unwrap();
    let want = hbllm::haar::fwd_rows(&w);
    let max_diff = got
        .iter()
        .zip(want.data.iter())
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max);
    assert!(max_diff < 1e-5, "haar kernel disagrees with rust: {max_diff}");
}

#[test]
fn binary_gemm_hlo_matches_rust_dequant() {
    let Some(root) = artifacts_root() else { return };
    let rt = Runtime::new(&root).unwrap();
    let exe = rt.load("hlo/binary_gemm.hlo.txt").unwrap();
    let (n, m, b) = (512usize, 512usize, 8usize);
    let mut rng = Pcg32::seeded(2);
    let signs = Matrix::from_fn(n, m, |_, _| if rng.f32() < 0.5 { -1.0 } else { 1.0 });
    let alpha = Matrix::from_fn(n, 2, |_, _| rng.f32() + 0.1);
    let mu = Matrix::from_fn(n, 2, |_, _| 0.1 * rng.normal_f32());
    let x = Matrix::from_fn(m, b, |_, _| rng.normal_f32());
    let args = [
        xla::Literal::vec1(&signs.data).reshape(&[n as i64, m as i64]).unwrap(),
        xla::Literal::vec1(&alpha.data).reshape(&[n as i64, 2]).unwrap(),
        xla::Literal::vec1(&mu.data).reshape(&[n as i64, 2]).unwrap(),
        xla::Literal::vec1(&x.data).reshape(&[m as i64, b as i64]).unwrap(),
    ];
    let out = exe.run(&args).unwrap();
    let got = out[0].to_vec::<f32>().unwrap();
    // rust reference: reconstruct coeffs, inverse haar, matmul
    let h = m / 2;
    let coeff = Matrix::from_fn(n, m, |i, j| {
        let band = if j < h { 0 } else { 1 };
        alpha.get(i, band) * signs.get(i, j) + mu.get(i, band)
    });
    let w = hbllm::haar::inv_rows(&coeff);
    let want = w.matmul(&x);
    let mut max_rel = 0f64;
    for (g, w) in got.iter().zip(want.data.iter()) {
        let rel = ((g - w).abs() / (1.0 + w.abs())) as f64;
        max_rel = max_rel.max(rel);
    }
    assert!(max_rel < 1e-4, "binary_gemm kernel mismatch: {max_rel}");
}

#[test]
fn rust_forward_matches_hlo_nll() {
    let Some(root) = artifacts_root() else { return };
    let session = Session::open(&root).unwrap();
    let weights = session.fp_weights();
    let seq = weights.config.seq_len;
    let corpus = Corpus::load(&root.join("data/c4s.bin")).unwrap();
    let window = &corpus.data[..seq];

    // PJRT path
    let runner = session.runner(weights, false).unwrap();
    let mut tokens = vec![0i32; runner.batch * seq];
    for (c, &b) in window.iter().enumerate() {
        tokens[c] = b as i32;
    }
    for r in 1..runner.batch {
        for c in 0..seq {
            tokens[r * seq + c] = tokens[c];
        }
    }
    let nll_hlo = runner.nll(&tokens).unwrap();

    // pure-Rust path
    let logits = forward(weights, window, None);
    let nll_rust = nll_from_logits(&logits, window);

    let per_row = seq - 1;
    let mut max_diff = 0f32;
    for t in 0..per_row {
        max_diff = max_diff.max((nll_hlo[t] - nll_rust[t]).abs());
    }
    assert!(
        max_diff < 2e-2,
        "rust forward and HLO disagree: max |Δnll| = {max_diff}"
    );
    // and the pallas-attention entry must agree with the jnp entry
    let runner_pallas = session.runner(weights, true).unwrap();
    let nll_pallas = runner_pallas.nll(&tokens).unwrap();
    let mut max_diff2 = 0f32;
    for t in 0..per_row {
        max_diff2 = max_diff2.max((nll_hlo[t] - nll_pallas[t]).abs());
    }
    assert!(max_diff2 < 1e-3, "pallas vs jnp entry mismatch: {max_diff2}");
}

#[test]
fn quantized_model_still_models_language() {
    let Some(root) = artifacts_root() else { return };
    let mut session = Session::open(&root).unwrap();
    let scope = EvalScope { ppl_windows: 8, qa_items: 4, calib_windows: 4 };
    let mut fp_be = session.backend(session.fp_weights(), XLA).unwrap();
    let corpus = session.corpus("wiki2s").unwrap();
    let fp_ppl = hbllm::eval::perplexity(fp_be.as_mut(), &corpus, scope.ppl_windows).unwrap();

    let q = quant::by_name("hbllm-row").unwrap();
    let (qw, results) = session
        .quantize(q.as_ref(), &scope, &QuantJobConfig { workers: 4, quiet: true })
        .unwrap();
    assert_eq!(results.len(), qw.config.linear_names().len());
    let mut q_be = session.backend(&qw, XLA).unwrap();
    let q_ppl = hbllm::eval::perplexity(q_be.as_mut(), &corpus, scope.ppl_windows).unwrap();

    assert!(fp_ppl > 1.0 && fp_ppl < 15.0, "fp ppl insane: {fp_ppl}");
    assert!(q_ppl >= fp_ppl * 0.99, "quantized better than fp?! {q_ppl} vs {fp_ppl}");
    assert!(
        q_ppl < fp_ppl * 10.0,
        "hbllm-row collapsed: {q_ppl} vs fp {fp_ppl}"
    );
}

#[test]
fn serve_roundtrip() {
    let Some(root) = artifacts_root() else { return };
    let session = Session::open(&root).unwrap();
    let mut backend = session.backend(session.fp_weights(), XLA).unwrap();
    let (listener, addr) = serve::bind("127.0.0.1:0").unwrap();
    let client = std::thread::spawn(move || {
        use std::io::{BufRead, BufReader, Write};
        let mut s = std::net::TcpStream::connect(addr).unwrap();
        s.write_all(b"ta kivo remo so ta lute pamo.\n").unwrap();
        let mut line = String::new();
        BufReader::new(s.try_clone().unwrap()).read_line(&mut line).unwrap();
        line
    });
    serve::serve_on(listener, backend.as_mut(), BatcherConfig::default(), Some(1)).unwrap();
    let line = client.join().unwrap();
    assert!(line.starts_with("ppl "), "bad response: {line}");
    let v: f64 = line[4..].trim().parse().unwrap();
    assert!(v > 1.0 && v < 1000.0, "ppl out of range: {v}");
}
