//! Backend parity for the native packed-weight engine (no artifacts
//! needed): the engine executing the 1-bit Haar-packed form must agree
//! with the dequantized dense reference forward, and its KV-cached
//! incremental decode must be indistinguishable from full re-forward.

use hbllm::calib;
use hbllm::coordinator::{quantize_model, QuantJobConfig};
use hbllm::engine::{self, Backend, NativeBackend, PackedModel};
use hbllm::model::testing::micro_weights;
use hbllm::model::{forward, nll_from_logits, Weights};
use hbllm::quant;
use hbllm::util::rng::Pcg32;

/// A small synthetic model, PTQ-quantized with hbllm-row (calibrated on a
/// couple of synthetic windows, as the scheduler tests do).
fn quantized_micro(seed: u64) -> Weights {
    let mut w = micro_weights(seed);
    let win: Vec<u8> = (0..w.config.seq_len as u8).map(|i| i.wrapping_mul(37)).collect();
    let win2: Vec<u8> = (0..w.config.seq_len as u8)
        .map(|i| i.wrapping_mul(11).wrapping_add(3))
        .collect();
    let ctxs = calib::collect(&w, &[&win, &win2]).contexts().unwrap();
    let q = quant::by_name("hbllm-row").unwrap();
    quantize_model(&mut w, &ctxs, q.as_ref(), &QuantJobConfig { workers: 2, quiet: true })
        .unwrap();
    w
}

#[test]
fn packed_engine_nll_matches_dequantized_reference() {
    let qw = quantized_micro(101);
    let seq = qw.config.seq_len;
    let packed = PackedModel::from_weights(&qw, true).unwrap();
    // ground truth: dense reconstruction of the packed layers through the
    // reference forward
    let reference = packed.to_weights();

    let mut be = NativeBackend::new(packed, 2);
    let windows: [Vec<u8>; 2] = [
        (0..seq as u8).map(|i| i.wrapping_mul(29).wrapping_add(7)).collect(),
        b"ta kivo remo so ta lute pamo kina vu. "
            .iter()
            .copied()
            .cycle()
            .take(seq)
            .collect(),
    ];
    let mut tokens: Vec<i32> = Vec::new();
    for win in &windows {
        tokens.extend(win.iter().map(|&b| b as i32));
    }
    let got = be.nll(&tokens).unwrap();
    assert_eq!(got.len(), 2 * (seq - 1));
    for (r, win) in windows.iter().enumerate() {
        let want = nll_from_logits(&forward(&reference, win, None), win);
        for (t, w_nll) in want.iter().enumerate() {
            let g = got[r * (seq - 1) + t];
            assert!(
                (g - w_nll).abs() < 1e-3,
                "row {r} pos {t}: engine {g} vs reference {w_nll}"
            );
        }
    }
}

#[test]
fn dense_engine_nll_matches_fp_reference() {
    // same check without packing: the engine forward itself (KV-cached,
    // position-at-a-time) against the batch reference forward
    let w = micro_weights(102);
    let seq = w.config.seq_len;
    let window: Vec<u8> = (0..seq as u8).map(|i| i.wrapping_mul(53).wrapping_add(1)).collect();
    let want = nll_from_logits(&forward(&w, &window, None), &window);

    let mut be = NativeBackend::new(PackedModel::from_weights(&w, false).unwrap(), 1);
    let tokens: Vec<i32> = window.iter().map(|&b| b as i32).collect();
    let got = be.nll(&tokens).unwrap();
    for (g, r) in got.iter().zip(&want) {
        assert!((g - r).abs() < 1e-4, "{g} vs {r}");
    }
}

#[test]
fn kv_cache_decode_is_byte_identical_to_full_reforward() {
    let qw = quantized_micro(103);
    let n_new = 2 * qw.config.seq_len; // long enough to slide past the window
    let prompt = b"ta kivo ";

    // incremental: one backend, cache reused across tokens
    let mut inc = NativeBackend::new(PackedModel::from_weights(&qw, true).unwrap(), 1);
    let mut rng = Pcg32::seeded(0);
    let a = engine::generate(&mut inc, prompt, n_new, 0.0, &mut rng).unwrap();

    // full re-forward: cache dropped before every token, so each step
    // recomputes the whole window from scratch
    let mut full = NativeBackend::new(PackedModel::from_weights(&qw, true).unwrap(), 1);
    let mut text = prompt.to_vec();
    for _ in 0..n_new {
        full.reset();
        let row = full.decode_step(&text).unwrap();
        text.push(engine::sample_logits(&row, 0.0, &mut rng) as u8);
    }

    assert_eq!(a, text, "incremental and full re-forward greedy outputs diverge");
}

#[test]
fn backend_generic_eval_agrees_across_engine_modes() {
    // perplexity through the Backend trait: packed engine vs its own
    // dequantized weights on the dense engine — the packing error is zero
    // by construction, so the numbers must match closely
    let qw = quantized_micro(104);
    let seq = qw.config.seq_len;
    let packed = PackedModel::from_weights(&qw, true).unwrap();
    let reference = packed.to_weights();
    let corpus = hbllm::data::Corpus {
        name: "synthetic".into(),
        data: (0..seq * 8).map(|i| (i % 89) as u8 + 33).collect(),
    };
    let mut p_be = NativeBackend::new(packed, 2);
    let mut d_be = NativeBackend::new(PackedModel::from_weights(&reference, false).unwrap(), 2);
    let p = hbllm::eval::perplexity(&mut p_be, &corpus, 4).unwrap();
    let d = hbllm::eval::perplexity(&mut d_be, &corpus, 4).unwrap();
    assert!(p.is_finite() && d.is_finite());
    assert!((p - d).abs() < 1e-3 * d, "packed {p} vs dense-reference {d}");
}
