//! Markdown link-and-anchor checker over `README.md` and `docs/*.md` —
//! the CI docs job runs it (std-only, no network): every relative link
//! must resolve to a file in the repository, and every `#anchor` —
//! same-file or cross-file — must match a heading's GitHub-style slug.
//! External (`http://`, `https://`, `mailto:`) targets are out of scope.

use std::collections::BTreeSet;
use std::fs;
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives in <repo>/rust")
        .to_path_buf()
}

/// The documents under check: the top-level README plus every `docs/*.md`.
fn doc_set(root: &Path) -> Vec<PathBuf> {
    let mut docs = vec![root.join("README.md")];
    let mut extra: Vec<PathBuf> = fs::read_dir(root.join("docs"))
        .expect("docs/ directory exists")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "md"))
        .collect();
    extra.sort();
    docs.extend(extra);
    docs
}

/// GitHub's heading→anchor slug: lowercase, punctuation dropped, spaces
/// become hyphens (underscores and hyphens survive).
fn slug(heading: &str) -> String {
    heading
        .trim()
        .chars()
        .filter_map(|c| {
            let c = c.to_ascii_lowercase();
            if c.is_alphanumeric() || c == '_' || c == '-' {
                Some(c)
            } else if c == ' ' {
                Some('-')
            } else {
                None
            }
        })
        .collect()
}

/// Anchor slugs of every ATX heading in a document (fenced code blocks
/// skipped — a bash comment is not a heading).
fn heading_slugs(text: &str) -> BTreeSet<String> {
    let mut out = BTreeSet::new();
    let mut in_fence = false;
    for line in text.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let trimmed = line.trim_start();
        let hashes = trimmed.chars().take_while(|&c| c == '#').count();
        if (1..=6).contains(&hashes) && trimmed.chars().nth(hashes) == Some(' ') {
            // strip inline-code backticks: GitHub slugs ignore them
            out.insert(slug(&trimmed[hashes + 1..].replace('`', "")));
        }
    }
    out
}

/// Inline-link targets (`[text](target)`) on one line.
fn links_in(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut i = 0;
    while let Some(p) = line[i..].find("](") {
        let start = i + p + 2;
        match line[start..].find(')') {
            Some(q) => {
                out.push(line[start..start + q].to_string());
                i = start + q + 1;
            }
            None => break,
        }
    }
    out
}

#[test]
fn markdown_links_and_anchors_resolve() {
    let root = repo_root();
    let mut failures: Vec<String> = Vec::new();
    let mut checked = 0usize;
    for doc in doc_set(&root) {
        let text = fs::read_to_string(&doc).unwrap();
        let dir = doc.parent().unwrap().to_path_buf();
        let rel = doc.strip_prefix(&root).unwrap_or(&doc).display().to_string();
        let mut in_fence = false;
        for (ln, line) in text.lines().enumerate() {
            if line.trim_start().starts_with("```") {
                in_fence = !in_fence;
                continue;
            }
            if in_fence {
                continue;
            }
            for target in links_in(line) {
                // drop an optional markdown link title after the path
                let target = target.split_whitespace().next().unwrap_or("").to_string();
                if target.is_empty()
                    || target.starts_with("http://")
                    || target.starts_with("https://")
                    || target.starts_with("mailto:")
                {
                    continue;
                }
                checked += 1;
                let (path_part, anchor) = match target.split_once('#') {
                    Some((p, a)) => (p, Some(a.to_string())),
                    None => (target.as_str(), None),
                };
                let file = if path_part.is_empty() { doc.clone() } else { dir.join(path_part) };
                if !file.is_file() {
                    failures.push(format!("{rel}:{}: broken link {target:?}", ln + 1));
                    continue;
                }
                if let Some(a) = anchor {
                    if file.extension().is_some_and(|x| x == "md") {
                        let slugs = heading_slugs(&fs::read_to_string(&file).unwrap());
                        if !slugs.contains(&a) {
                            failures.push(format!(
                                "{rel}:{}: anchor #{a} not found in {} (have: {})",
                                ln + 1,
                                path_part,
                                slugs.iter().cloned().collect::<Vec<_>>().join(", ")
                            ));
                        }
                    }
                }
            }
        }
    }
    assert!(failures.is_empty(), "broken documentation links:\n{}", failures.join("\n"));
    // the checker must actually be checking something — an empty doc set
    // or a broken extractor would otherwise pass vacuously
    assert!(checked >= 10, "only {checked} relative links found; extractor broken?");
}

/// The serving documentation suite exists and the README points into it.
#[test]
fn serving_docs_exist_and_are_linked() {
    let root = repo_root();
    for doc in ["docs/API.md", "docs/ARCHITECTURE.md", "docs/FORMAT.md", "docs/OBSERVABILITY.md"]
    {
        assert!(root.join(doc).is_file(), "{doc} missing");
    }
    let readme = fs::read_to_string(root.join("README.md")).unwrap();
    for target in ["docs/API.md", "docs/ARCHITECTURE.md", "docs/OBSERVABILITY.md"] {
        assert!(
            readme.contains(&format!("({target})")) || readme.contains(&format!("({target}#")),
            "README does not link {target}"
        );
    }
    // the API reference covers every serving surface the code exposes
    let api = fs::read_to_string(root.join("docs/API.md")).unwrap();
    for needle in [
        "POST /v1/generate",
        "POST /v1/score",
        "GET /v1/stats",
        "GET /v1/metrics",
        "event: tok",
        "prio <interactive|batch>",
        "kv exhausted",
        "X-Priority",
        "shared_blocks",
        "prefix_cache_hits",
        "prefix_cache_misses",
        "GET /v1/trace",
        "format=chrome",
        "\"latency\"",
        "id: 0",
        "POST /v1/drain",
        "\"draining\"",
        "ok draining",
        "no healthy workers",
        "drain is not routed; drain workers directly",
        "Router front-end",
        "POST /v1/workers",
        "\"retries\"",
    ] {
        assert!(api.contains(needle), "docs/API.md lost its {needle:?} coverage");
    }
    // the prefix-sharing lifecycle is documented where the code lives
    let arch = fs::read_to_string(root.join("docs/ARCHITECTURE.md")).unwrap();
    for needle in [
        "Prefix sharing",
        "copy-on-write",
        "kv_adopt_prefix",
        "prefix_parity",
        "Kernel dispatch",
        "HBLLM_KERNEL",
        "kernels_conformance",
        "bit-identity",
        "Router tier",
        "rendezvous",
        "sticky_prefix",
        "load_slack",
        "router_failover",
        "no healthy workers",
    ] {
        assert!(arch.contains(needle), "docs/ARCHITECTURE.md lost its {needle:?} coverage");
    }
    // the metric catalog covers the families the bundle registers
    let obs = fs::read_to_string(root.join("docs/OBSERVABILITY.md")).unwrap();
    for needle in [
        "GET /v1/metrics",
        "hbllm_requests_started_total",
        "hbllm_ttft_us",
        "hbllm_kv_blocks_used",
        "hbllm_shared_blocks",
        "hbllm_prefix_cache_hits_total",
        "hbllm_prefix_cache_misses_total",
        "hbllm_connections_active",
        "hbllm_kernel_info",
        "hbllm_http_streams_aborted_total",
        "chaos_soak",
        "/v1/trace",
        "quantile",
        "HBLLM_SLO_SCALE",
        "INTERACTIVE_BURST",
        "Perfetto",
        "hbllm_router_requests_total",
        "hbllm_router_retries_total",
        "hbllm_router_connections_active",
        "hbllm_router_worker_up",
        "router_chaos_replica_death_and_replacement_conserve_requests",
    ] {
        assert!(obs.contains(needle), "docs/OBSERVABILITY.md lost its {needle:?} coverage");
    }
    // the README advertises the multi-replica topology
    for needle in ["router --workers", "/v1/drain", "docs/ARCHITECTURE.md#router-tier"] {
        assert!(readme.contains(needle), "README.md lost its {needle:?} coverage");
    }
}

#[test]
fn slug_rules_match_github() {
    assert_eq!(slug("SSE event grammar"), "sse-event-grammar");
    assert_eq!(slug("POST /v1/generate"), "post-v1generate");
    assert_eq!(slug("Priorities"), "priorities");
    assert_eq!(slug("HTTP status codes"), "http-status-codes");
}
