//! Multi-process failover suite for the router tier (the tentpole's
//! pinning tests). Every worker here is a REAL process — spawned by
//! re-exec through `tests/router_util` — and the router runs over
//! localhost TCP exactly as `hbllm router --workers …` deploys it.
//!
//! What is pinned:
//!
//! * **Transparency** — the byte streams a client sees through the
//!   router (TCP line protocol and HTTP/SSE, greedy + speculative +
//!   sampled + scoring + error paths) are identical to a direct worker
//!   connection, `id:` lines included.
//! * **Failover** — `SIGKILL` under two mid-flight streams surfaces the
//!   documented retryable `aborted` on each (`docs/API.md` §Errors),
//!   while a queued request that had produced no output replays
//!   invisibly on a survivor (`hbllm_router_retries_total` counts it),
//!   and later traffic keeps flowing.
//! * **Stickiness** — requests sharing a prompt-prefix window land on
//!   the worker [`rendezvous_pick`] predicts, concentrating that
//!   worker's prefix-cache hits; the other replica sees nothing.
//! * **Graceful drain** — a drained worker finishes active lanes,
//!   returns every KV block, exits 0, and the router routes around it.
//! * **Stats coherence** — `/v1/stats` under concurrent polling never
//!   shows an incoherent snapshot, and flips to 503 once the engine is
//!   gone.
//!
//! Teardown invariant everywhere: every gracefully-stopped worker must
//! report `free == total` for its KV arena ([`assert_clean_drain`]).

mod router_util;

use hbllm::coordinator::{http, rendezvous_pick, serve, BatcherConfig, RouterConfig};
use hbllm::engine::{Backend, NativeBackend, PackedModel};
use hbllm::model::testing::synth_weights;
use hbllm::util::json::Json;
use router_util::*;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Re-exec entry point: a no-op under a normal test run, a full worker
/// process when the harness spawns us with `HBLLM_TEST_WORKER=1`.
#[test]
fn worker_process_entry() {
    router_util::worker_entry_if_requested();
}

// ---------------------------------------------------------------------------
// Transparency: the router is invisible in the byte stream
// ---------------------------------------------------------------------------

#[test]
fn router_front_is_byte_identical_to_a_direct_worker() {
    let envs = [("HBLLM_TEST_WORKER_SEED", "41"), ("HBLLM_TEST_WORKER_SPEC_K", "2")];
    let w0 = spawn_worker(&envs);
    let w1 = spawn_worker(&envs);
    let workers = vec![w0.addr(), w1.addr()];
    let (rt_tcp, rt_http) = start_router(workers, RouterConfig::default());
    wait_for_stats(rt_http, Duration::from_secs(5), |j| {
        j.get("healthy") == Some(&Json::Num(2.0))
    });

    // Both workers share the model seed, so whichever replica the router
    // places on, the bytes must match a direct w0 connection exactly.
    // (prompt + max_new always fit the micro model's 12-position window)
    let tcp_lines = [
        "gen 5 0 0 ta kivo",        // greedy → the speculative path
        "gen 4 0.8 12345 so lute",  // sampled with a pinned seed
        "gen 0 0 0 ne",             // zero-token fast path
        "prio batch gen 3 0 0 du pamo",
        "prio interactive gen 4 0 0 remo",
        "ppl ta kivo remo",         // scoring verb ({:.4} formatting)
        "so lute pamo",             // legacy bare line scoring
        "gen x",                    // usage error
        "prio urgent gen 3 0 0 ta", // bad priority level
    ];
    for req in tcp_lines {
        let line = format!("{req}\n");
        let direct = tcp_transcript(w0.tcp, &line);
        let routed = tcp_transcript(rt_tcp, &line);
        assert!(!direct.is_empty(), "direct worker went silent for {req:?}");
        assert_eq!(routed, direct, "TCP bytes diverged through the router for {req:?}");
    }

    // whole raw HTTP responses: status line, headers, SSE id: lines, all
    let http_bodies = [
        r#"{"prompt": "ta kivo", "max_new": 5}"#,
        r#"{"prompt": "so", "max_new": 4, "temperature": 0.9, "seed": 7}"#,
        "not json", // the worker's 400 relays verbatim
    ];
    for body in http_bodies {
        let direct = sse_transcript(w0.http, body);
        let routed = sse_transcript(rt_http, body);
        assert_eq!(routed, direct, "HTTP bytes diverged through the router for {body:?}");
    }

    // the greedy requests really exercised speculation on the worker
    let sj = stats(w0.http);
    assert_eq!(sj.at(&["spec", "enabled"]), Some(&Json::Bool(true)));
    assert!(
        sj.at(&["spec", "drafted"]).and_then(Json::as_f64).unwrap() >= 1.0,
        "speculative decoding never engaged"
    );

    // SSE ids through the router are contiguous from 0 (4 toks + done)
    let raw = sse_transcript(rt_http, r#"{"prompt": "ne du", "max_new": 4}"#);
    assert_eq!(sse_ids(&raw), vec![0, 1, 2, 3, 4], "router renumbered SSE ids:\n{raw}");

    assert_clean_drain(w0);
    assert_clean_drain(w1);
}

// ---------------------------------------------------------------------------
// Fleet endpoints + fail-fast with an empty fleet
// ---------------------------------------------------------------------------

#[test]
fn fleet_endpoints_account_the_fleet_and_requests_fail_fast_without_workers() {
    let w = spawn_worker(&[("HBLLM_TEST_WORKER_SEED", "77")]);
    let waddr = w.addr();
    let (rt_tcp, rt_http) = start_router(vec![waddr.clone()], RouterConfig::default());
    wait_for_stats(rt_http, Duration::from_secs(5), |j| {
        j.get("healthy") == Some(&Json::Num(1.0))
    });

    // drain is a per-worker lifecycle verb, never routed
    assert_eq!(
        tcp_transcript(rt_tcp, "drain\n"),
        "err drain is not routed; drain workers directly\n"
    );
    assert_eq!(
        stats(w.http).get("draining"),
        Some(&Json::Bool(false)),
        "the router's refusal must not have touched the worker"
    );

    // one request per front so the counters move
    let t = tcp_transcript(rt_tcp, "gen 2 0 0 ta\n");
    assert!(t.ends_with("done 2\n"), "TCP gen failed: {t:?}");
    let raw = sse_transcript(rt_http, r#"{"prompt": "so", "max_new": 2}"#);
    assert!(raw.starts_with("HTTP/1.1 200 OK\r\n"), "SSE gen failed:\n{raw}");
    assert_eq!(sse_ids(&raw), vec![0, 1, 2]);
    let (st, body) = http_request(rt_http, "POST", "/v1/score", r#"{"texts": ["ta kivo"]}"#);
    assert_eq!(st, 200, "routed scoring failed: {body}");
    assert!(Json::parse(&body).unwrap().get("results").is_some());

    // fleet stats and the router's own exposition agree
    let j = stats(rt_http);
    assert_eq!(j.get("healthy"), Some(&Json::Num(1.0)));
    assert_eq!(j.at(&["requests", "tcp"]), Some(&Json::Num(1.0)));
    assert_eq!(j.at(&["requests", "http"]), Some(&Json::Num(2.0)));
    assert_eq!(j.get("retries"), Some(&Json::Num(0.0)));
    let rows = j.get("workers").unwrap().as_arr().unwrap();
    assert_eq!(rows.len(), 1);
    assert_eq!(rows[0].get("worker").and_then(Json::as_str), Some(waddr.as_str()));
    assert_eq!(rows[0].get("up"), Some(&Json::Bool(true)));
    assert_eq!(rows[0].get("draining"), Some(&Json::Bool(false)));
    let deadline = Instant::now() + Duration::from_secs(5);
    loop {
        // connection gauges settle once the closed sessions unwind; the
        // scrape's own connection holds the http gauge at exactly 1
        let m = scrape(rt_http);
        if metric(&m, "hbllm_router_connections_active{front=\"tcp\"}") == 0.0
            && metric(&m, "hbllm_router_connections_active{front=\"http\"}") == 1.0
        {
            assert_eq!(metric(&m, "hbllm_router_requests_total{front=\"tcp\"}"), 1.0);
            assert_eq!(metric(&m, "hbllm_router_requests_total{front=\"http\"}"), 2.0);
            assert_eq!(metric(&m, "hbllm_router_retries_total"), 0.0);
            assert_eq!(
                metric(&m, &format!("hbllm_router_worker_up{{worker=\"{waddr}\"}}")),
                1.0
            );
            break;
        }
        assert!(Instant::now() < deadline, "router connection gauges never settled: {m:?}");
        std::thread::sleep(Duration::from_millis(20));
    }

    // fleet management: idempotent add, dead-address add, bad body
    let (st, body) =
        http_request(rt_http, "POST", "/v1/workers", &format!(r#"{{"add": "{waddr}"}}"#));
    assert_eq!(st, 200);
    assert_eq!(Json::parse(&body).unwrap().get("workers").unwrap().as_arr().unwrap().len(), 1);
    let (st, body) = http_request(rt_http, "POST", "/v1/workers", r#"{"add": "127.0.0.1:1"}"#);
    assert_eq!(st, 200);
    let j = Json::parse(&body).unwrap();
    assert_eq!(j.get("workers").unwrap().as_arr().unwrap().len(), 2);
    assert_eq!(j.get("healthy"), Some(&Json::Num(1.0)), "a dead address counted as healthy");
    let (st, _) = http_request(rt_http, "POST", "/v1/workers", r#"{"nope": 1}"#);
    assert_eq!(st, 400);
    let (st, _) = http_request(rt_http, "GET", "/v1/workers", "");
    assert_eq!(st, 200);
    let (st, _) = http_request(rt_http, "GET", "/v1/generate", "");
    assert_eq!(st, 405);
    let (st, _) = http_request(rt_http, "GET", "/v1/nope", "");
    assert_eq!(st, 404);

    // empty fleet: fail fast with the documented error on every front
    assert_clean_drain(w);
    wait_for_stats(rt_http, Duration::from_secs(5), |j| {
        j.get("healthy") == Some(&Json::Num(0.0))
    });
    assert_eq!(tcp_transcript(rt_tcp, "gen 2 0 0 ta\n"), "err no healthy workers\n");
    assert_eq!(tcp_transcript(rt_tcp, "ppl ta kivo\n"), "err no healthy workers\n");
    let (st, body) =
        http_request(rt_http, "POST", "/v1/generate", r#"{"prompt": "x", "max_new": 1}"#);
    assert_eq!(st, 503);
    assert_eq!(
        Json::parse(&body).unwrap().get("error").and_then(Json::as_str),
        Some("no healthy workers")
    );
    let (st, _) = http_request(rt_http, "POST", "/v1/score", r#"{"texts": ["x"]}"#);
    assert_eq!(st, 503);
}

// ---------------------------------------------------------------------------
// Failover: replica death mid-stream
// ---------------------------------------------------------------------------

/// Read whatever is available until a read deadline passes; append to
/// `acc`. Returns true on EOF. Raw reads (not line-framed) so a timeout
/// can never discard a partially-read frame.
#[cfg(unix)]
fn slurp_until_stall(r: &mut BufReader<TcpStream>, acc: &mut String) -> bool {
    let mut buf = [0u8; 4096];
    loop {
        match r.read(&mut buf) {
            Ok(0) => return true,
            Ok(n) => acc.push_str(std::str::from_utf8(&buf[..n]).expect("ASCII protocol")),
            Err(_) => return false, // deadline: stream is stalled
        }
    }
}

#[cfg(unix)]
fn has_terminal_line(acc: &str) -> bool {
    acc.lines().any(|l| {
        l.starts_with("done ")
            || l.starts_with("err ")
            || l == "event: done"
            || l == "event: error"
    })
}

/// The tentpole's failure semantics, against real process death:
///
/// * two streams (TCP + SSE) past their first token when the worker is
///   SIGKILLed surface the documented retryable `aborted`;
/// * a queued request with zero output replays invisibly on a survivor
///   and its bytes match a direct survivor run;
/// * the router marks the replica down, counts exactly one retry, and
///   keeps serving.
///
/// The victim is frozen with SIGSTOP before the kill so "mid-stream" is
/// verified, not raced: if either stream managed to finish before the
/// freeze landed, the victim is thawed and the dance retries.
#[cfg(unix)]
#[test]
fn worker_death_mid_stream_aborts_streams_and_replays_unstarted_requests() {
    // a deliberately slower, longer-sequence shape than `micro`, so
    // streams are reliably in flight when the freeze lands
    let shape = [
        ("HBLLM_TEST_WORKER_SEED", "7"),
        ("HBLLM_TEST_WORKER_D", "48"),
        ("HBLLM_TEST_WORKER_LAYERS", "4"),
        ("HBLLM_TEST_WORKER_HEADS", "4"),
        ("HBLLM_TEST_WORKER_DFF", "192"),
        ("HBLLM_TEST_WORKER_SEQ", "160"),
        ("HBLLM_TEST_WORKER_MAX_NEW", "150"),
        ("HBLLM_TEST_WORKER_LANES", "2"),
    ];
    let mut victim = spawn_worker(&shape);
    let survivor = spawn_worker(&shape);
    let workers = vec![victim.addr(), survivor.addr()];
    let cfg = RouterConfig::default();
    let (rt_tcp, rt_http) = start_router(workers.clone(), cfg);
    wait_for_stats(rt_http, Duration::from_secs(5), |j| {
        j.get("healthy") == Some(&Json::Num(2.0))
    });
    // a prompt the router will stick to the victim — predicted through
    // the same public functions the router's placement uses
    let sticky = find_sticky_prompt(&workers, 0, cfg.sticky_prefix);

    let mut frozen = None;
    for _ in 0..40 {
        // A: TCP stream through the router
        let a = TcpStream::connect(rt_tcp).unwrap();
        a.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        (&a).write_all(format!("gen 140 0.5 9 {sticky}\n").as_bytes()).unwrap();
        let mut ar = BufReader::new(a.try_clone().unwrap());
        // H: SSE stream through the router
        let h = TcpStream::connect(rt_http).unwrap();
        h.set_read_timeout(Some(Duration::from_millis(100))).unwrap();
        let hb = format!(
            r#"{{"prompt": "{sticky}", "max_new": 140, "temperature": 0.5, "seed": 11}}"#
        );
        (&h).write_all(
            format!(
                "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{hb}",
                hb.len()
            )
            .as_bytes(),
        )
        .unwrap();
        let mut hr = BufReader::new(h.try_clone().unwrap());

        // wait until BOTH streams have produced output, then freeze
        let (mut a_text, mut h_text) = (String::new(), String::new());
        let deadline = Instant::now() + Duration::from_secs(10);
        while !(a_text.contains("tok ") && h_text.contains("event: tok")) {
            slurp_until_stall(&mut ar, &mut a_text);
            slurp_until_stall(&mut hr, &mut h_text);
            assert!(
                Instant::now() < deadline,
                "streams never started: tcp={a_text:?} sse={h_text:?}"
            );
        }
        signal_pid(victim.pid(), SIGSTOP);
        // collect what was already in flight; if either stream reached a
        // terminal frame the freeze was too late — thaw and retry
        std::thread::sleep(Duration::from_millis(50));
        slurp_until_stall(&mut ar, &mut a_text);
        slurp_until_stall(&mut hr, &mut h_text);
        if !has_terminal_line(&a_text) && !has_terminal_line(&h_text) {
            frozen = Some((a, ar, a_text, h, hr, h_text));
            break;
        }
        signal_pid(victim.pid(), SIGCONT);
        // dropping a/h ends this attempt's router sessions client-side
    }
    let (a, mut ar, mut a_text, h, mut hr, mut h_text) =
        frozen.expect("could not freeze the victim mid-stream in 40 attempts");

    // B: sticky to the (frozen) victim — forwarded, zero frames produced
    let bp = sticky.clone();
    let b = std::thread::spawn(move || tcp_transcript(rt_tcp, &format!("gen 4 0 0 {bp}\n")));
    std::thread::sleep(Duration::from_millis(300));

    // real replica death (SIGKILL thaws and kills a stopped process)
    victim.kill();

    // A surfaces the documented retryable abort as its terminal line
    a.set_read_timeout(Some(Duration::from_millis(250))).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while !a_text.lines().any(|l| l == "err aborted") {
        slurp_until_stall(&mut ar, &mut a_text);
        assert!(Instant::now() < deadline, "TCP stream never aborted: {a_text:?}");
    }
    assert_eq!(a_text.lines().last(), Some("err aborted"), "abort was not terminal: {a_text:?}");
    assert!(!a_text.lines().any(|l| l.starts_with("done ")));

    // H gets the same abort as an SSE error frame, ids still contiguous
    h.set_read_timeout(Some(Duration::from_millis(250))).unwrap();
    let deadline = Instant::now() + Duration::from_secs(10);
    while !slurp_until_stall(&mut hr, &mut h_text) {
        assert!(Instant::now() < deadline, "SSE stream never closed: {h_text:?}");
    }
    let events = parse_events(&h_text);
    assert_eq!(
        events.last().map(|(e, d)| (e.as_str(), d.as_str())),
        Some(("error", "aborted")),
        "SSE stream did not abort:\n{h_text}"
    );
    assert!(events[..events.len() - 1].iter().all(|(e, _)| e == "tok"));
    let ids = sse_ids(&h_text);
    assert_eq!(ids.len(), events.len());
    for (i, id) in ids.iter().enumerate() {
        assert_eq!(*id, i as u64, "SSE ids lost monotonicity across the abort: {ids:?}");
    }

    // B replayed invisibly: same bytes as a direct run on the survivor
    let bt = b.join().unwrap();
    assert!(bt.ends_with("done 4\n"), "queued request did not survive the kill: {bt:?}");
    assert!(!bt.contains("err "), "the replay leaked an error to the client: {bt:?}");
    let direct = tcp_transcript(survivor.tcp, &format!("gen 4 0 0 {sticky}\n"));
    assert_eq!(bt, direct, "replayed bytes diverged from a direct survivor run");

    // router accounting: victim down, exactly one retry (B), fleet of 1
    let deadline = Instant::now() + Duration::from_secs(5);
    let m = loop {
        let m = scrape(rt_http);
        if metric(&m, &format!("hbllm_router_worker_up{{worker=\"{}\"}}", workers[0])) == 0.0 {
            break m;
        }
        assert!(Instant::now() < deadline, "router never marked the dead replica down");
        std::thread::sleep(Duration::from_millis(20));
    };
    assert_eq!(metric(&m, &format!("hbllm_router_worker_up{{worker=\"{}\"}}", workers[1])), 1.0);
    assert_eq!(
        metric(&m, "hbllm_router_retries_total"),
        1.0,
        "exactly the one zero-frame request should have replayed"
    );
    let j = stats(rt_http);
    assert_eq!(j.get("healthy"), Some(&Json::Num(1.0)));
    assert_eq!(j.get("retries"), Some(&Json::Num(1.0)));

    // queued traffic keeps draining on the survivor
    for i in 0..3 {
        let t = tcp_transcript(rt_tcp, &format!("gen 2 0 0 {sticky} {i}\n"));
        assert!(t.ends_with("done 2\n"), "post-kill request {i} stalled: {t:?}");
    }
    assert_clean_drain(survivor);
}

// ---------------------------------------------------------------------------
// Stickiness: prefix-sharing requests concentrate on one worker's cache
// ---------------------------------------------------------------------------

#[test]
fn sticky_prefix_routing_concentrates_cache_hits_on_one_worker() {
    let envs = [("HBLLM_TEST_WORKER_SEED", "31"), ("HBLLM_TEST_WORKER_PREFIX_CACHE", "8")];
    let w0 = spawn_worker(&envs);
    let w1 = spawn_worker(&envs);
    let workers = vec![w0.addr(), w1.addr()];
    // an 8-byte sticky window == 2 KV blocks of shared prefix
    let cfg = RouterConfig { sticky_prefix: 8, ..RouterConfig::default() };
    let (rt_tcp, rt_http) = start_router(workers.clone(), cfg);
    wait_for_stats(rt_http, Duration::from_secs(5), |j| {
        j.get("healthy") == Some(&Json::Num(2.0))
    });

    let base = "ta kivo "; // exactly the sticky window
    let predicted =
        rendezvous_pick(hbllm::coordinator::prefix_hash(base.as_bytes(), 8), &workers).unwrap();

    // seed the predicted worker's cache, then extend the prefix
    let t = tcp_transcript(rt_tcp, &format!("gen 3 0 0 {base}\n"));
    assert!(t.ends_with("done 3\n"), "seed request failed: {t:?}");
    for ext in ["t", "s", "n"] {
        let t = tcp_transcript(rt_tcp, &format!("gen 2 0 0 {base}{ext}\n"));
        assert!(t.ends_with("done 2\n"), "extension {ext:?} failed: {t:?}");
    }

    let (hot, cold) = if predicted == 0 { (&w0, &w1) } else { (&w1, &w0) };
    let tot = |j: &Json, k: &str| j.at(&["totals", k]).and_then(Json::as_f64).unwrap();
    let hj = stats(hot.http);
    let cj = stats(cold.http);
    // all four requests landed where rendezvous predicted…
    assert_eq!(tot(&hj, "requests_started"), 4.0, "sticky placement leaked off {predicted}");
    assert_eq!(tot(&cj, "requests_started"), 0.0, "the cold worker saw sticky traffic");
    // …so the seed misses once and every extension hits that cache
    assert_eq!(tot(&hj, "prefix_cache_hits"), 3.0, "extensions missed the sticky cache");
    assert_eq!(tot(&hj, "prefix_cache_misses"), 1.0);
    assert_eq!(tot(&cj, "prefix_cache_hits"), 0.0);

    assert_clean_drain(w0);
    assert_clean_drain(w1);
}

// ---------------------------------------------------------------------------
// Graceful drain: finish active lanes, return the arena, leave the fleet
// ---------------------------------------------------------------------------

#[test]
fn graceful_drain_finishes_active_work_and_the_router_routes_around_it() {
    let envs = [("HBLLM_TEST_WORKER_SEED", "57")];
    let w0 = spawn_worker(&envs);
    let w1 = spawn_worker(&envs);
    let workers = vec![w0.addr(), w1.addr()];
    let cfg = RouterConfig::default();
    let (rt_tcp, rt_http) = start_router(workers.clone(), cfg);
    wait_for_stats(rt_http, Duration::from_secs(5), |j| {
        j.get("healthy") == Some(&Json::Num(2.0))
    });

    // a stream active on w0 while the drain lands: it must run to
    // completion — drain closes admission, never active lanes
    let w0_tcp = w0.tcp;
    let active = std::thread::spawn(move || {
        let mut s = TcpStream::connect(w0_tcp).unwrap();
        s.write_all(b"gen 5 0 0 ta kivo\n").unwrap();
        let mut r = BufReader::new(s);
        let mut out = String::new();
        let mut line = String::new();
        loop {
            line.clear();
            if r.read_line(&mut line).unwrap() == 0 {
                break;
            }
            out.push_str(&line);
            if line.starts_with("done ") || line.starts_with("err ") {
                break;
            }
            std::thread::sleep(Duration::from_millis(2)); // slow consumer
        }
        out
    });
    std::thread::sleep(Duration::from_millis(10));

    let probe = w0.tcp;
    let (free, total) = w0.drain_and_wait();
    assert_eq!(free, total, "drained worker leaked KV blocks");
    assert!(total > 0);
    let transcript = active.join().unwrap();
    assert!(
        transcript.ends_with("done 5\n"),
        "active stream did not finish under drain: {transcript:?}"
    );
    // exit was clean and complete: the port no longer accepts
    assert!(TcpStream::connect(probe).is_err(), "drained worker still accepting connections");

    // the router noticed and placement routes around the drained worker
    let j = wait_for_stats(rt_http, Duration::from_secs(5), |j| {
        j.get("healthy") == Some(&Json::Num(1.0))
    });
    let rows = j.get("workers").unwrap().as_arr().unwrap();
    let row = rows
        .iter()
        .find(|r| r.get("worker").and_then(Json::as_str) == Some(workers[0].as_str()))
        .expect("drained worker still listed");
    assert!(
        row.get("up") == Some(&Json::Bool(false))
            || row.get("draining") == Some(&Json::Bool(true)),
        "fleet stats still show the drained worker placeable: {row}"
    );

    // sticky-to-w0 traffic keeps flowing, failed over to w1
    let sticky = find_sticky_prompt(&workers, 0, cfg.sticky_prefix);
    let t = tcp_transcript(rt_tcp, &format!("gen 3 0 0 {sticky}\n"));
    assert!(t.ends_with("done 3\n"), "traffic stalled after a graceful drain: {t:?}");
    let started = stats(w1.http).at(&["totals", "requests_started"]).and_then(Json::as_f64);
    assert!(started.unwrap() >= 1.0, "the survivor never saw the failed-over request");

    assert_clean_drain(w1);
}

// ---------------------------------------------------------------------------
// /v1/stats coherence under concurrent polling + the 503 engine-down path
// ---------------------------------------------------------------------------

/// In-process server (no router): hammer `/v1/stats` from several
/// keep-alive connections while generations run, asserting every
/// response is internally coherent, then pin the endpoint's 503 once
/// `POST /v1/drain` has taken the engine down.
#[test]
fn stats_stay_coherent_under_concurrent_polling_then_503_when_engine_gone() {
    let weights = synth_weights(21, 16, 2, 2, 32, 12);
    let mut be =
        NativeBackend::with_threads(PackedModel::from_weights(&weights, true).unwrap(), 1, 1);
    be.set_lanes(2);
    let block_len = 4usize;
    let blocks = 2 * hbllm::engine::paged::blocks_for(be.seq(), block_len);
    be.set_kv_blocks(Some(blocks), Some(block_len));
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();

    const GENS: usize = 4;
    const POLLERS: usize = 3;
    const POLLS: usize = 25;
    let supervisor = std::thread::spawn(move || {
        let mut threads = Vec::new();
        for i in 0..GENS {
            threads.push(std::thread::spawn(move || {
                let body = format!(r#"{{"prompt": "ta kivo {i}", "max_new": 3}}"#);
                let raw = sse_transcript(http_addr, &body);
                let events = parse_events(&raw);
                assert_eq!(
                    events.last().map(|(e, d)| (e.as_str(), d.as_str())),
                    Some(("done", "3")),
                    "generation under polling failed:\n{raw}"
                );
            }));
        }
        for _ in 0..POLLERS {
            threads.push(std::thread::spawn(move || {
                let s = TcpStream::connect(http_addr).unwrap();
                let mut reader = BufReader::new(s.try_clone().unwrap());
                for _ in 0..POLLS {
                    (&s).write_all(b"GET /v1/stats HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
                    let (status, body) = read_framed(&mut reader);
                    assert_eq!(status, 200);
                    let j = Json::parse(&body).unwrap();
                    let num = |k: &str| j.get(k).and_then(Json::as_f64).unwrap();
                    // a snapshot is taken atomically on the engine
                    // thread: queued must equal the per-client depths,
                    // active fit the lanes, and the KV ledger add up
                    assert!(num("active") <= num("lanes"), "active lanes overflow: {body}");
                    let depth_sum: f64 = j
                        .get("clients")
                        .and_then(Json::as_arr)
                        .unwrap()
                        .iter()
                        .map(|c| c.get("depth").and_then(Json::as_f64).unwrap())
                        .sum();
                    assert_eq!(num("queued"), depth_sum, "queued != client depths: {body}");
                    let free = j.at(&["kv", "free_blocks"]).and_then(Json::as_f64).unwrap();
                    let total = j.at(&["kv", "total_blocks"]).and_then(Json::as_f64).unwrap();
                    assert_eq!(total, blocks as f64);
                    assert!(free <= total, "KV ledger overflow: {body}");
                    // every active lane holds at least one block
                    assert!(total - free >= num("active"), "active lanes without KV: {body}");
                    assert_eq!(j.get("draining"), Some(&Json::Bool(false)));
                    let ts = |k: &str| j.at(&["totals", k]).and_then(Json::as_f64).unwrap();
                    assert!(ts("requests_started") >= ts("requests_finished"));
                }
            }));
        }
        for t in threads {
            t.join().expect("stats-coherence client panicked");
        }

        // the 503 path, on one keep-alive connection: drain, then poll
        // the same endpoint until the engine is gone
        let s = TcpStream::connect(http_addr).unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        (&s).write_all(b"POST /v1/drain HTTP/1.1\r\nHost: t\r\nContent-Length: 0\r\n\r\n")
            .unwrap();
        let (status, body) = read_framed(&mut reader);
        assert_eq!(status, 200, "drain refused: {body}");
        assert_eq!(Json::parse(&body).unwrap().get("draining"), Some(&Json::Bool(true)));
        let deadline = Instant::now() + Duration::from_secs(5);
        loop {
            (&s).write_all(b"GET /v1/stats HTTP/1.1\r\nHost: t\r\n\r\n").unwrap();
            let (status, body) = read_framed(&mut reader);
            if status == 503 {
                assert!(
                    Json::parse(&body).unwrap().get("error").is_some(),
                    "503 without an error body: {body}"
                );
                break;
            }
            // a pre-exit snapshot may still answer — it must say so
            assert_eq!(status, 200);
            assert_eq!(Json::parse(&body).unwrap().get("draining"), Some(&Json::Bool(true)));
            assert!(Instant::now() < deadline, "stats never surfaced the engine-down 503");
            std::thread::sleep(Duration::from_millis(5));
        }
    });

    serve::serve_fronts(
        vec![http::HttpConn::front_end(http_l, Some(GENS + POLLERS + 1))],
        &mut be,
        BatcherConfig::default(),
    )
    .unwrap();
    supervisor.join().unwrap();
    let st = be.kv_stats().expect("metered backend");
    assert_eq!(st.free_blocks, st.total_blocks, "stats test leaked KV blocks");
}
