//! Continuous-batching generation, end to end (no artifacts needed):
//! batched-vs-sequential greedy decode parity on the native engine, and
//! the TCP serve protocol (`gen`/`ppl` verbs, streaming, error lines)
//! under concurrent clients contending for fewer lanes than clients.

use hbllm::coordinator::{serve, BatcherConfig};
use hbllm::engine::{self, Backend, NativeBackend, PackedModel};
use hbllm::model::testing::micro_weights;
use hbllm::util::rng::Pcg32;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

fn packed_micro(seed: u64) -> NativeBackend {
    let w = micro_weights(seed);
    NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1)
}

/// The acceptance invariant: N lanes decoded in lock step through
/// `decode_batch` produce byte-identical greedy outputs to N sequential
/// single-lane `decode_step` runs — including after the window slides past
/// `seq_len` (which forces mid-flight re-prefills inside the batch).
#[test]
fn batched_greedy_decode_matches_sequential() {
    let seed = 61;
    let seq = micro_weights(seed).config.seq_len;
    let n_new = seq + 4;
    let prompts: [&[u8]; 4] = [b"ta ", b"kivo remo", b"a", b"so lute "];

    // sequential reference: a fresh single-lane backend per prompt
    let mut want: Vec<Vec<u8>> = Vec::new();
    for p in prompts {
        let mut be = packed_micro(seed);
        let mut rng = Pcg32::seeded(0);
        want.push(engine::generate(&mut be, p, n_new, 0.0, &mut rng).unwrap());
    }

    // batched: one 4-lane backend, all prompts advanced in lock step
    let mut be = packed_micro(seed);
    assert_eq!(be.set_lanes(4), 4);
    let mut texts: Vec<Vec<u8>> = prompts.iter().map(|p| p.to_vec()).collect();
    for _ in 0..n_new {
        let rows = {
            let reqs: Vec<(usize, &[u8])> =
                texts.iter().enumerate().map(|(i, t)| (i, t.as_slice())).collect();
            be.decode_batch(&reqs).unwrap()
        };
        for (text, row) in texts.iter_mut().zip(rows) {
            let next = engine::sample_logits(&row, 0.0, &mut Pcg32::seeded(0)) as u8;
            text.push(next);
        }
    }
    assert_eq!(texts, want, "batched greedy decode diverged from sequential");
}

/// Staggered admission: a lane that joins mid-stream (prefilling its
/// prompt while the other lane decodes) must not perturb the established
/// lane, and must itself match a solo run.
#[test]
fn late_admission_does_not_perturb_running_lane() {
    let seed = 63;
    let n_new = 6;
    let solo = |prompt: &[u8]| {
        let mut be = packed_micro(seed);
        let mut rng = Pcg32::seeded(0);
        engine::generate(&mut be, prompt, n_new, 0.0, &mut rng).unwrap()
    };
    let want_a = solo(b"ta ki");
    let want_b = solo(b"vo remo ");

    let mut be = packed_micro(seed);
    be.set_lanes(2);
    let mut a = b"ta ki".to_vec();
    let mut b = b"vo remo ".to_vec();
    let greedy = |row: &[f32]| engine::sample_logits(row, 0.0, &mut Pcg32::seeded(0)) as u8;
    // lane 0 decodes alone for 3 tokens...
    for _ in 0..3 {
        let rows = be.decode_batch(&[(0, &a)]).unwrap();
        a.push(greedy(&rows[0]));
    }
    // ...then lane 1 is admitted and both run to completion
    for step in 0..n_new {
        let rows = {
            let reqs: Vec<(usize, &[u8])> = if step < 3 {
                vec![(0, a.as_slice()), (1, b.as_slice())]
            } else {
                vec![(1, b.as_slice())]
            };
            be.decode_batch(&reqs).unwrap()
        };
        if step < 3 {
            a.push(greedy(&rows[0]));
            b.push(greedy(&rows[1]));
        } else {
            b.push(greedy(&rows[0]));
        }
    }
    assert_eq!(a, want_a, "established lane perturbed by admission");
    assert_eq!(b, want_b, "late-admitted lane diverged from solo run");
}

/// Acceptance pin for paged KV serving: an arena deliberately sized below
/// worst case (2 lanes' worth of blocks for 4 clients on 4 lanes) must
/// complete *every* request through admission backpressure — requests
/// queue for blocks, never panic, never get evicted — and greedy outputs
/// stay byte-identical across the contending clients.
#[test]
fn undersized_kv_arena_completes_all_requests_via_backpressure() {
    let seed = 65;
    let n_clients = 4;
    let n_new = 6;
    let mut be = packed_micro(seed);
    be.set_lanes(4);
    // seq 12 at block_len 4 -> 3 blocks per worst-case lane; grant 2 lanes' worth
    be.set_kv_blocks(Some(6), Some(4));
    let (listener, addr) = serve::bind("127.0.0.1:0").unwrap();

    let clients: Vec<std::thread::JoinHandle<Vec<u8>>> = (0..n_clients)
        .map(|_| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut stream = stream;
                let mut line = String::new();
                // scoring while generation holds the arena: the engine
                // loop defers the batch for blocks instead of failing it
                stream.write_all(b"ppl ta kivo remo\n").unwrap();
                reader.read_line(&mut line).unwrap();
                assert!(
                    line.starts_with("ppl "),
                    "scoring failed under kv pressure: {line:?}"
                );
                // prompt 5 + 6 new tokens = 11 positions -> 3 blocks reserved
                stream.write_all(format!("gen {n_new} 0 0 ta ki\n").as_bytes()).unwrap();
                let mut toks: Vec<u8> = Vec::new();
                loop {
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    let t = line.trim_end();
                    if let Some(b) = t.strip_prefix("tok ") {
                        toks.push(b.parse().unwrap());
                    } else {
                        assert_eq!(t, format!("done {n_new}"), "request not completed: {t:?}");
                        break;
                    }
                }
                toks
            })
        })
        .collect();

    serve::serve_on(listener, &mut be, BatcherConfig::default(), Some(n_clients)).unwrap();
    let outs: Vec<Vec<u8>> = clients.into_iter().map(|c| c.join().unwrap()).collect();
    for o in &outs[1..] {
        assert_eq!(o, &outs[0], "backpressure leaked state between sequences");
    }
    let mut solo = packed_micro(seed);
    let mut rng = Pcg32::seeded(0);
    let full = engine::generate(&mut solo, b"ta ki", n_new, 0.0, &mut rng).unwrap();
    assert_eq!(&full[b"ta ki".len()..], &outs[0][..]);
}

/// An arena too small for even one request: the sequence is admitted
/// (its reservation clamps to the whole arena), decodes until the blocks
/// run out, and is evicted with a single `err kv exhausted` line — the
/// server neither panics nor wedges, and a request that fits afterwards
/// completes normally on the same connection.
#[test]
fn kv_exhaustion_over_tcp_reports_err_and_recovers() {
    let seed = 66;
    let mut be = packed_micro(seed);
    be.set_lanes(2);
    be.set_kv_blocks(Some(1), Some(4)); // one 4-token block total
    let (listener, addr) = serve::bind("127.0.0.1:0").unwrap();

    let client = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut stream = stream;
        let mut line = String::new();
        // 4-byte prompt + 6 tokens needs 3 blocks; only 1 exists
        stream.write_all(b"gen 6 0 0 abcd\n").unwrap();
        let mut toks = 0usize;
        loop {
            line.clear();
            reader.read_line(&mut line).unwrap();
            let t = line.trim_end();
            if t.starts_with("tok ") {
                toks += 1;
                assert!(toks < 6, "over-long sequence was never evicted");
            } else {
                assert_eq!(t, "err kv exhausted", "wrong eviction signal: {t:?}");
                break;
            }
        }
        // eviction released every block: a fitting request completes
        stream.write_all(b"gen 2 0 0 ab\n").unwrap();
        let mut generated = 0usize;
        loop {
            line.clear();
            reader.read_line(&mut line).unwrap();
            let t = line.trim_end();
            if t.starts_with("tok ") {
                generated += 1;
            } else {
                assert_eq!(t, "done 2", "server wedged after kv eviction: {t:?}");
                break;
            }
        }
        assert_eq!(generated, 2);
    });

    serve::serve_on(listener, &mut be, BatcherConfig::default(), Some(1)).unwrap();
    client.join().unwrap();
}

/// `--load` round trip: a freshly *saved* HBQ1 artifact, reloaded from
/// disk and served over TCP, must score and generate — and its greedy
/// output must match a direct in-process generate over the same loaded
/// records (the packed records execute as-is, no re-quantization).
#[test]
fn serve_from_saved_artifact_round_trips() {
    use hbllm::pack::format;
    let w = micro_weights(67);
    let art = format::PackedModel::from_weights(&w);
    let path = std::env::temp_dir().join("hbllm_serve_roundtrip.hbq");
    art.save(&path).unwrap();
    let loaded = format::PackedModel::load(&path).unwrap();
    std::fs::remove_file(&path).ok();

    // reference: direct greedy generate over the same loaded records
    let mut reference = NativeBackend::with_threads(
        PackedModel::from_artifact(&w.config, &loaded).unwrap(),
        1,
        1,
    );
    let mut rng = Pcg32::seeded(0);
    let n_new = 6;
    let want = engine::generate(&mut reference, b"ta ki", n_new, 0.0, &mut rng).unwrap();

    let mut be = NativeBackend::with_threads(
        PackedModel::from_artifact(&w.config, &loaded).unwrap(),
        1,
        1,
    );
    be.set_lanes(2);
    let (listener, addr) = serve::bind("127.0.0.1:0").unwrap();
    let client = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut stream = stream;
        let mut line = String::new();
        stream.write_all(b"ppl ta kivo remo\n").unwrap();
        reader.read_line(&mut line).unwrap();
        assert!(line.starts_with("ppl "), "artifact serving cannot score: {line:?}");
        stream.write_all(format!("gen {n_new} 0 0 ta ki\n").as_bytes()).unwrap();
        let mut toks: Vec<u8> = Vec::new();
        loop {
            line.clear();
            reader.read_line(&mut line).unwrap();
            let t = line.trim_end();
            if let Some(b) = t.strip_prefix("tok ") {
                toks.push(b.parse().unwrap());
            } else {
                assert_eq!(t, format!("done {n_new}"), "bad terminator: {t:?}");
                break;
            }
        }
        toks
    });
    serve::serve_on(listener, &mut be, BatcherConfig::default(), Some(1)).unwrap();
    let toks = client.join().unwrap();
    assert_eq!(&want[b"ta ki".len()..], &toks[..], "served artifact diverged from direct decode");
}

/// Full protocol over TCP: more clients than lanes, each mixing legacy
/// bare-line scoring, `ppl`, empty-input errors, bad syntax, and a greedy
/// `gen` stream. Greedy determinism across contending clients is the
/// observable proof that lane turnover (admission + eviction) does not
/// leak state between sequences.
#[test]
fn serve_gen_protocol_end_to_end() {
    let seed = 62;
    let mut be = packed_micro(seed);
    be.set_lanes(2);
    let (listener, addr) = serve::bind("127.0.0.1:0").unwrap();
    let n_clients = 4;
    let n_new = 6;

    let clients: Vec<std::thread::JoinHandle<Vec<u8>>> = (0..n_clients)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut stream = stream;
                let mut line = String::new();
                let mut req = |s: &str, line: &mut String| {
                    stream.write_all(s.as_bytes()).unwrap();
                    line.clear();
                    reader.read_line(line).unwrap();
                };

                // ppl verb
                req("ppl ta kivo remo\n", &mut line);
                assert!(line.starts_with("ppl "), "bad ppl response: {line:?}");
                let v: f64 = line[4..].trim().parse().unwrap();
                assert!(v.is_finite() && v > 0.0);

                // legacy bare line still scores
                req("ta kivo remo\n", &mut line);
                assert!(line.starts_with("ppl "), "legacy scoring broke: {line:?}");

                // empty input is an error, not a pad-byte perplexity
                req("ppl   \t \n", &mut line);
                assert_eq!(line.trim_end(), "err empty input");

                // malformed gen
                req("gen nope\n", &mut line);
                assert!(line.starts_with("err usage"), "bad syntax not reported: {line:?}");

                // greedy generation streams tokens then a terminator
                stream.write_all(format!("gen {n_new} 0 0 ta ki\n").as_bytes()).unwrap();
                let mut toks: Vec<u8> = Vec::new();
                loop {
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    let t = line.trim_end();
                    if let Some(b) = t.strip_prefix("tok ") {
                        toks.push(b.parse().unwrap());
                    } else {
                        assert_eq!(t, format!("done {n_new}"), "client {c}: bad terminator {t:?}");
                        break;
                    }
                }
                assert_eq!(toks.len(), n_new);
                toks
            })
        })
        .collect();

    serve::serve_on(listener, &mut be, BatcherConfig::default(), Some(n_clients)).unwrap();
    let outs: Vec<Vec<u8>> = clients.into_iter().map(|c| c.join().unwrap()).collect();

    // greedy decoding is deterministic: every client saw the same bytes...
    for o in &outs[1..] {
        assert_eq!(o, &outs[0], "lane turnover leaked state between sequences");
    }
    // ...and they match a direct single-lane generate on the same model
    let mut solo = packed_micro(seed);
    let mut rng = Pcg32::seeded(0);
    let full = engine::generate(&mut solo, b"ta ki", n_new, 0.0, &mut rng).unwrap();
    assert_eq!(&full[b"ta ki".len()..], &outs[0][..]);
}
