//! Shared multi-process fleet harness for the router test suites
//! (`tests/router_failover.rs`, `tests/chaos_soak.rs`).
//!
//! Workers are REAL processes: each test binary re-execs itself
//! (`std::env::current_exe()`) with `HBLLM_TEST_WORKER=1`, which makes
//! the `worker_process_entry` test in that binary run a full
//! `serve_fronts` server instead of returning immediately. The child
//! announces its bound ports on stdout (`worker tcp=A http=B`), serves
//! until it is drained (`POST /v1/drain`) or killed, and — on a graceful
//! exit — prints its final KV arena occupancy so the parent can assert
//! `free == total` on every worker at teardown.
//!
//! The router itself runs in-process (it is the system under test and
//! its state is asserted through its own `/v1/stats` + `/v1/metrics`
//! endpoints), while every worker lives in its own process so `SIGSTOP`
//! / `SIGKILL` exercise real replica death, not a simulation.
#![allow(dead_code)]

use hbllm::coordinator::{
    http, prefix_hash, rendezvous_pick, run_router, serve, BatcherConfig, RouterConfig,
};
use hbllm::engine::{Backend, NativeBackend, PackedModel, SpecConfig};
use hbllm::model::testing::synth_weights;
use hbllm::util::json::Json;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::process::{Child, ChildStdout, Command, Stdio};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------------
// The worker process body
// ---------------------------------------------------------------------------

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// The body of the re-exec'd worker process. Call this from a `#[test]`
/// named `worker_process_entry` in each test binary that spawns workers;
/// without `HBLLM_TEST_WORKER` in the environment it is a no-op, so the
/// entry passes vacuously during a normal test run.
///
/// Model shape, lanes, spec and cache knobs come from
/// `HBLLM_TEST_WORKER_*` variables (defaults mirror `micro_weights`).
/// The KV arena is always metered, sized to the worst case
/// (`lanes * blocks_for(seq)`), so a clean drain must return every block.
pub fn worker_entry_if_requested() {
    if std::env::var("HBLLM_TEST_WORKER").is_err() {
        return;
    }
    let seed = env_u64("HBLLM_TEST_WORKER_SEED", 91);
    let d = env_u64("HBLLM_TEST_WORKER_D", 16) as usize;
    let layers = env_u64("HBLLM_TEST_WORKER_LAYERS", 2) as usize;
    let heads = env_u64("HBLLM_TEST_WORKER_HEADS", 2) as usize;
    let dff = env_u64("HBLLM_TEST_WORKER_DFF", 32) as usize;
    let seq = env_u64("HBLLM_TEST_WORKER_SEQ", 12) as usize;
    let lanes = env_u64("HBLLM_TEST_WORKER_LANES", 2) as usize;
    let spec_k = env_u64("HBLLM_TEST_WORKER_SPEC_K", 0) as usize;
    let prefix_cache = env_u64("HBLLM_TEST_WORKER_PREFIX_CACHE", 0) as usize;
    let max_new_cap = env_u64("HBLLM_TEST_WORKER_MAX_NEW", 256) as usize;

    let w = synth_weights(seed, d, layers, heads, dff, seq);
    let mut be = NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
    be.set_lanes(lanes);
    let block_len = 4usize;
    let blocks = lanes * hbllm::engine::paged::blocks_for(be.seq(), block_len);
    be.set_kv_blocks(Some(blocks), Some(block_len));
    let spec = be.set_spec(SpecConfig::with_k(spec_k));

    let (tcp_l, tcp_addr) = serve::bind("127.0.0.1:0").unwrap();
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();
    // the parent scans stdout for this line to learn our ports
    println!("worker tcp={tcp_addr} http={http_addr}");
    let _ = std::io::stdout().flush();

    serve::serve_fronts(
        vec![serve::FrontEnd::line(tcp_l, None), http::HttpConn::front_end(http_l, None)],
        &mut be,
        BatcherConfig { spec, prefix_cache, max_new_cap, ..BatcherConfig::default() },
    )
    .unwrap();

    // graceful exit: report the arena so the parent can assert free==total
    let st = be.kv_stats().expect("worker backend is KV-metered");
    println!("worker kv free={} total={}", st.free_blocks, st.total_blocks);
    let _ = std::io::stdout().flush();
}

// ---------------------------------------------------------------------------
// Spawning and steering worker processes
// ---------------------------------------------------------------------------

/// One worker process plus the stdout pipe the harness reads its
/// announcements from.
pub struct Worker {
    pub child: Child,
    pub tcp: SocketAddr,
    pub http: SocketAddr,
    reader: BufReader<ChildStdout>,
}

/// Re-exec the current test binary as a worker (see
/// [`worker_entry_if_requested`]) and block until it announces its ports.
pub fn spawn_worker(envs: &[(&str, &str)]) -> Worker {
    let exe = std::env::current_exe().expect("current test binary");
    let mut cmd = Command::new(exe);
    // --nocapture: libtest must not swallow the child's address line
    cmd.args(["worker_process_entry", "--exact", "--test-threads=1", "--nocapture"])
        .env("HBLLM_TEST_WORKER", "1")
        .stdout(Stdio::piped())
        .stderr(Stdio::null());
    for (k, v) in envs {
        cmd.env(k, v);
    }
    let mut child = cmd.spawn().expect("spawning worker process");
    let mut reader = BufReader::new(child.stdout.take().expect("worker stdout piped"));
    let mut line = String::new();
    let (tcp, http) = loop {
        line.clear();
        if reader.read_line(&mut line).expect("reading worker stdout") == 0 {
            panic!("worker exited before announcing its ports");
        }
        if let Some(rest) = line.trim_end().strip_prefix("worker tcp=") {
            let (t, h) = rest.split_once(" http=").expect("worker address line shape");
            break (t.parse().unwrap(), h.parse().unwrap());
        }
    };
    Worker { child, tcp, http, reader }
}

impl Worker {
    pub fn http_url(&self) -> String {
        format!("http://{}", self.http)
    }

    /// The address string the router knows this worker by — feed the
    /// same strings to [`rendezvous_pick`] to predict placement.
    pub fn addr(&self) -> String {
        self.http.to_string()
    }

    pub fn pid(&self) -> u32 {
        self.child.id()
    }

    /// SIGKILL — abrupt replica death, no goodbye.
    pub fn kill(&mut self) {
        let _ = self.child.kill();
        let _ = self.child.wait();
    }

    /// Drain gracefully (`POST /v1/drain`), wait for a clean exit, and
    /// return the worker's final KV arena as `(free, total)`. Panics if
    /// the worker exits non-zero or never reports its arena.
    pub fn drain_and_wait(mut self) -> (u64, u64) {
        let _ = http::client_drain(&self.http_url());
        let mut line = String::new();
        let mut kv = None;
        loop {
            line.clear();
            match self.reader.read_line(&mut line) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
            if let Some(rest) = line.trim_end().strip_prefix("worker kv free=") {
                let (free, total) = rest.split_once(" total=").expect("worker kv line shape");
                kv = Some((free.parse().unwrap(), total.parse().unwrap()));
            }
        }
        let status = self.child.wait().expect("waiting for drained worker");
        assert!(status.success(), "drained worker exited with {status:?}");
        kv.expect("worker never reported its KV arena")
    }
}

/// Drain a worker and assert its arena came back whole — the teardown
/// every fleet test ends with.
pub fn assert_clean_drain(w: Worker) {
    let addr = w.addr();
    let (free, total) = w.drain_and_wait();
    assert!(total > 0, "worker {addr} had no KV arena");
    assert_eq!(free, total, "worker {addr} leaked KV blocks at drain");
}

#[cfg(unix)]
pub fn signal_pid(pid: u32, sig: i32) {
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    let rc = unsafe { kill(pid as i32, sig) };
    assert_eq!(rc, 0, "kill({pid}, {sig}) failed");
}

#[cfg(any(target_os = "linux", target_os = "android"))]
pub const SIGSTOP: i32 = 19;
#[cfg(any(target_os = "linux", target_os = "android"))]
pub const SIGCONT: i32 = 18;
#[cfg(all(unix, not(any(target_os = "linux", target_os = "android"))))]
pub const SIGSTOP: i32 = 17; // BSD / macOS numbering
#[cfg(all(unix, not(any(target_os = "linux", target_os = "android"))))]
pub const SIGCONT: i32 = 19;

// ---------------------------------------------------------------------------
// The router under test
// ---------------------------------------------------------------------------

/// Start a router over `workers` on ephemeral ports; returns
/// `(tcp_addr, http_addr)`. The router thread runs for the remainder of
/// the test process (its listeners have no connection budget), which is
/// exactly the CLI deployment shape.
pub fn start_router(workers: Vec<String>, cfg: RouterConfig) -> (SocketAddr, SocketAddr) {
    let (tcp_l, tcp_addr) = serve::bind("127.0.0.1:0").unwrap();
    let (http_l, http_addr) = serve::bind("127.0.0.1:0").unwrap();
    std::thread::spawn(move || {
        run_router(Some((tcp_l, None)), Some((http_l, None)), workers, cfg).unwrap();
    });
    (tcp_addr, http_addr)
}

/// Search prompts until one's sticky hash lands on `workers[target]` —
/// placement prediction through the same public functions the router
/// uses, so the tests and the router cannot drift apart.
pub fn find_sticky_prompt(workers: &[String], target: usize, sticky_prefix: usize) -> String {
    // fixed-width so the prompt length never depends on how many
    // candidates were rejected (micro workers only have 12 positions)
    for i in 0u64..1000 {
        let p = format!("ta kv {i:03}");
        if rendezvous_pick(prefix_hash(p.as_bytes(), sticky_prefix), workers) == Some(target) {
            return p;
        }
    }
    panic!("no sticky prompt for worker {target} in 1000 candidates")
}

// ---------------------------------------------------------------------------
// Wire helpers (framing identical to tests/chaos_soak.rs)
// ---------------------------------------------------------------------------

/// Read one `Content-Length`-framed HTTP response off `reader`.
pub fn read_framed(reader: &mut BufReader<TcpStream>) -> (u16, String) {
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .unwrap_or_else(|| panic!("bad status line {line:?}"))
        .parse()
        .unwrap();
    let mut clen = 0usize;
    loop {
        line.clear();
        reader.read_line(&mut line).unwrap();
        let t = line.trim_end();
        if t.is_empty() {
            break;
        }
        let low = t.to_ascii_lowercase();
        if let Some(v) = low.strip_prefix("content-length:") {
            clen = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; clen];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

/// One framed HTTP exchange on its own connection.
pub fn http_request(addr: SocketAddr, method: &str, path: &str, body: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(
            format!(
                "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
    let mut reader = BufReader::new(stream);
    read_framed(&mut reader)
}

/// `GET /v1/stats`, parsed.
pub fn stats(addr: SocketAddr) -> Json {
    let (status, body) = http_request(addr, "GET", "/v1/stats", "");
    assert_eq!(status, 200, "stats endpoint failed: {body}");
    Json::parse(&body).expect("stats is JSON")
}

/// Poll `GET /v1/stats` until `pred` holds (or panic after `timeout`).
pub fn wait_for_stats(addr: SocketAddr, timeout: Duration, pred: impl Fn(&Json) -> bool) -> Json {
    let deadline = Instant::now() + timeout;
    loop {
        let j = stats(addr);
        if pred(&j) {
            return j;
        }
        assert!(Instant::now() < deadline, "stats condition never held; last: {j}");
        std::thread::sleep(Duration::from_millis(20));
    }
}

/// One TCP line-protocol exchange: send `request` (must end in `\n`),
/// return the raw response bytes through the terminal line
/// (`done …` / `err …` / `ppl …`). Raw so byte-identity can be asserted.
pub fn tcp_transcript(addr: SocketAddr, request: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(request.as_bytes()).unwrap();
    let mut reader = BufReader::new(s);
    let mut out = String::new();
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line).unwrap() == 0 {
            break;
        }
        out.push_str(&line);
        let t = line.trim_end();
        if t.starts_with("done ") || t.starts_with("err ") || t.starts_with("ppl ") {
            break;
        }
    }
    out
}

/// One `POST /v1/generate`, returning the ENTIRE raw response — status
/// line, headers, and SSE frames with their `id:` lines — read to EOF.
pub fn sse_transcript(addr: SocketAddr, body: &str) -> String {
    let mut s = TcpStream::connect(addr).unwrap();
    s.write_all(
        format!(
            "POST /v1/generate HTTP/1.1\r\nHost: t\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        )
        .as_bytes(),
    )
    .unwrap();
    let mut out = String::new();
    BufReader::new(s).read_to_string(&mut out).unwrap();
    out
}

/// Parse an SSE body into (event, data) pairs.
pub fn parse_events(body: &str) -> Vec<(String, String)> {
    let mut events = Vec::new();
    let mut ev = String::new();
    for line in body.lines() {
        if let Some(e) = line.strip_prefix("event: ") {
            ev = e.to_string();
        } else if let Some(d) = line.strip_prefix("data: ") {
            events.push((ev.clone(), d.to_string()));
        }
    }
    events
}

/// The `id:` sequence of an SSE transcript.
pub fn sse_ids(body: &str) -> Vec<u64> {
    body.lines().filter_map(|l| l.strip_prefix("id: ")).map(|v| v.parse().unwrap()).collect()
}

/// Parse Prometheus text exposition into name{labels} -> value.
pub fn parse_metrics(text: &str) -> BTreeMap<String, f64> {
    let mut out = BTreeMap::new();
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        if let Some((key, val)) = line.rsplit_once(' ') {
            out.insert(key.to_string(), val.parse().unwrap_or(f64::NAN));
        }
    }
    out
}

pub fn metric(m: &BTreeMap<String, f64>, key: &str) -> f64 {
    *m.get(key).unwrap_or(&0.0)
}

/// Scrape an HTTP endpoint's `/v1/metrics`, parsed.
pub fn scrape(addr: SocketAddr) -> BTreeMap<String, f64> {
    let (status, body) = http_request(addr, "GET", "/v1/metrics", "");
    assert_eq!(status, 200);
    parse_metrics(&body)
}
