//! Speculative-decoding acceptance pins (no artifacts needed): the
//! frequency cascade (Haar low-band draft + full-model verify) must be
//! **byte-identical** to plain greedy decoding — across draft widths,
//! window slides, staggered multi-lane admission, TCP serving with mixed
//! greedy/sampling traffic, and a deliberately draft-hostile model whose
//! energy lives in the high band (near-zero acceptance must cost
//! throughput only, never correctness or termination).

use hbllm::engine::{self, Backend, NativeBackend, PackedModel, SpecConfig};
use hbllm::model::testing::micro_weights;
use hbllm::util::rng::Pcg32;

fn packed(seed: u64) -> NativeBackend {
    let w = micro_weights(seed);
    NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1)
}

fn plain_greedy(seed: u64, prompt: &[u8], n_new: usize) -> Vec<u8> {
    let mut be = packed(seed);
    let mut rng = Pcg32::seeded(0);
    engine::generate(&mut be, prompt, n_new, 0.0, &mut rng).unwrap()
}

/// The headline invariant: speculative greedy decode is byte-identical to
/// plain greedy decode for every draft width.
#[test]
fn spec_greedy_is_byte_identical_across_k() {
    let seed = 71;
    for prompt in [b"ta ".as_slice(), b"kivo remo", b""] {
        let want = plain_greedy(seed, prompt, 9);
        for k in [1usize, 2, 4] {
            let mut be = packed(seed);
            let got = engine::generate_spec(&mut be, prompt, 9, k).unwrap();
            assert_eq!(got, want, "k={k} prompt={prompt:?} diverged from plain greedy");
            let st = be.spec_stats().unwrap();
            assert!(st.rounds > 0, "k={k}: no speculative rounds ran");
        }
    }
}

/// Parity must survive the window sliding past `seq_len`: near the edge
/// the draft width clamps to the remaining headroom (down to zero) and
/// post-slide rounds re-prefill, exactly like the plain engine.
#[test]
fn spec_parity_across_window_slide() {
    let seed = 72;
    let seq = micro_weights(seed).config.seq_len;
    let n_new = seq + 4;
    let want = plain_greedy(seed, b"ab", n_new);
    for k in [1usize, 2, 4] {
        let mut be = packed(seed);
        let got = engine::generate_spec(&mut be, b"ab", n_new, k).unwrap();
        assert_eq!(got, want, "k={k} diverged across the window slide");
    }
}

/// Staggered multi-lane speculation: lane 0 speculates alone, lane 1
/// joins mid-stream (prefilling inside the same verify sweep), lane 0
/// finishes first — both must match solo runs byte for byte.
#[test]
fn staggered_spec_lanes_do_not_perturb_each_other() {
    let seed = 73;
    let n_new = 6;
    let want_a = plain_greedy(seed, b"ta ki", n_new);
    let want_b = plain_greedy(seed, b"vo remo ", n_new);
    let mut be = packed(seed);
    be.set_lanes(2);
    let mut a = b"ta ki".to_vec();
    let mut b = b"vo remo ".to_vec();
    // lane 0 runs one solo round before lane 1 is admitted
    let r = be.decode_batch_spec(&[(0, a.as_slice())], 2).unwrap();
    for &x in &r[0].bytes {
        if a.len() < want_a.len() {
            a.push(x);
        }
    }
    let mut guard = 0;
    while a.len() < want_a.len() || b.len() < want_b.len() {
        let a_active = a.len() < want_a.len();
        let b_active = b.len() < want_b.len();
        let mut reqs: Vec<(usize, &[u8])> = Vec::new();
        if a_active {
            reqs.push((0, a.as_slice()));
        }
        if b_active {
            reqs.push((1, b.as_slice()));
        }
        let rounds = be.decode_batch_spec(&reqs, 2).unwrap();
        let mut ri = 0;
        if a_active {
            for &x in &rounds[ri].bytes {
                if a.len() < want_a.len() {
                    a.push(x);
                }
            }
            ri += 1;
        }
        if b_active {
            for &x in &rounds[ri].bytes {
                if b.len() < want_b.len() {
                    b.push(x);
                }
            }
        }
        guard += 1;
        assert!(guard < 100, "staggered speculation failed to terminate");
    }
    assert_eq!(a, want_a, "established spec lane perturbed by admission");
    assert_eq!(b, want_b, "late-admitted spec lane diverged from solo run");
}

/// A draft-hostile model: every linear's paper-orientation rows alternate
/// `+v, -v` in adjacent columns, so the Haar low band (pairwise sums) is
/// near zero and the draft proposes from an almost information-free view.
/// Acceptance collapses — and nothing else may: output stays
/// byte-identical and decoding terminates.
#[test]
fn degenerate_high_band_draft_terminates_with_exact_output() {
    let mut w = micro_weights(74);
    for name in w.config.linear_names() {
        // model orientation [in, out]: negate odd input rows so paper
        // rows pair `+v, -v` along the Haar axis
        let mut m = w.get(&name).as_mat().clone();
        for j in (0..m.rows).step_by(2) {
            for c in 0..m.cols {
                let v = m.get(j, c);
                m.set(j + 1, c, -v);
            }
        }
        w.set_matrix(&name, m);
    }
    let mk = || NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
    let n_new = 8;
    let mut plain = mk();
    let mut rng = Pcg32::seeded(0);
    let want = engine::generate(&mut plain, b"ta ", n_new, 0.0, &mut rng).unwrap();
    let mut spec = mk();
    let got = engine::generate_spec(&mut spec, b"ta ", n_new, 4).unwrap();
    assert_eq!(got, want, "degenerate draft broke parity");
    let st = spec.spec_stats().unwrap();
    assert!(st.drafted > 0, "degenerate case never drafted: {st:?}");
    assert!(
        st.accepted <= st.drafted,
        "bookkeeping corrupt: {} accepted of {} drafted",
        st.accepted,
        st.drafted
    );
}

/// Speculative serving over TCP with mixed traffic: greedy clients ride
/// the cascade (and match the plain solo reference exactly), a sampling
/// client shares the same lanes on the plain path.
#[test]
fn spec_serving_over_tcp_matches_plain_with_mixed_sampling() {
    use hbllm::coordinator::{serve, BatcherConfig};
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;
    let seed = 75;
    let n_new = 6;
    let mut be = packed(seed);
    be.set_lanes(2);
    let spec = be.set_spec(SpecConfig::with_k(3));
    assert!(spec.enabled);
    let (listener, addr) = serve::bind("127.0.0.1:0").unwrap();

    let clients: Vec<std::thread::JoinHandle<(usize, Vec<u8>)>> = (0..3usize)
        .map(|c| {
            std::thread::spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut stream = stream;
                // client 2 samples (plain path); the rest decode greedily
                let temp = if c == 2 { "0.8" } else { "0" };
                stream
                    .write_all(format!("gen {n_new} {temp} {c} ta ki\n").as_bytes())
                    .unwrap();
                let mut toks: Vec<u8> = Vec::new();
                let mut line = String::new();
                loop {
                    line.clear();
                    reader.read_line(&mut line).unwrap();
                    let t = line.trim_end();
                    if let Some(b) = t.strip_prefix("tok ") {
                        toks.push(b.parse().unwrap());
                    } else {
                        assert_eq!(t, format!("done {n_new}"), "client {c}: {t:?}");
                        break;
                    }
                }
                (c, toks)
            })
        })
        .collect();

    serve::serve_on(
        listener,
        &mut be,
        BatcherConfig { spec, ..Default::default() },
        Some(3),
    )
    .unwrap();
    let mut outs: Vec<Vec<u8>> = vec![Vec::new(); 3];
    for h in clients {
        let (c, toks) = h.join().unwrap();
        outs[c] = toks;
    }
    assert_eq!(outs[0], outs[1], "greedy spec clients diverged from each other");
    let want = plain_greedy(seed, b"ta ki", n_new);
    assert_eq!(&want[b"ta ki".len()..], &outs[0][..], "spec serving diverged from plain");
    assert_eq!(outs[2].len(), n_new, "sampling client starved under spec traffic");
    let st = be.spec_stats().unwrap();
    assert!(st.drafted > 0, "speculation never engaged over TCP: {st:?}");
}

/// Randomized parity sweep for the CI `--ignored` pass: random prompts,
/// draft widths and generation lengths, spec vs plain byte equality.
#[test]
#[ignore = "slow: run via cargo test --release -- --ignored"]
fn prop_spec_parity_randomized() {
    use hbllm::util::proptest::check;
    check(
        "spec-parity-randomized",
        20,
        |g| {
            (
                g.rng.next_u64() % 1000,
                g.size(1, 5),  // k
                g.size(1, 18), // n_new (crosses the seq-12 slide)
                g.size(0, 6),  // prompt length
            )
        },
        |&(seed, k, n_new, plen)| {
            let prompt: Vec<u8> = (0..plen).map(|i| (i * 37 + seed as usize) as u8).collect();
            let want = plain_greedy(seed, &prompt, n_new);
            let mut be = packed(seed);
            let got = engine::generate_spec(&mut be, &prompt, n_new, k).unwrap();
            if got == want {
                Ok(())
            } else {
                Err(format!("seed={seed} k={k} n_new={n_new} plen={plen}: diverged"))
            }
        },
    );
}
