//! Paged-vs-flat KV decode parity, end to end (no artifacts needed).
//!
//! The paged KV subsystem changes only where K/V rows *live* (a shared
//! block arena behind per-sequence block tables) — never the per-position
//! arithmetic. A backend configured with `block_len == seq_len` and one
//! block per lane is memory-layout-equivalent to the old flat cache, so
//! greedy decoding through it is the "flat" reference every fine-grained
//! paging must match byte for byte: single lane, staggered multi-lane
//! with mid-flight admission/eviction (block churn), and texts long
//! enough to slide the window (forced re-prefills that release and
//! re-allocate blocks).

use hbllm::engine::{self, Backend, NativeBackend, PackedModel};
use hbllm::model::testing::synth_weights;
use hbllm::util::proptest::check;
use hbllm::util::rng::Pcg32;

const SEED: u64 = 77;

/// Shared test model: bigger than `micro_weights` (multiple heads, seq
/// crossing several blocks) but still artifact-free and fast.
fn model() -> hbllm::model::Weights {
    synth_weights(SEED, 32, 2, 4, 64, 16)
}

/// A packed-engine backend with `lanes` lanes and an explicit paged-KV
/// geometry; `block_len == seq` with `blocks == lanes` is the flat layout.
fn backend(lanes: usize, n_blocks: usize, block_len: usize) -> NativeBackend {
    let w = model();
    let mut be = NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
    be.set_lanes(lanes);
    be.set_kv_blocks(Some(n_blocks), Some(block_len));
    be
}

fn flat(lanes: usize) -> NativeBackend {
    let seq = model().config.seq_len;
    backend(lanes, lanes, seq)
}

fn greedy(row: &[f32]) -> u8 {
    engine::sample_logits(row, 0.0, &mut Pcg32::seeded(0)) as u8
}

/// Single lane, generation running past `seq_len`: paged decode (several
/// block geometries, including a non-divisor block length) is
/// byte-identical to the flat layout through the window slide.
#[test]
fn single_lane_greedy_parity_across_block_geometries() {
    let seq = model().config.seq_len;
    let n_new = seq + 5;
    let gen_with = |be: &mut NativeBackend| {
        let mut rng = Pcg32::seeded(0);
        engine::generate(be, b"ta kivo ", n_new, 0.0, &mut rng).unwrap()
    };
    let want = gen_with(&mut flat(1));
    for (blocks, bl) in [(seq, 1), (4, 4), (6, 3), (2, 11)] {
        assert!(blocks * bl >= seq, "geometry under worst case breaks the reference");
        let got = gen_with(&mut backend(1, blocks, bl));
        assert_eq!(got, want, "paged ({blocks} x {bl}) diverged from flat");
    }
}

/// Staggered multi-lane decode with admission, eviction and readmission:
/// recycled blocks must not leak state between sequences, and every
/// lane must match its solo flat run.
#[test]
fn staggered_lanes_with_block_churn_match_flat() {
    let n_new = 8;
    let solo = |prompt: &[u8]| {
        let mut be = flat(1);
        let mut rng = Pcg32::seeded(0);
        engine::generate(&mut be, prompt, n_new, 0.0, &mut rng).unwrap()
    };
    let want_a = solo(b"ta ki");
    let want_b = solo(b"vo remo ");
    let want_c = solo(b"so lu");

    // paged: 2 lanes over a tight arena (2 lanes' worth at block_len 4)
    let seq = model().config.seq_len;
    let per_lane = (seq + 3) / 4;
    let mut be = backend(2, 2 * per_lane, 4);
    let mut a = b"ta ki".to_vec();
    let mut b = b"vo remo ".to_vec();
    // lane 0 decodes alone for 3 tokens...
    for _ in 0..3 {
        let rows = be.decode_batch(&[(0, &a)]).unwrap();
        a.push(greedy(&rows[0]));
    }
    // ...then lane 1 joins until lane 0 finishes
    for step in 0..n_new {
        let rows = {
            let reqs: Vec<(usize, &[u8])> = if step < n_new - 3 {
                vec![(0, a.as_slice()), (1, b.as_slice())]
            } else {
                vec![(1, b.as_slice())]
            };
            be.decode_batch(&reqs).unwrap()
        };
        if step < n_new - 3 {
            a.push(greedy(&rows[0]));
            b.push(greedy(&rows[1]));
        } else {
            b.push(greedy(&rows[0]));
        }
    }
    assert_eq!(a, want_a, "established lane perturbed by paged admission");
    assert_eq!(b, want_b, "late-admitted lane diverged from flat solo run");

    // lane 0 was evicted (reset) after finishing; its recycled blocks now
    // host a third sequence, which must still match its solo run
    be.reset_lane(0);
    let mut c = b"so lu".to_vec();
    for _ in 0..n_new {
        let rows = be.decode_batch(&[(0, &c)]).unwrap();
        c.push(greedy(&rows[0]));
    }
    assert_eq!(c, want_c, "recycled blocks leaked state into a new sequence");
}

/// Randomized schedules (heavy; CI `--ignored` pass): arbitrary
/// admit/step/evict interleavings over a paged backend, every finished
/// sequence checked byte-for-byte against a flat solo run of the same
/// prompt — window slides included.
#[test]
#[ignore = "slow: run via cargo test --release -- --ignored"]
fn prop_random_schedules_match_flat_reference() {
    let seq = model().config.seq_len;
    let prompts: [&[u8]; 5] = [b"ta ", b"kivo remo", b"a", b"so lute ", b"remo vo ta"];
    check(
        "paged-random-schedules",
        12,
        |g| (g.rng.next_u64(), g.size(2, 4), g.size(2, 5), g.size(6, 22)),
        |&(seed, lanes, block_len, n_new)| {
            // arena sized for the lane count so the schedule never hits
            // backpressure (that path is pinned by the scheduler tests)
            let per_lane = (seq + block_len - 1) / block_len;
            let mut be = backend(lanes, lanes * per_lane, block_len);
            let mut rng = Pcg32::seeded(seed);
            // solo flat references, computed lazily per prompt/new-count
            let solo = |prompt: &[u8]| {
                let mut fb = flat(1);
                let mut r = Pcg32::seeded(0);
                engine::generate(&mut fb, prompt, n_new, 0.0, &mut r).unwrap()
            };
            // lane -> (text, tokens generated) for resident sequences
            let mut resident: Vec<Option<(Vec<u8>, usize)>> = vec![None; lanes];
            let mut checked = 0usize;
            for _ in 0..120 {
                let roll = rng.f64();
                if roll < 0.25 {
                    // admit into a free lane
                    if let Some(lane) = (0..lanes).find(|&l| resident[l].is_none()) {
                        let p = *rng.choose(&prompts);
                        be.reset_lane(lane);
                        resident[lane] = Some((p.to_vec(), 0));
                    }
                } else if roll < 0.32 {
                    // evict a random resident lane mid-flight
                    let lane = rng.below(lanes);
                    if resident[lane].take().is_some() {
                        be.reset_lane(lane);
                    }
                } else {
                    // one lock-step sweep over every resident lane
                    let idxs: Vec<usize> =
                        (0..lanes).filter(|&l| resident[l].is_some()).collect();
                    if idxs.is_empty() {
                        continue;
                    }
                    let rows = {
                        let reqs: Vec<(usize, &[u8])> = idxs
                            .iter()
                            .map(|&l| (l, resident[l].as_ref().unwrap().0.as_slice()))
                            .collect();
                        be.decode_batch(&reqs).map_err(|e| e.to_string())?
                    };
                    for (&l, row) in idxs.iter().zip(&rows) {
                        let (text, done) = resident[l].as_mut().unwrap();
                        text.push(greedy(row));
                        *done += 1;
                        if *done == n_new {
                            let prompt_len = text.len() - n_new;
                            let want = solo(&text[..prompt_len]);
                            if *text != want {
                                return Err(format!(
                                    "lane {l} diverged from flat solo run after {n_new} tokens"
                                ));
                            }
                            checked += 1;
                            resident[l] = None;
                            be.reset_lane(l);
                        }
                    }
                }
            }
            if checked == 0 {
                return Err("schedule finished no sequence — generator too timid".into());
            }
            Ok(())
        },
    );
}
