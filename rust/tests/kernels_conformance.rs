//! Kernel conformance: every compiled-in packed-GEMV kernel the host can
//! run (`scalar` always; AVX2/NEON when supported) is driven through the
//! same randomized `(words, x, j0, j1)` cases and pinned **bit-identical**
//! — to the scalar reference and to a naive per-bit implementation of the
//! canonical reduction order (see `pack::kernels` module docs). This is
//! the contract that lets the serving parity suites (`engine_parity`,
//! `spec_parity`, `prefix_parity`) hold whichever kernel dispatch picks.
//!
//! Also here: the cache-blocked multi-lane sweep is pinned against the
//! unblocked sweep and the per-lane GEMV at 1, 2, and 7 lanes, and the
//! `HBLLM_KERNEL=scalar` override is exercised in a child process.

use hbllm::engine::model::Linear;
use hbllm::pack::{kernels, BitMatrix, HaarPackedLinear};
use hbllm::tensor::Matrix;
use hbllm::util::rng::Pcg32;

/// The canonical reduction order, computed naively per bit: eight buckets
/// by absolute column index mod 8, filled in ascending-`j` order, reduced
/// left-to-right.
fn canonical_dot(words: &[u64], x: &[f32], j0: usize, j1: usize) -> f32 {
    let mut lanes = [0f32; 8];
    for j in j0..j1 {
        let bit = (words[j / 64] >> (j % 64)) & 1;
        lanes[j % 8] += if bit == 1 { x[j] } else { -x[j] };
    }
    let mut acc = 0f32;
    for l in lanes {
        acc += l;
    }
    acc
}

fn random_row(rng: &mut Pcg32, m: usize) -> (BitMatrix, Vec<f32>) {
    let mat = Matrix::from_fn(1, m, |_, _| {
        let v = rng.normal_f32();
        if v == 0.0 {
            1.0
        } else {
            v
        }
    });
    let bits = BitMatrix::from_signs(&mat);
    let x: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
    (bits, x)
}

#[test]
fn every_supported_kernel_is_bit_identical_to_scalar() {
    let scalar = kernels::all().iter().find(|k| k.name == "scalar").expect("scalar kernel");
    let mut rng = Pcg32::seeded(0x5eed);
    for case in 0..300 {
        let m = 1 + rng.below(320);
        let (bits, x) = random_row(&mut rng, m);
        let j0 = rng.below(m);
        let j1 = j0 + rng.below(m - j0 + 1);
        let words = bits.row_words(0);
        let want = scalar.dot_range(words, &x, j0, j1);
        // the scalar reference itself implements the canonical order
        let naive = canonical_dot(words, &x, j0, j1);
        assert_eq!(
            want.to_bits(),
            naive.to_bits(),
            "scalar diverged from the naive per-bit loop on [{j0},{j1}) of {m} (case {case})"
        );
        for k in kernels::all().iter().filter(|k| k.supported()) {
            let got = k.dot_range(words, &x, j0, j1);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "kernel {} diverged from scalar on [{j0},{j1}) of {m} (case {case}): \
                 {got} vs {want}",
                k.name
            );
        }
    }
}

#[test]
fn alignment_sweep_pins_kernels_across_byte_and_word_boundaries() {
    // exhaustive (j0, j1) window around the first u64 boundary: empty
    // ranges, sub-byte ranges, byte-straddling and word-straddling ranges
    // all included by construction
    let mut rng = Pcg32::seeded(0xa119);
    let m = 144;
    let (bits, x) = random_row(&mut rng, m);
    let words = bits.row_words(0);
    let supported: Vec<_> = kernels::all().iter().filter(|k| k.supported()).collect();
    assert!(!supported.is_empty());
    for j0 in 0..=80usize {
        for j1 in j0..=m.min(j0 + 80) {
            let want = canonical_dot(words, &x, j0, j1);
            for k in &supported {
                let got = k.dot_range(words, &x, j0, j1);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "kernel {} diverged on [{j0},{j1})",
                    k.name
                );
            }
        }
    }
}

#[test]
fn gemv_rows_lanes_blocked_matches_unblocked_at_1_2_7_lanes() {
    let mut rng = Pcg32::seeded(77);
    let (rows, m) = (33usize, 96usize);
    let w = Matrix::from_fn(rows, m, |_, _| rng.normal_f32());
    let p = HaarPackedLinear::from_dense(&w).unwrap();
    for &lanes in &[1usize, 2, 7] {
        let xs: Vec<Vec<f32>> = (0..lanes)
            .map(|_| (0..m).map(|_| rng.normal_f32()).collect())
            .collect();
        let mut z_all = vec![0.0f32; lanes * m];
        let mut sums = Vec::new();
        for (l, x) in xs.iter().enumerate() {
            sums.push(p.prepare_activation_slice(x, &mut z_all[l * m..(l + 1) * m]));
        }
        let run = |budget: usize| -> Vec<Vec<f32>> {
            let mut out: Vec<Vec<f32>> = (0..lanes).map(|_| vec![0.0; rows]).collect();
            let mut ys: Vec<&mut [f32]> = out.iter_mut().map(|y| y.as_mut_slice()).collect();
            p.gemv_rows_lanes_blocked(&z_all, &sums, 0, &mut ys, budget);
            out
        };
        // one block covering every row == the unblocked sweep
        let unblocked = run(usize::MAX);
        // tiny and mid-sized budgets force 1-row and few-row blocks;
        // blocking must only reorder the (row, lane) schedule, never the
        // arithmetic, so outputs are bit-identical
        for budget in [0usize, 1, 13, 64, 1 << 20] {
            assert_eq!(run(budget), unblocked, "lanes={lanes} budget={budget}");
        }
        // the production entry point (default L2 budget)
        let mut got: Vec<Vec<f32>> = (0..lanes).map(|_| vec![0.0; rows]).collect();
        {
            let mut ys: Vec<&mut [f32]> = got.iter_mut().map(|y| y.as_mut_slice()).collect();
            p.gemv_rows_lanes(&z_all, &sums, 0, &mut ys);
        }
        assert_eq!(got, unblocked, "lanes={lanes} default budget");
        // and the single-lane reference GEMV, lane by lane
        for (l, x) in xs.iter().enumerate() {
            let mut y = vec![0.0; rows];
            p.gemv(x, &mut y);
            assert_eq!(y, got[l], "lane {l} of {lanes} diverged from per-lane gemv");
        }
    }
}

#[test]
fn linear_gemv_batch_matches_per_lane_at_1_2_7_lanes() {
    let mut rng = Pcg32::seeded(78);
    let lin = Linear::Packed(
        HaarPackedLinear::from_dense(&Matrix::from_fn(19, 64, |_, _| rng.normal_f32())).unwrap(),
    );
    for &lanes in &[1usize, 2, 7] {
        let xs: Vec<Vec<f32>> = (0..lanes)
            .map(|_| (0..64).map(|_| rng.normal_f32()).collect())
            .collect();
        let mut want: Vec<Vec<f32>> = Vec::new();
        for x in &xs {
            let mut y = vec![0.0; 19];
            lin.gemv(x, &mut y, 1);
            want.push(y);
        }
        let mut got: Vec<Vec<f32>> = (0..lanes).map(|_| vec![0.0; 19]).collect();
        let mut io: Vec<(&[f32], &mut [f32])> = xs
            .iter()
            .zip(got.iter_mut())
            .map(|(x, y)| (x.as_slice(), y.as_mut_slice()))
            .collect();
        let mut z = Vec::new();
        lin.gemv_batch(&mut io, &mut z, 2);
        drop(io);
        assert_eq!(got, want, "{lanes}-lane gemv_batch diverged from per-lane gemv");
    }
}

/// `HBLLM_KERNEL=scalar` must force the scalar path. The selection is
/// cached per process, so the override is exercised in a child: this test
/// re-executes its own binary filtered to itself with the variable set,
/// and the child branch asserts what `active()` resolved to.
#[test]
fn hbllm_kernel_env_forces_scalar() {
    if std::env::var("HBLLM_KERNEL").as_deref() == Ok("scalar") {
        assert_eq!(kernels::active().name, "scalar");
        return;
    }
    let exe = std::env::current_exe().expect("test binary path");
    let out = std::process::Command::new(exe)
        .args(["hbllm_kernel_env_forces_scalar", "--exact", "--test-threads=1"])
        .env("HBLLM_KERNEL", "scalar")
        .output()
        .expect("spawn child test process");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success() && stdout.contains("1 passed"),
        "override child failed:\n{stdout}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
}
