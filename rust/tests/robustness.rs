//! Robustness + failure-injection tests (no artifacts needed): degenerate
//! inputs, adversarial weight shapes, and cross-method invariants.

use hbllm::quant::hbllm::{Hbllm, HbllmOpts, Variant};
use hbllm::quant::{by_name, synth, table_methods, HessianCtx, Quantizer};
use hbllm::tensor::Matrix;
use hbllm::util::proptest::{check, Gen};
use hbllm::util::rng::Pcg32;

fn all_methods() -> Vec<Box<dyn Quantizer>> {
    let mut v: Vec<Box<dyn Quantizer>> = table_methods()
        .into_iter()
        .map(|n| by_name(n).unwrap())
        .collect();
    v.push(by_name("rtn").unwrap());
    v
}

#[test]
fn zero_matrix_is_fixed_point_everywhere() {
    let w = Matrix::zeros(16, 64);
    let ctx = HessianCtx::identity(64);
    for q in all_methods() {
        let out = q.quantize(&w, &ctx);
        assert!(
            out.w_hat.data.iter().all(|v| v.abs() < 1e-5),
            "{}: zero matrix not preserved (max {})",
            q.name(),
            out.w_hat.max_abs()
        );
    }
}

#[test]
fn constant_matrix_reconstructs_exactly_for_mean_based_methods() {
    let w = Matrix::from_vec(8, 32, vec![0.7; 8 * 32]);
    let ctx = HessianCtx::identity(32);
    for name in ["rtn", "hbllm-row", "billm"] {
        let q = by_name(name).unwrap();
        let out = q.quantize(&w, &ctx);
        assert!(out.mse < 1e-6, "{name}: constant matrix mse {}", out.mse);
    }
}

#[test]
fn extreme_outliers_do_not_produce_nan() {
    let mut rng = Pcg32::seeded(1);
    let mut w = Matrix::from_fn(32, 128, |_, _| rng.normal_f32() * 1e-3);
    w.set(3, 77, 1e6);
    w.set(17, 2, -1e6);
    let ctx = HessianCtx::identity(128);
    for q in all_methods() {
        let out = q.quantize(&w, &ctx);
        assert!(
            out.w_hat.data.iter().all(|v| v.is_finite()),
            "{}: non-finite output under extreme outliers",
            q.name()
        );
    }
}

#[test]
fn tiny_shapes_do_not_panic() {
    // shapes smaller than block/group sizes, odd rows, 2 columns
    let ctx2 = HessianCtx::identity(2);
    let ctx4 = HessianCtx::identity(4);
    for q in all_methods() {
        for (n, m, ctx) in [(1usize, 2usize, &ctx2), (3, 4, &ctx4), (2, 2, &ctx2)] {
            let mut rng = Pcg32::seeded(7);
            let w = Matrix::from_fn(n, m, |_, _| rng.normal_f32());
            let out = q.quantize(&w, ctx);
            assert_eq!((out.w_hat.rows, out.w_hat.cols), (n, m), "{}", q.name());
        }
    }
}

#[test]
fn prop_hbllm_error_bounded_by_signal() {
    // 1-bit mean-centred binarization can never exceed the centred signal
    // energy by much; catches sign/scale bugs under random shapes
    check(
        "hbllm-bounded",
        12,
        |g: &mut Gen| {
            let n = 2 * g.size(2, 12);
            let m = 2 * g.size(4, 40);
            (n, m, g.rng.next_u64())
        },
        |&(n, m, seed)| {
            let (w, ctx) = synth::llm_like_layer(n, m, seed);
            let q = Hbllm::with_opts(
                Variant::Row,
                HbllmOpts { beta: 32, n_candidates: 8, ..Default::default() },
            );
            let out = q.quantize(&w, &ctx);
            let sig = w.frob_norm().powi(2) / (w.rows * w.cols) as f64;
            if out.mse <= sig * 4.0 {
                Ok(())
            } else {
                Err(format!("mse {} vs signal {}", out.mse, sig))
            }
        },
    );
}

#[test]
fn prop_wbits_monotone_in_shape() {
    // per-weight overhead must shrink as matrices grow (amortization)
    check(
        "wbits-amortize",
        10,
        |g: &mut Gen| 128 * (1 + g.size(1, 8)),
        |&d| {
            let small = Hbllm::row().avg_wbits(d, d);
            let large = Hbllm::row().avg_wbits(4 * d, 4 * d);
            if large <= small + 1e-9 {
                Ok(())
            } else {
                Err(format!("wbits grew: {small} -> {large}"))
            }
        },
    );
}

#[test]
fn hessian_outlier_shifts_salient_choice() {
    // inject a huge activation spike on one column: that column must be
    // reconstructed more accurately than under identity hessian
    let n = 32;
    let m = 64;
    let mut rng = Pcg32::seeded(3);
    let w = Matrix::from_fn(n, m, |_, _| rng.normal_f32());
    let spiked = {
        use hbllm::tensor::linalg::Sq;
        let mut h = Sq::zeros(m);
        h.add_diag(1.0);
        h.set(13, 13, 1e4); // column 13 matters enormously
        HessianCtx::new(h, 0.01).unwrap()
    };
    let ident = HessianCtx::identity(m);
    let q = Hbllm::row();
    let col_err = |out: &Matrix| -> f64 {
        (0..n)
            .map(|i| ((w.get(i, 13) - out.get(i, 13)) as f64).powi(2))
            .sum()
    };
    let e_spiked = col_err(&q.quantize(&w, &spiked).w_hat);
    let e_ident = col_err(&q.quantize(&w, &ident).w_hat);
    assert!(
        e_spiked <= e_ident * 1.5,
        "hessian saliency ignored: spiked {e_spiked} vs ident {e_ident}"
    );
}
