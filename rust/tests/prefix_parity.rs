//! Shared-prefix decode parity, end to end (no artifacts needed).
//!
//! Prefix sharing changes only which arena blocks a lane's table points
//! at — never the attention arithmetic: a lane that adopts a donor's
//! prefix blocks read-only ([`Backend::kv_adopt_prefix`]) must decode
//! byte-identically to a lane that prefilled the same text from scratch,
//! and the donor must be unperturbed by the adopter's copy-on-write
//! clones. These tests pin that across block geometries (divisor and
//! non-divisor block lengths, whole-block and mid-block divergence
//! points), through a window slide on the adopted lane, and on the
//! speculative-decode path, with the arena asserted leak-free after
//! every scenario.

use hbllm::engine::{self, Backend, NativeBackend, PackedModel, SpecConfig};
use hbllm::model::testing::synth_weights;
use hbllm::util::rng::Pcg32;

const SEED: u64 = 77;

/// Shared test model: multiple heads, seq crossing several blocks,
/// artifact-free and fast (same shape as the paged parity suite).
fn model() -> hbllm::model::Weights {
    synth_weights(SEED, 32, 2, 4, 64, 16)
}

/// A packed-engine backend with `lanes` lanes and an explicit paged-KV
/// geometry.
fn backend(lanes: usize, n_blocks: usize, block_len: usize) -> NativeBackend {
    let w = model();
    let mut be = NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
    be.set_lanes(lanes);
    be.set_kv_blocks(Some(n_blocks), Some(block_len));
    be
}

fn greedy(row: &[f32]) -> u8 {
    engine::sample_logits(row, 0.0, &mut Pcg32::seeded(0)) as u8
}

/// Greedily extend `text` by `n_new` bytes on `lane` via lock-step
/// decode sweeps (the engine prefills whatever `text` holds beyond the
/// lane's KV fill level, so this drives fresh, adopted, and sliding
/// lanes alike).
fn decode_greedy(be: &mut NativeBackend, lane: usize, text: &mut Vec<u8>, n_new: usize) {
    for _ in 0..n_new {
        let rows = be.decode_batch(&[(lane, text.as_slice())]).unwrap();
        text.push(greedy(&rows[0]));
    }
}

/// From-scratch reference: the same prompt decoded greedily on a fresh
/// backend of the same geometry, no sharing involved.
fn from_scratch(n_blocks: usize, block_len: usize, prompt: &[u8], n_new: usize) -> Vec<u8> {
    let mut be = backend(1, n_blocks, block_len);
    be.reset_lane(0);
    let mut text = prompt.to_vec();
    decode_greedy(&mut be, 0, &mut text, n_new);
    text
}

fn assert_drained(be: &NativeBackend, ctx: &str) {
    let st = be.kv_stats().unwrap();
    assert_eq!(st.free_blocks, st.total_blocks, "{ctx}: arena leaked blocks");
    assert_eq!(st.shared_blocks, 0, "{ctx}: stale shared refcounts");
}

/// Adopted-prefix decode is byte-identical to from-scratch prefill
/// across block geometries, for both a whole-block and a mid-block
/// divergence point, with the donor's own continuation unperturbed by
/// the adopter's copy-on-write traffic.
#[test]
fn adopted_prefix_decode_matches_from_scratch_across_geometries() {
    let seq = model().config.seq_len;
    for bl in [4usize, 3, 11, 16] {
        let per_lane = (seq + bl - 1) / bl;
        let n_blocks = 2 * per_lane;
        let mut be = backend(2, n_blocks, bl);

        // donor: lane 0 decodes 4 tokens past an 8-byte prompt
        let mut donor = b"ta kivo ".to_vec();
        be.reset_lane(0);
        decode_greedy(&mut be, 0, &mut donor, 4);

        // m = 8 diverges at the prompt boundary (a whole-block edge for
        // bl = 4); m = 7 diverges mid-block in every geometry here
        for m in [8usize, 7] {
            let blocks = be
                .kv_retain_prefix(0, m)
                .expect("donor lane holds the prefix");
            assert!(be.kv_adopt_prefix(1, &blocks, m, &donor[..m]), "adoption refused");
            let mut got = donor[..m].to_vec();
            got.extend_from_slice(b"vo");
            decode_greedy(&mut be, 1, &mut got, 4);

            let mut want = donor[..m].to_vec();
            want.extend_from_slice(b"vo");
            let want = from_scratch(n_blocks, bl, &want, 4);
            assert_eq!(
                got, want,
                "adopted lane diverged from scratch (bl={bl}, m={m})"
            );
            be.kv_release_blocks(&blocks);
            be.reset_lane(1);
        }

        // the donor keeps decoding over its (previously shared) blocks:
        // adopter COW clones must never have touched the originals
        decode_greedy(&mut be, 0, &mut donor, 2);
        let want_donor = from_scratch(n_blocks, bl, b"ta kivo ", 6);
        assert_eq!(donor, want_donor, "donor perturbed by adopters (bl={bl})");

        be.reset_lane(0);
        assert_drained(&be, &format!("bl={bl}"));
    }
}

/// An adopted lane generating past `seq_len` slides its window (the
/// forced re-prefill releases the shared blocks mid-flight) and must
/// still match the from-scratch run of the same prompt through the
/// slide.
#[test]
fn adopted_lane_survives_window_slide_byte_identically() {
    let seq = model().config.seq_len;
    let (bl, n_blocks) = (4usize, 2 * ((seq + 3) / 4));
    let mut be = backend(2, n_blocks, bl);

    let mut donor = b"ta kivo ".to_vec();
    be.reset_lane(0);
    decode_greedy(&mut be, 0, &mut donor, 4);

    let blocks = be.kv_retain_prefix(0, 8).unwrap();
    assert!(be.kv_adopt_prefix(1, &blocks, 8, &donor[..8]));
    let mut got = donor[..8].to_vec();
    got.extend_from_slice(b"xy");
    // 10-byte prompt + 10 tokens crosses seq_len 16: the window slides
    decode_greedy(&mut be, 1, &mut got, 10);

    let mut prompt = donor[..8].to_vec();
    prompt.extend_from_slice(b"xy");
    let want = from_scratch(n_blocks, bl, &prompt, 10);
    assert_eq!(got, want, "window slide over an adopted prefix diverged");

    be.kv_release_blocks(&blocks);
    be.reset_lane(0);
    be.reset_lane(1);
    assert_drained(&be, "window slide");
}

/// Speculative decoding over an adopted prefix: the draft/verify rounds
/// run with the lane's leading blocks mapped read-only, and the
/// committed bytes equal the plain greedy from-scratch run (spec is
/// byte-identical by construction; sharing must not break that).
#[test]
fn spec_decode_over_shared_prefix_matches_plain_reference() {
    let seq = model().config.seq_len;
    let (bl, n_blocks) = (4usize, 2 * ((seq + 3) / 4));
    let mut be = backend(2, n_blocks, bl);
    let spec = be.set_spec(SpecConfig::with_k(3));
    assert!(spec.enabled, "native backend lost its draft path");

    let mut donor = b"ta kivo ".to_vec();
    be.reset_lane(0);
    decode_greedy(&mut be, 0, &mut donor, 4);

    let blocks = be.kv_retain_prefix(0, 8).unwrap();
    assert!(be.kv_adopt_prefix(1, &blocks, 8, &donor[..8]));
    let mut got = donor[..8].to_vec();
    got.extend_from_slice(b"vo");
    let n_new = 5usize;
    let mut remaining = n_new;
    while remaining > 0 {
        // the scheduler's clamp: never draft past the remaining budget
        let k = spec.k.min(remaining.saturating_sub(1));
        let rounds = be.decode_batch_spec(&[(1, got.as_slice())], k).unwrap();
        assert!(!rounds[0].bytes.is_empty(), "spec round committed nothing");
        got.extend_from_slice(&rounds[0].bytes);
        remaining -= rounds[0].bytes.len();
    }

    let mut prompt = donor[..8].to_vec();
    prompt.extend_from_slice(b"vo");
    let want = from_scratch(n_blocks, bl, &prompt, n_new);
    assert_eq!(got, want, "speculative decode over shared prefix diverged");

    be.kv_release_blocks(&blocks);
    be.reset_lane(0);
    be.reset_lane(1);
    assert_drained(&be, "spec over shared prefix");
}
