//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute many.
//!
//! Python never runs here — `make artifacts` produced HLO *text* (the
//! xla_extension-0.5.1-safe interchange; see DESIGN.md) and this module
//! feeds it to the PJRT CPU client via the `xla` crate.
//!
//! Weight tensors are uploaded once as device buffers (`execute_b`), so the
//! per-batch hot path only moves the token array — the §Perf L3 fix.

use crate::model::{Tensor, Weights};
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

pub struct Runtime {
    pub client: xla::PjRtClient,
    root: PathBuf,
}

impl Runtime {
    /// `root` is the artifacts directory (contains manifest.json, hlo/).
    pub fn new(root: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime { client, root: root.to_path_buf() })
    }

    pub fn artifacts_root(&self) -> &Path {
        &self.root
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text artifact (path relative to the artifacts root).
    pub fn load(&self, rel: &str) -> Result<Executable> {
        let path = self.root.join(rel);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compiling {rel}"))?;
        Ok(Executable { exe, name: rel.to_string() })
    }
}

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with host literals; returns the elements of the result tuple.
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Upload literals to device buffers once (for weight residency).
    pub fn buffers(&self, args: &[xla::Literal]) -> Result<Vec<xla::PjRtBuffer>> {
        let client = self.exe.client();
        args.iter()
            .map(|l| {
                client
                    .buffer_from_host_literal(None, l)
                    .map_err(|e| anyhow!("buffer upload: {e}"))
            })
            .collect()
    }

    /// Execute with pre-uploaded device buffers.
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute_b(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }
}

/// Convert a model tensor to an XLA literal with its natural shape.
pub fn tensor_literal(t: &Tensor) -> Result<xla::Literal> {
    match t {
        Tensor::Vec1(v) => Ok(xla::Literal::vec1(v)),
        Tensor::Mat(m) => xla::Literal::vec1(&m.data)
            .reshape(&[m.rows as i64, m.cols as i64])
            .map_err(|e| anyhow!("reshape: {e}")),
    }
}

/// Token batch literal: i32 [batch, seq].
pub fn tokens_literal(tokens: &[i32], batch: usize, seq: usize) -> Result<xla::Literal> {
    assert_eq!(tokens.len(), batch * seq);
    xla::Literal::vec1(tokens)
        .reshape(&[batch as i64, seq as i64])
        .map_err(|e| anyhow!("reshape tokens: {e}"))
}

/// The NLL evaluation entry point with device-resident weights.
pub struct NllRunner {
    exe: Executable,
    weight_bufs: Vec<xla::PjRtBuffer>,
    /// The CPU PJRT client's buffer_from_host_literal may alias host
    /// memory, so the literals must outlive the buffers.
    _weight_lits: Vec<xla::Literal>,
    pub batch: usize,
    pub seq: usize,
}

impl NllRunner {
    /// `entry` is e.g. "hlo/nll_tiny.hlo.txt"; weights are uploaded once.
    pub fn new(rt: &Runtime, entry: &str, weights: &Weights, batch: usize) -> Result<NllRunner> {
        let exe = rt.load(entry)?;
        let lits: Vec<xla::Literal> = weights
            .flat_in_order()
            .iter()
            .map(|t| tensor_literal(t))
            .collect::<Result<_>>()?;
        let weight_bufs = exe.buffers(&lits)?;
        Ok(NllRunner {
            exe,
            weight_bufs,
            _weight_lits: lits,
            batch,
            seq: weights.config.seq_len,
        })
    }

    /// Per-position NLL for a [batch, seq] token batch: returns
    /// batch × (seq−1) values, row-major.
    pub fn nll(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let tok_lit = tokens_literal(tokens, self.batch, self.seq)?;
        let tok_buf = self.exe.buffers(std::slice::from_ref(&tok_lit))?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weight_bufs.len());
        args.push(&tok_buf[0]);
        args.extend(self.weight_bufs.iter());
        let out = self.exe.run_b(&args)?;
        let nll = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("empty result tuple"))?;
        Ok(nll.to_vec::<f32>()?)
    }

    /// Run the underlying entry point but interpret the tuple's first
    /// element with an arbitrary shape (used by `LogitsRunner`).
    fn run_raw(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        let tok_lit = tokens_literal(tokens, self.batch, self.seq)?;
        let tok_buf = self.exe.buffers(std::slice::from_ref(&tok_lit))?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weight_bufs.len());
        args.push(&tok_buf[0]);
        args.extend(self.weight_bufs.iter());
        let out = self.exe.run_b(&args)?;
        let first = out
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("empty result tuple"))?;
        Ok(first.to_vec::<f32>()?)
    }

    /// Swap the device-resident weights (after quantization).
    pub fn set_weights(&mut self, weights: &Weights) -> Result<()> {
        let lits: Vec<xla::Literal> = weights
            .flat_in_order()
            .iter()
            .map(|t| tensor_literal(t))
            .collect::<Result<_>>()?;
        self.weight_bufs = self.exe.buffers(&lits)?;
        self._weight_lits = lits;
        Ok(())
    }
}

/// Full-logits entry point (generation): logits f32[B, S, V].
pub struct LogitsRunner {
    inner: NllRunner,
    pub vocab: usize,
}

impl LogitsRunner {
    pub fn new(rt: &Runtime, entry: &str, weights: &Weights, batch: usize) -> Result<LogitsRunner> {
        let inner = NllRunner::new(rt, entry, weights, batch)?;
        Ok(LogitsRunner { vocab: weights.config.vocab, inner })
    }

    pub fn batch(&self) -> usize {
        self.inner.batch
    }

    pub fn seq(&self) -> usize {
        self.inner.seq
    }

    /// logits for a [batch, seq] token array: batch × seq × vocab floats.
    pub fn logits(&self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.inner.run_raw(tokens)
    }

    /// Greedy/temperature generation by iterative re-forward (no KV cache —
    /// the AOT module has a fixed shape; `engine::NativeBackend` is the
    /// KV-cached path). An empty prompt is seeded with the pad byte so the
    /// window always has a position to condition on.
    pub fn generate(
        &self,
        prompt: &[u8],
        n_new: usize,
        temperature: f32,
        rng: &mut crate::util::rng::Pcg32,
    ) -> Result<Vec<u8>> {
        let (b, s, v) = (self.inner.batch, self.inner.seq, self.vocab);
        let mut text: Vec<u8> = prompt.to_vec();
        if text.is_empty() {
            text.push(crate::data::ByteTokenizer::PAD);
        }
        for _ in 0..n_new {
            let start = text.len().saturating_sub(s - 1);
            let window = &text[start..];
            let pos = window.len() - 1;
            let mut tokens = vec![crate::data::ByteTokenizer::PAD as i32; b * s];
            for (c, &byte) in window.iter().enumerate() {
                tokens[c] = byte as i32;
            }
            let logits = self.logits(&tokens)?;
            let row = &logits[pos * v..(pos + 1) * v];
            let next = crate::engine::sample_logits(row, temperature, rng);
            text.push(next as u8);
        }
        Ok(text)
    }
}
