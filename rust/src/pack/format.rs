//! On-disk format for HBLLM-quantized models (the deployment artifact):
//! packed Haar-domain sign bits + per-row per-band (α, μ) in fp16, plus the
//! untouched fp32 side tensors (embeddings, norms, head).
//!
//! Layout ("HBQ1", all little-endian):
//!   u32 magic, u32 version
//!   u32 n_records
//!   per record:
//!     u16 name_len, name bytes
//!     u8  kind (0 = fp32 dense, 1 = haar-packed 1-bit)
//!     u32 rows, u32 cols
//!     kind 0: rows*cols f32
//!     kind 1: rows*2 f16 alpha, rows*2 f16 mu, ceil(cols/64)*rows u64 signs
//!
//! Scale/mean parameters are genuinely stored at fp16, so a saved+loaded
//! model measures the true cost of the paper's storage budget (tests check
//! the roundtrip error against the fp16 quantization step).
//!
//! The byte-level specification (field semantics, invariants, storage
//! accounting) lives in `docs/FORMAT.md` at the repository root — keep the
//! two in sync when bumping `VERSION`.

use super::{BitMatrix, HaarPackedLinear};
use crate::model::{Tensor, Weights};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::Path;

pub const MAGIC: u32 = 0x48425131; // "HBQ1"
pub const VERSION: u32 = 1;

/// Minimal f32 -> IEEE 754 half conversion (round-to-nearest-even).
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let mut exp = ((bits >> 23) & 0xff) as i32 - 127 + 15;
    let mut mant = bits & 0x7fffff;
    if exp <= 0 {
        // subnormal / underflow
        if exp < -10 {
            return sign;
        }
        mant |= 0x800000;
        let shift = (14 - exp) as u32;
        let half = 1u32 << (shift - 1);
        let rounded = (mant + half) >> shift;
        return sign | rounded as u16;
    }
    if exp >= 0x1f {
        return sign | 0x7c00; // inf
    }
    // round mantissa to 10 bits
    let mant10 = mant >> 13;
    let rem = mant & 0x1fff;
    let mut out = sign | ((exp as u16) << 10) | mant10 as u16;
    if rem > 0x1000 || (rem == 0x1000 && (mant10 & 1) == 1) {
        out = out.wrapping_add(1);
        if out & 0x7c00 == 0x7c00 {
            out = sign | 0x7c00;
        }
        let _ = &mut exp;
    }
    out
}

pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if mant == 0 {
            sign
        } else {
            // subnormal: value = mant × 2⁻²⁴
            let v = mant as f32 * (1.0 / 16777216.0);
            let vb = v.to_bits() | sign;
            return f32::from_bits(vb);
        }
    } else if exp == 0x1f {
        sign | 0x7f800000 | (mant << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (mant << 13)
    };
    f32::from_bits(bits)
}

/// A record: either a raw fp32 tensor or a packed 1-bit layer.
pub enum Record {
    Dense { rows: usize, cols: usize, data: Vec<f32> },
    Packed(HaarPackedLinear),
}

pub struct PackedModel {
    pub records: Vec<(String, Record)>,
}

impl PackedModel {
    /// Pack a quantized `Weights`: linear layers become Haar-packed 1-bit
    /// records (refit from their dequantized values), everything else dense.
    pub fn from_weights(w: &Weights) -> PackedModel {
        let linear: std::collections::BTreeSet<String> =
            w.config.linear_names().into_iter().collect();
        let mut records = Vec::new();
        for name in &w.config.param_order {
            let rec = match w.get(name) {
                Tensor::Vec1(v) => Record::Dense { rows: 1, cols: v.len(), data: v.clone() },
                Tensor::Mat(m) => {
                    if linear.contains(name) {
                        // paper orientation for packing; a linear whose
                        // packed width would be odd has no Haar band split
                        // (`OddWidth`) — store it dense rather than
                        // silently truncating its last column
                        match HaarPackedLinear::from_dense(&m.transpose()) {
                            Ok(p) => Record::Packed(p),
                            Err(_) => {
                                Record::Dense { rows: m.rows, cols: m.cols, data: m.data.clone() }
                            }
                        }
                    } else {
                        Record::Dense { rows: m.rows, cols: m.cols, data: m.data.clone() }
                    }
                }
            };
            records.push((name.clone(), rec));
        }
        PackedModel { records }
    }

    /// Serialize to the HBQ1 byte image. Deterministic: the same records
    /// always produce the same bytes, and `from_bytes` ∘ `to_bytes` is the
    /// identity on the byte image (fuzz-tested below) — alpha/mu are
    /// already fp16-quantized by the first save, so a load/save cycle
    /// cannot drift.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(&MAGIC.to_le_bytes());
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.records.len() as u32).to_le_bytes());
        for (name, rec) in &self.records {
            buf.extend_from_slice(&(name.len() as u16).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            match rec {
                Record::Dense { rows, cols, data } => {
                    buf.push(0);
                    buf.extend_from_slice(&(*rows as u32).to_le_bytes());
                    buf.extend_from_slice(&(*cols as u32).to_le_bytes());
                    for v in data {
                        buf.extend_from_slice(&v.to_le_bytes());
                    }
                }
                Record::Packed(p) => {
                    buf.push(1);
                    let (rows, cols) = (p.bits.rows, p.bits.cols);
                    buf.extend_from_slice(&(rows as u32).to_le_bytes());
                    buf.extend_from_slice(&(cols as u32).to_le_bytes());
                    for i in 0..rows {
                        for b in 0..2 {
                            buf.extend_from_slice(&f32_to_f16_bits(p.alpha[i][b]).to_le_bytes());
                        }
                    }
                    for i in 0..rows {
                        for b in 0..2 {
                            buf.extend_from_slice(&f32_to_f16_bits(p.mu[i][b]).to_le_bytes());
                        }
                    }
                    for i in 0..rows {
                        for w64 in p.bits.row_words(i) {
                            buf.extend_from_slice(&w64.to_le_bytes());
                        }
                    }
                }
            }
        }
        buf
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let buf = self.to_bytes();
        let mut f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<PackedModel> {
        let raw = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_bytes(&raw)
    }

    /// Parse an HBQ1 byte image. Corrupt input — truncation, a bad magic
    /// or version, an unknown record kind, or a record whose declared
    /// shape runs past the end of the buffer — returns `Err`; it never
    /// panics, and every allocation sized by a header field is bounded by
    /// the buffer's own size plus a small constant (payload lengths are
    /// validated against the remaining bytes *before* any allocation and
    /// the record-table reservation is capped, so a bit-flipped
    /// `rows`/`cols`/record count cannot trigger a multi-gigabyte `Vec`
    /// reservation).
    pub fn from_bytes(raw: &[u8]) -> Result<PackedModel> {
        let mut i = 0usize;
        let take = |i: &mut usize, n: usize| -> Result<&[u8]> {
            if *i + n > raw.len() {
                bail!("truncated packed model at byte {i:?}");
            }
            let s = &raw[*i..*i + n];
            *i += n;
            Ok(s)
        };
        let u32_at = |i: &mut usize| -> Result<u32> {
            let s = take(i, 4)?;
            Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
        };
        if u32_at(&mut i)? != MAGIC {
            bail!("bad magic");
        }
        if u32_at(&mut i)? != VERSION {
            bail!("unsupported version");
        }
        let n = u32_at(&mut i)? as usize;
        // a record is at least 11 bytes (name_len + kind + rows + cols), so
        // a corrupt count larger than the buffer could hold must fail here
        // — not inside a Vec::with_capacity reservation
        if (n as u64) * 11 > (raw.len() - i) as u64 {
            bail!("truncated packed model: {n} records claimed in {} bytes", raw.len());
        }
        // cap the up-front reservation: `n` is attacker-controlled (only
        // loosely bounded by the check above), and real models have tens
        // of records, not thousands
        let mut records = Vec::with_capacity(n.min(1024));
        for _ in 0..n {
            let nl = {
                let s = take(&mut i, 2)?;
                u16::from_le_bytes([s[0], s[1]]) as usize
            };
            let name = String::from_utf8_lossy(take(&mut i, nl)?).into_owned();
            let kind = take(&mut i, 1)?[0];
            let rows = u32_at(&mut i)? as usize;
            let cols = u32_at(&mut i)? as usize;
            // validate the declared payload against the remaining bytes
            // before allocating anything sized by rows/cols; checked math
            // — rows*cols*4 can wrap u64 for crafted u32 pairs, which
            // would sneak a tiny "payload" past the length check
            let payload: u64 = match kind {
                0 => match (rows as u64)
                    .checked_mul(cols as u64)
                    .and_then(|p| p.checked_mul(4))
                {
                    Some(p) => p,
                    None => bail!(
                        "corrupt packed model: record {name:?} claims {rows}x{cols} elements"
                    ),
                },
                1 => {
                    let wpr = (cols as u64 + 63) / 64;
                    // alpha + mu (rows × 2 bands × 2 bytes each) + signs;
                    // bounded: rows, cols < 2^32 so rows*wpr*8 < 2^62
                    (rows as u64) * 8 + (rows as u64) * wpr * 8
                }
                k => bail!("unknown record kind {k}"),
            };
            if payload > (raw.len() - i) as u64 {
                bail!(
                    "truncated packed model: record {name:?} claims {payload} payload bytes \
                     with {} left",
                    raw.len() - i
                );
            }
            let rec = match kind {
                0 => {
                    let mut data = Vec::with_capacity(rows * cols);
                    for _ in 0..rows * cols {
                        let s = take(&mut i, 4)?;
                        data.push(f32::from_le_bytes([s[0], s[1], s[2], s[3]]));
                    }
                    Record::Dense { rows, cols, data }
                }
                1 => {
                    let mut alpha = Vec::with_capacity(rows);
                    for _ in 0..rows {
                        let mut ab = [0f32; 2];
                        for b in ab.iter_mut() {
                            let s = take(&mut i, 2)?;
                            *b = f16_bits_to_f32(u16::from_le_bytes([s[0], s[1]]));
                        }
                        alpha.push(ab);
                    }
                    let mut mu = Vec::with_capacity(rows);
                    for _ in 0..rows {
                        let mut ub = [0f32; 2];
                        for b in ub.iter_mut() {
                            let s = take(&mut i, 2)?;
                            *b = f16_bits_to_f32(u16::from_le_bytes([s[0], s[1]]));
                        }
                        mu.push(ub);
                    }
                    let wpr = (cols + 63) / 64;
                    let mut bits = BitMatrix::zeros(rows, cols);
                    for r in 0..rows {
                        for wi in 0..wpr {
                            let s = take(&mut i, 8)?;
                            let word = u64::from_le_bytes([
                                s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
                            ]);
                            for bit in 0..64 {
                                let j = wi * 64 + bit;
                                if j < cols && (word >> bit) & 1 == 1 {
                                    bits.set(r, j, true);
                                }
                            }
                        }
                    }
                    // validated assembly: an odd `cols` (crafted or
                    // bit-flipped) must fail the load, not produce a layer
                    // whose GEMV ignores its last column
                    match HaarPackedLinear::from_parts(bits, alpha, mu) {
                        Ok(p) => Record::Packed(p),
                        Err(e) => bail!("corrupt packed model: record {name:?}: {e}"),
                    }
                }
                k => bail!("unknown record kind {k}"),
            };
            records.push((name, rec));
        }
        Ok(PackedModel { records })
    }

    pub fn file_bits_per_linear_weight(&self) -> f64 {
        let mut bits = 0f64;
        let mut elems = 0f64;
        for (_, rec) in &self.records {
            if let Record::Packed(p) = rec {
                bits += (p.bits.storage_bytes() * 8) as f64 + (p.bits.rows * 2 * 2 * 16) as f64;
                elems += (p.bits.rows * p.bits.cols) as f64;
            }
        }
        if elems == 0.0 {
            0.0
        } else {
            bits / elems
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg32;

    /// A random model: dense and packed records of random shapes (packed
    /// cols even, spanning one or more sign words), finite values.
    fn arb_model(seed: u64, max_records: usize) -> PackedModel {
        let mut rng = Pcg32::seeded(seed);
        let n = rng.below(max_records + 1);
        let mut records = Vec::new();
        for ri in 0..n {
            let name: String = (0..rng.below(12))
                .map(|_| (b'a' + rng.below(26) as u8) as char)
                .collect();
            let rec = if rng.f64() < 0.5 {
                let (rows, cols) = (1 + rng.below(4), 1 + rng.below(9));
                let data = (0..rows * cols).map(|_| rng.normal_f32()).collect();
                Record::Dense { rows, cols, data }
            } else {
                let rows = 1 + rng.below(5);
                let cols = 2 * (1 + rng.below(40)); // even; up to 80 > one word
                let w = Matrix::from_fn(rows, cols, |_, _| rng.normal_f32() * 0.1);
                Record::Packed(HaarPackedLinear::from_dense(&w).unwrap())
            };
            records.push((format!("{name}{ri}"), rec));
        }
        PackedModel { records }
    }

    fn roundtrip_case(seed: u64, max_records: usize) -> Result<(), String> {
        let m = arb_model(seed, max_records);
        let b1 = m.to_bytes();
        let back = PackedModel::from_bytes(&b1).map_err(|e| format!("load failed: {e}"))?;
        let b2 = back.to_bytes();
        if b1 == b2 {
            Ok(())
        } else {
            Err(format!("re-save differs: {} vs {} bytes", b1.len(), b2.len()))
        }
    }

    fn corruption_case(seed: u64, max_records: usize) -> Result<(), String> {
        let bytes = arb_model(seed, max_records).to_bytes();
        let mut rng = Pcg32::seeded(seed ^ 0x9e3779b9);
        // every strict prefix must fail loudly (records are sized exactly,
        // so a cut always lands mid-record or mid-header): sample cuts
        // plus the header boundaries
        let mut cuts: Vec<usize> = (0..12).map(|_| rng.below(bytes.len())).collect();
        cuts.extend([0, 4, 8, bytes.len() - 1]);
        for cut in cuts {
            if PackedModel::from_bytes(&bytes[..cut]).is_ok() {
                return Err(format!("truncation at {cut}/{} accepted", bytes.len()));
            }
        }
        // single-bit flips must never panic (the property under test is
        // "no panic / no huge allocation"); flips inside magic or version
        // must additionally be rejected
        for _ in 0..16 {
            let pos = rng.below(bytes.len());
            let mut bad = bytes.clone();
            bad[pos] ^= 1u8 << rng.below(8);
            let res = PackedModel::from_bytes(&bad);
            if pos < 8 && res.is_ok() {
                return Err(format!("corrupt header accepted (byte {pos})"));
            }
        }
        Ok(())
    }

    #[test]
    fn fuzz_save_load_save_byte_identical() {
        check(
            "hbq1-roundtrip",
            30,
            |g| (g.rng.next_u64(), g.size(0, 5)),
            |&(seed, maxr)| roundtrip_case(seed, maxr),
        );
    }

    #[test]
    fn fuzz_truncated_and_bitflipped_inputs_error_not_panic() {
        check(
            "hbq1-corruption",
            30,
            |g| (g.rng.next_u64(), g.size(1, 4)),
            |&(seed, maxr)| corruption_case(seed, maxr),
        );
    }

    #[test]
    #[ignore = "slow: run via cargo test --release -- --ignored"]
    fn fuzz_hbq1_heavy() {
        check(
            "hbq1-roundtrip-heavy",
            150,
            |g| (g.rng.next_u64(), g.size(0, 10)),
            |&(seed, maxr)| roundtrip_case(seed, maxr),
        );
        check(
            "hbq1-corruption-heavy",
            150,
            |g| (g.rng.next_u64(), g.size(1, 8)),
            |&(seed, maxr)| corruption_case(seed, maxr),
        );
    }

    #[test]
    fn corrupt_shape_fields_fail_without_allocating() {
        // a dense record claiming 2^32-ish elements in a tiny file must be
        // rejected by the payload check, not die reserving gigabytes
        let model = PackedModel {
            records: vec![(
                "w".into(),
                Record::Dense { rows: 1, cols: 2, data: vec![1.0, 2.0] },
            )],
        };
        let mut bytes = model.to_bytes();
        // record starts at 12: name_len(2) + name(1) + kind(1) => rows u32 at 16
        bytes[16..20].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = PackedModel::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // same for a packed record's cols
        let mut bytes = model.to_bytes();
        bytes[15] = 1; // kind -> packed
        bytes[20..24].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = PackedModel::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        // rows*cols*4 wrapping u64 to 0 must not bypass the length check
        // (0x8000_0000^2 * 4 == 2^64): Err, not a capacity-overflow panic
        let mut bytes = model.to_bytes();
        bytes[16..20].copy_from_slice(&0x8000_0000u32.to_le_bytes());
        bytes[20..24].copy_from_slice(&0x8000_0000u32.to_le_bytes());
        let err = PackedModel::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("corrupt"), "{err}");
    }

    #[test]
    fn odd_cols_packed_record_is_rejected_at_load() {
        // cols 2 -> 3 keeps words_per_row (and thus the declared payload)
        // unchanged, so the record passes every length check and must be
        // caught by the typed `OddWidth` validation in `from_parts`
        let p = HaarPackedLinear::from_parts(
            BitMatrix::zeros(1, 2),
            vec![[0.0f32; 2]],
            vec![[0.0f32; 2]],
        )
        .unwrap();
        let model = PackedModel { records: vec![("w".into(), Record::Packed(p))] };
        let mut bytes = model.to_bytes();
        // record starts at 12: name_len(2) + name(1) + kind(1) + rows(4)
        // => cols u32 at byte 20
        bytes[20..24].copy_from_slice(&3u32.to_le_bytes());
        let err = PackedModel::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("even input width"), "{err}");
    }

    #[test]
    fn f16_roundtrip_values() {
        for v in [0.0f32, 1.0, -1.0, 0.5, 65504.0, 1e-4, -3.1415926, 0.099975586] {
            let back = f16_bits_to_f32(f32_to_f16_bits(v));
            let tol = (v.abs() * 1e-3).max(1e-7);
            assert!((back - v).abs() <= tol, "{v} -> {back}");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(1e9)).is_infinite());
        // subnormals survive approximately
        let tiny = 3e-6f32;
        let back = f16_bits_to_f32(f32_to_f16_bits(tiny));
        assert!((back - tiny).abs() < 1e-6);
    }

    #[test]
    fn packed_roundtrip_preserves_gemv() {
        let mut rng = Pcg32::seeded(4);
        let w = Matrix::from_fn(32, 128, |_, _| rng.normal_f32() * 0.05);
        let p = HaarPackedLinear::from_dense(&w).unwrap();
        let model = PackedModel {
            records: vec![("l0.wq".into(), Record::Packed(p.clone()))],
        };
        let path = std::env::temp_dir().join("hbllm_packed_test.hbq");
        model.save(&path).unwrap();
        let back = PackedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let Record::Packed(q) = &back.records[0].1 else { panic!("kind") };
        let x: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
        let mut y1 = vec![0f32; 32];
        let mut y2 = vec![0f32; 32];
        p.gemv(&x, &mut y1);
        q.gemv(&x, &mut y2);
        for (a, b) in y1.iter().zip(&y2) {
            // only fp16 rounding of alpha/mu may differ
            assert!((a - b).abs() < 2e-3 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn dense_roundtrip_exact() {
        let model = PackedModel {
            records: vec![(
                "ln_f".into(),
                Record::Dense { rows: 1, cols: 4, data: vec![1.0, -2.5, 3e-9, 42.0] },
            )],
        };
        let path = std::env::temp_dir().join("hbllm_dense_test.hbq");
        model.save(&path).unwrap();
        let back = PackedModel::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let Record::Dense { data, .. } = &back.records[0].1 else { panic!("kind") };
        assert_eq!(data, &vec![1.0, -2.5, 3e-9, 42.0]);
    }

    #[test]
    fn rejects_corrupt_files() {
        let path = std::env::temp_dir().join("hbllm_corrupt_test.hbq");
        std::fs::write(&path, b"garbage").unwrap();
        assert!(PackedModel::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn file_bits_near_one() {
        let mut rng = Pcg32::seeded(5);
        let w = Matrix::from_fn(64, 512, |_, _| rng.normal_f32());
        let model = PackedModel {
            records: vec![("l".into(), Record::Packed(HaarPackedLinear::from_dense(&w).unwrap()))],
        };
        let b = model.file_bits_per_linear_weight();
        assert!(b > 1.0 && b < 1.2, "{b}");
    }
}
