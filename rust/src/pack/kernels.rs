//! Runtime-dispatched kernels for the packed sign-word dot product — the
//! innermost loop of every decode, draft, and verify sweep.
//!
//! Three implementations of the same contract live here:
//!
//! * `scalar` — the byte-sign-table path, always compiled, always supported.
//!   It is the canonical reference: the property tests and the
//!   `kernels_conformance` suite pin every other kernel bit-identical to it.
//! * `avx2` — x86-64, 8 f32 lanes per step (`_mm256`), selected when
//!   `is_x86_feature_detected!("avx2")` holds at startup.
//! * `neon` — aarch64, two 4-lane halves per step (`float32x4_t`).
//!
//! A note on the XNOR-popcount formulation from the binary-nets literature
//! (BiLLM / PB-LLM in PAPERS.md): popcount realizes the speedup only when
//! *both* operands are binarized. Here the activation side stays f32 (the
//! Haar adjoint produces real-valued `z`), so the applicable trick is the
//! FMA-free *sign gather*: the bit pattern becomes a sign-bit XOR mask and
//! each step is a masked vector add — no multiplies, no table loads in the
//! SIMD paths.
//!
//! ## The canonical reduction order
//!
//! f32 addition is not associative, and the serving parity suites
//! (`engine_parity`, `spec_parity`, `prefix_parity`) demand byte-for-byte
//! identical outputs whichever kernel runs. Every kernel therefore computes
//! the *same* reduction, defined as:
//!
//! ```text
//! lanes[8] = 0
//! for j in j0..j1 (ascending):  lanes[j mod 8] += s_j · x[j]
//! result = ((((((lanes[0] + lanes[1]) + lanes[2]) + ... ) + lanes[7])
//! ```
//!
//! i.e. eight partial sums bucketed by *absolute* column index mod 8, each
//! filled in ascending-`j` order, reduced left-to-right at the end. The
//! bucketing is alignment-free — the value never depends on how `[j0, j1)`
//! sits relative to byte or word boundaries — and it is exactly the shape a
//! 256-bit register accumulates naturally, which is what lets the SIMD
//! paths reproduce it bit-for-bit (for finite inputs; only NaN sign
//! propagation may differ between `±1.0 * x` and a sign-bit XOR).
//!
//! ## Selection
//!
//! [`active`] resolves once per process, wasmer-style (an engine picked by
//! `CpuFeature` set, SNIPPETS.md §2): the first compiled-in kernel the host
//! supports wins, `scalar` is the fallback, and `HBLLM_KERNEL=<name>`
//! (e.g. `HBLLM_KERNEL=scalar`) forces a specific kernel for debugging or
//! cross-checking. An unknown or unsupported name logs a warning and falls
//! back to auto-selection. The chosen name is printed in the `serve`
//! banner and exported as the `kernel` label of `hbllm_kernel_info`.

use std::sync::OnceLock;

/// 256-entry byte -> eight ±1.0 multipliers table. Lets the scalar binary
/// dot product run as plain vectorizable FMAs over 8-lane chunks instead of
/// a serial trailing_zeros bit loop (§Perf L3: 53.7% -> ~30% of f32 GEMV).
fn sign_table() -> &'static [[f32; 8]; 256] {
    static TABLE: OnceLock<Box<[[f32; 8]; 256]>> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = Box::new([[0f32; 8]; 256]);
        for b in 0..256usize {
            for k in 0..8 {
                t[b][k] = if (b >> k) & 1 == 1 { 1.0 } else { -1.0 };
            }
        }
        t
    })
}

/// One sign-word dot implementation plus the metadata needed to pick it at
/// startup. `name` is what the serve banner and `hbllm_kernel_info` report.
pub struct Kernel {
    pub name: &'static str,
    supported: fn() -> bool,
    dot: fn(&[u64], &[f32], usize, usize) -> f32,
}

impl Kernel {
    /// Does the running CPU support this kernel? (`scalar` always does;
    /// the SIMD kernels consult runtime feature detection, which std
    /// caches after the first query.)
    pub fn supported(&self) -> bool {
        (self.supported)()
    }

    /// Σ_j s_j·x_j over `[j0, j1)` in the canonical reduction order (see
    /// the module docs). The SIMD entries re-verify CPU support on entry —
    /// a cached-flag load — so calling an unsupported kernel panics
    /// instead of executing illegal instructions.
    #[inline]
    pub fn dot_range(&self, words: &[u64], x: &[f32], j0: usize, j1: usize) -> f32 {
        (self.dot)(words, x, j0, j1)
    }
}

impl std::fmt::Debug for Kernel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Kernel")
            .field("name", &self.name)
            .field("supported", &self.supported())
            .finish()
    }
}

fn scalar_supported() -> bool {
    true
}

/// Scalar reference: byte-table body, per-bit head/tail, all feeding the
/// eight `j mod 8` buckets of the canonical reduction.
fn dot_scalar(words: &[u64], x: &[f32], j0: usize, j1: usize) -> f32 {
    debug_assert!(j1 <= x.len());
    debug_assert!(j0 >= j1 || (j1 - 1) / 64 < words.len());
    let table = sign_table();
    let mut lanes = [0f32; 8];
    let mut j = j0;
    // head: unaligned bits up to the next byte boundary
    while j < j1 && j % 8 != 0 {
        let bit = (words[j / 64] >> (j % 64)) & 1;
        lanes[j % 8] += if bit == 1 { x[j] } else { -x[j] };
        j += 1;
    }
    // body: whole bytes via the table; j % 8 == 0 here, so table slot k is
    // exactly bucket (j + k) mod 8 == k
    while j + 8 <= j1 {
        let byte = ((words[j / 64] >> (j % 64)) & 0xff) as usize;
        let signs = &table[byte];
        let xs = &x[j..j + 8];
        for k in 0..8 {
            lanes[k] += signs[k] * xs[k];
        }
        j += 8;
    }
    // tail
    while j < j1 {
        let bit = (words[j / 64] >> (j % 64)) & 1;
        lanes[j % 8] += if bit == 1 { x[j] } else { -x[j] };
        j += 1;
    }
    let mut acc = 0f32;
    for l in lanes {
        acc += l;
    }
    acc
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use std::arch::x86_64::*;

    pub fn supported() -> bool {
        std::arch::is_x86_feature_detected!("avx2")
    }

    pub fn dot(words: &[u64], x: &[f32], j0: usize, j1: usize) -> f32 {
        // cached-flag load; guards the unsafe target_feature call below
        assert!(supported(), "avx2 kernel invoked on a non-AVX2 host");
        // SAFETY: AVX2 verified present on this CPU just above.
        unsafe { dot_impl(words, x, j0, j1) }
    }

    /// Eight `j mod 8` buckets live in one `__m256`; each full byte is one
    /// sign-bit XOR + vector add. Head/tail bits are folded into the same
    /// bucket array before load / after store, so the reduction order is
    /// exactly the canonical one.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_impl(words: &[u64], x: &[f32], j0: usize, j1: usize) -> f32 {
        debug_assert!(j1 <= x.len());
        debug_assert!(j0 >= j1 || (j1 - 1) / 64 < words.len());
        let mut lanes = [0f32; 8];
        let mut j = j0;
        while j < j1 && j % 8 != 0 {
            let bit = (words[j / 64] >> (j % 64)) & 1;
            lanes[j % 8] += if bit == 1 { x[j] } else { -x[j] };
            j += 1;
        }
        if j + 8 <= j1 {
            // lane k of the register is bucket k: element k of a byte group
            // tests bit k (set ⇒ +x, clear ⇒ flip the IEEE sign bit)
            let bit_sel = _mm256_set_epi32(128, 64, 32, 16, 8, 4, 2, 1);
            let sign_bit = _mm256_set1_epi32(i32::MIN);
            let mut vacc = _mm256_loadu_ps(lanes.as_ptr());
            while j + 8 <= j1 {
                let byte = ((words[j / 64] >> (j % 64)) & 0xff) as i32;
                let is_set = _mm256_cmpeq_epi32(
                    _mm256_and_si256(_mm256_set1_epi32(byte), bit_sel),
                    bit_sel,
                );
                let flip = _mm256_andnot_si256(is_set, sign_bit);
                let xv = _mm256_loadu_ps(x.as_ptr().add(j));
                vacc = _mm256_add_ps(vacc, _mm256_xor_ps(xv, _mm256_castsi256_ps(flip)));
                j += 8;
            }
            _mm256_storeu_ps(lanes.as_mut_ptr(), vacc);
        }
        while j < j1 {
            let bit = (words[j / 64] >> (j % 64)) & 1;
            lanes[j % 8] += if bit == 1 { x[j] } else { -x[j] };
            j += 1;
        }
        let mut acc = 0f32;
        for l in lanes {
            acc += l;
        }
        acc
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    use std::arch::aarch64::*;

    pub fn supported() -> bool {
        // NEON is architecturally mandatory for aarch64 std targets, but
        // consult the runtime detector anyway to keep the selection logic
        // uniform across kernels.
        std::arch::is_aarch64_feature_detected!("neon")
    }

    pub fn dot(words: &[u64], x: &[f32], j0: usize, j1: usize) -> f32 {
        assert!(supported(), "neon kernel invoked without NEON support");
        // SAFETY: NEON verified present on this CPU just above.
        unsafe { dot_impl(words, x, j0, j1) }
    }

    /// The eight buckets split across two `float32x4_t` halves (buckets
    /// 0..4 and 4..8); each full byte is two sign-bit XORs + two vector
    /// adds. Same canonical reduction as the scalar reference.
    #[target_feature(enable = "neon")]
    unsafe fn dot_impl(words: &[u64], x: &[f32], j0: usize, j1: usize) -> f32 {
        debug_assert!(j1 <= x.len());
        debug_assert!(j0 >= j1 || (j1 - 1) / 64 < words.len());
        let mut lanes = [0f32; 8];
        let mut j = j0;
        while j < j1 && j % 8 != 0 {
            let bit = (words[j / 64] >> (j % 64)) & 1;
            lanes[j % 8] += if bit == 1 { x[j] } else { -x[j] };
            j += 1;
        }
        if j + 8 <= j1 {
            let sel_lo: [u32; 4] = [1, 2, 4, 8];
            let sel_hi: [u32; 4] = [16, 32, 64, 128];
            let bits_lo = vld1q_u32(sel_lo.as_ptr());
            let bits_hi = vld1q_u32(sel_hi.as_ptr());
            let sign_bit = vdupq_n_u32(0x8000_0000);
            let mut acc_lo = vld1q_f32(lanes.as_ptr());
            let mut acc_hi = vld1q_f32(lanes.as_ptr().add(4));
            while j + 8 <= j1 {
                let byte = ((words[j / 64] >> (j % 64)) & 0xff) as u32;
                let b = vdupq_n_u32(byte);
                let set_lo = vceqq_u32(vandq_u32(b, bits_lo), bits_lo);
                let set_hi = vceqq_u32(vandq_u32(b, bits_hi), bits_hi);
                // BIC: sign_bit & !set — bit set ⇒ no flip (+x), clear ⇒ -x
                let flip_lo = vbicq_u32(sign_bit, set_lo);
                let flip_hi = vbicq_u32(sign_bit, set_hi);
                let xlo = vld1q_f32(x.as_ptr().add(j));
                let xhi = vld1q_f32(x.as_ptr().add(j + 4));
                acc_lo = vaddq_f32(
                    acc_lo,
                    vreinterpretq_f32_u32(veorq_u32(vreinterpretq_u32_f32(xlo), flip_lo)),
                );
                acc_hi = vaddq_f32(
                    acc_hi,
                    vreinterpretq_f32_u32(veorq_u32(vreinterpretq_u32_f32(xhi), flip_hi)),
                );
                j += 8;
            }
            vst1q_f32(lanes.as_mut_ptr(), acc_lo);
            vst1q_f32(lanes.as_mut_ptr().add(4), acc_hi);
        }
        while j < j1 {
            let bit = (words[j / 64] >> (j % 64)) & 1;
            lanes[j % 8] += if bit == 1 { x[j] } else { -x[j] };
            j += 1;
        }
        let mut acc = 0f32;
        for l in lanes {
            acc += l;
        }
        acc
    }
}

const SCALAR: Kernel = Kernel { name: "scalar", supported: scalar_supported, dot: dot_scalar };

#[cfg(target_arch = "x86_64")]
static KERNELS: [Kernel; 2] = [
    Kernel { name: "avx2", supported: avx2::supported, dot: avx2::dot },
    SCALAR,
];
#[cfg(target_arch = "aarch64")]
static KERNELS: [Kernel; 2] = [
    Kernel { name: "neon", supported: neon::supported, dot: neon::dot },
    SCALAR,
];
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
static KERNELS: [Kernel; 1] = [SCALAR];

/// Every kernel compiled into this binary, preferred first, `scalar` last.
/// Compiled-in is not the same as runnable: check [`Kernel::supported`]
/// before calling anything but `scalar` (the conformance suite does).
pub fn all() -> &'static [Kernel] {
    &KERNELS
}

/// Resolve a kernel: an explicitly `requested` name wins if it is
/// compiled-in and supported; otherwise (or with `None`) the first
/// supported kernel in preference order is chosen. `scalar` is always
/// compiled-in and always supported, so this cannot fail.
pub fn select(requested: Option<&str>) -> &'static Kernel {
    if let Some(name) = requested {
        match KERNELS.iter().find(|k| k.name == name) {
            Some(k) if k.supported() => return k,
            Some(k) => crate::util::log::warn(&format!(
                "HBLLM_KERNEL={} is compiled in but unsupported on this CPU; auto-selecting",
                k.name
            )),
            None => crate::util::log::warn(&format!(
                "HBLLM_KERNEL={name} unknown (compiled in: {}); auto-selecting",
                KERNELS.iter().map(|k| k.name).collect::<Vec<_>>().join(", ")
            )),
        }
    }
    KERNELS
        .iter()
        .find(|k| k.supported())
        .expect("scalar kernel is always compiled in and supported")
}

/// The process-wide kernel, resolved once on first use from the
/// `HBLLM_KERNEL` environment variable (unset ⇒ auto-select). Every GEMV
/// in the pack layer routes through this — full decode, the low-band
/// draft, and the multi-position verify sweep all dispatch here.
pub fn active() -> &'static Kernel {
    static ACTIVE: OnceLock<&'static Kernel> = OnceLock::new();
    ACTIVE.get_or_init(|| {
        let requested = std::env::var("HBLLM_KERNEL").ok();
        select(requested.as_deref())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_is_always_compiled_in_and_supported() {
        let k = all().iter().find(|k| k.name == "scalar").expect("scalar missing");
        assert!(k.supported());
        assert_eq!(all().last().unwrap().name, "scalar", "scalar must be the fallback");
    }

    #[test]
    fn select_honors_explicit_scalar() {
        // the HBLLM_KERNEL=scalar debugging override resolves through here
        assert_eq!(select(Some("scalar")).name, "scalar");
    }

    #[test]
    fn select_falls_back_on_unknown_names() {
        let auto = select(None);
        assert!(auto.supported());
        assert_eq!(select(Some("definitely-not-a-kernel")).name, auto.name);
    }

    #[test]
    fn active_is_a_supported_kernel() {
        assert!(active().supported());
    }

    #[test]
    fn empty_range_is_zero_for_every_supported_kernel() {
        let words = [u64::MAX];
        let x = [1.0f32; 64];
        for k in all().iter().filter(|k| k.supported()) {
            assert_eq!(k.dot_range(&words, &x, 5, 5).to_bits(), 0f32.to_bits(), "{}", k.name);
            assert_eq!(k.dot_range(&words, &x, 0, 0).to_bits(), 0f32.to_bits(), "{}", k.name);
        }
    }
}
