//! Bit-packed binary weight storage + the deployment GEMV hot path (§4.5).
//!
//! Signs are packed 64/word. The binary dot product uses the identity
//!   Σ_j s_ij x_j = 2·Σ_{j: s_ij=+1} x_j − Σ_j x_j
//! so each row costs one masked accumulation; with per-band (α, μ) the full
//! HBLLM reconstruction folds into the same pass (the Haar synthesis is a
//! 2-tap butterfly applied to the *activation* side instead — see
//! `HaarPackedLinear::gemv`).
//!
//! The serialized form of these layers (the `.hbq` deployment artifact,
//! written by [`format`]) is specified byte-by-byte in `docs/FORMAT.md` at
//! the repository root.
//!
//! The sign-word dot itself — scalar reference plus runtime-dispatched
//! AVX2/NEON SIMD variants, all pinned bit-identical — lives in
//! [`kernels`]; everything in this module routes through
//! [`kernels::active`], so full decode, the low-band draft, and the
//! multi-position verify sweep share one kernel selection.

pub mod format;
pub mod kernels;

use crate::haar;
use crate::tensor::Matrix;

/// Signed dot product of a packed sign row against `x` over [j0, j1):
/// Σ_j s_j·x_j with s_j = ±1 from the bit pattern. `j0`/`j1` need not be
/// word-aligned.
///
/// Dispatches to the process-wide kernel ([`kernels::active`]). Whatever
/// kernel runs, the result is computed in the canonical reduction order:
/// eight partial sums bucketed by absolute column index mod 8, each filled
/// in ascending-`j` order, reduced left-to-right — so the value is
/// independent of both the selected kernel and how the range sits relative
/// to byte/word boundaries (see `kernels` module docs; the former scalar
/// path summed its unaligned head/tail into a ninth accumulator, which made
/// results depend on `j0`/`j1` alignment).
#[inline]
pub fn signed_dot_range(words: &[u64], x: &[f32], j0: usize, j1: usize) -> f32 {
    kernels::active().dot_range(words, x, j0, j1)
}

/// Sign-word byte budget per block of the cache-blocked multi-lane sweep
/// ([`HaarPackedLinear::gemv_rows_lanes`]): small enough that one block of
/// rows' words plus a single lane's adjoint activation sit comfortably in
/// a 256 KiB+ L2, large enough that the per-block lane loop amortizes.
const GEMV_BLOCK_BYTES: usize = 64 * 1024;

/// Row-major bit matrix; bit = 1 encodes sign +1.
#[derive(Clone, Debug)]
pub struct BitMatrix {
    pub rows: usize,
    pub cols: usize,
    words_per_row: usize,
    words: Vec<u64>,
}

impl BitMatrix {
    pub fn zeros(rows: usize, cols: usize) -> BitMatrix {
        let wpr = (cols + 63) / 64;
        BitMatrix { rows, cols, words_per_row: wpr, words: vec![0; rows * wpr] }
    }

    /// Pack the sign pattern of a dense matrix (>= 0 -> +1).
    pub fn from_signs(m: &Matrix) -> BitMatrix {
        let mut b = BitMatrix::zeros(m.rows, m.cols);
        for i in 0..m.rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v >= 0.0 {
                    b.set(i, j, true);
                }
            }
        }
        b
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> bool {
        let w = self.words[i * self.words_per_row + j / 64];
        (w >> (j % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: bool) {
        let idx = i * self.words_per_row + j / 64;
        let mask = 1u64 << (j % 64);
        if v {
            self.words[idx] |= mask;
        } else {
            self.words[idx] &= !mask;
        }
    }

    #[inline]
    pub fn row_words(&self, i: usize) -> &[u64] {
        &self.words[i * self.words_per_row..(i + 1) * self.words_per_row]
    }

    pub fn sign_f32(&self, i: usize, j: usize) -> f32 {
        if self.get(i, j) {
            1.0
        } else {
            -1.0
        }
    }

    pub fn to_dense_signs(&self) -> Matrix {
        Matrix::from_fn(self.rows, self.cols, |i, j| self.sign_f32(i, j))
    }

    pub fn storage_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// Masked sum: `Σ_{j: bit set} x[j]` for one row.
    #[inline]
    pub fn masked_sum(&self, i: usize, x: &[f32]) -> f32 {
        debug_assert_eq!(x.len(), self.cols);
        let words = self.row_words(i);
        let mut acc = 0.0f32;
        for (wi, &w) in words.iter().enumerate() {
            if w == 0 {
                continue;
            }
            let base = wi * 64;
            if w == u64::MAX && base + 64 <= x.len() {
                // full word fast path
                let mut s = 0.0f32;
                for &v in &x[base..base + 64] {
                    s += v;
                }
                acc += s;
                continue;
            }
            let mut bits = w;
            while bits != 0 {
                let t = bits.trailing_zeros() as usize;
                let j = base + t;
                if j < x.len() {
                    acc += x[j];
                }
                bits &= bits - 1;
            }
        }
        acc
    }
}

/// A plain packed binary linear layer: W ≈ diag-free α_i · s_ij (per-row α),
/// used for the §4.5 latency comparison.
#[derive(Clone)]
pub struct PackedLinear {
    pub bits: BitMatrix,
    pub alpha: Vec<f32>, // per row
}

impl PackedLinear {
    pub fn from_dense(w: &Matrix) -> PackedLinear {
        // α_i = mean |w_i|: the L2-optimal per-row scale for sign binarization
        let alpha = (0..w.rows)
            .map(|i| w.row(i).iter().map(|v| v.abs()).sum::<f32>() / w.cols as f32)
            .collect();
        PackedLinear { bits: BitMatrix::from_signs(w), alpha }
    }

    /// y = Ŵ x with Ŵ_ij = α_i s_ij.
    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        for i in 0..self.bits.rows {
            let dot = signed_dot_range(self.bits.row_words(i), x, 0, self.bits.cols);
            y[i] = self.alpha[i] * dot;
        }
    }

    pub fn to_dense(&self) -> Matrix {
        Matrix::from_fn(self.bits.rows, self.bits.cols, |i, j| {
            self.alpha[i] * self.bits.sign_f32(i, j)
        })
    }
}

/// A layer whose input width is odd: the Haar band split pairs adjacent
/// columns (`z_lo[k] = x[2k] + x[2k+1]`), so an odd `cols` has no valid
/// two-band layout — the last column would be silently dropped by the
/// activation prologue. Rejected at construction and at HBQ1 load instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct OddWidth {
    pub rows: usize,
    pub cols: usize,
}

impl std::fmt::Display for OddWidth {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "haar-packed layer needs an even input width (got {}x{}): \
             the band split pairs adjacent columns",
            self.rows, self.cols
        )
    }
}

impl std::error::Error for OddWidth {}

/// HBLLM deployment layer: Haar-domain signs + per-row per-band (α, μ).
///
/// y = HaarInv_row(α⊙s + μ) · x. Rather than reconstructing W, we use
/// `<HaarInv(c)_i, x> = <c_i, A x>` where A is the synthesis adjoint — i.e.
/// transform the activation once per call (O(m)), then every row is a plain
/// binary dot in the Haar domain. This is the paper's "local convolution,
/// fuses into the linear layer" argument, executable form.
///
/// Invariant: `bits.cols` is even (the two bands split at `cols/2`). The
/// constructors ([`Self::from_dense`], [`Self::from_parts`]) enforce it
/// with a typed [`OddWidth`] error; the fields stay public for the
/// serializer, which only ever round-trips already-validated layers.
#[derive(Clone)]
pub struct HaarPackedLinear {
    pub bits: BitMatrix, // Haar-domain signs
    pub alpha: Vec<[f32; 2]>,
    pub mu: Vec<[f32; 2]>,
}

impl HaarPackedLinear {
    /// Assemble a layer from already-packed parts (the HBQ1 load path),
    /// rejecting odd widths — a crafted or bit-flipped artifact must not
    /// produce a layer whose GEMV silently ignores its last column.
    pub fn from_parts(
        bits: BitMatrix,
        alpha: Vec<[f32; 2]>,
        mu: Vec<[f32; 2]>,
    ) -> Result<HaarPackedLinear, OddWidth> {
        if bits.cols % 2 != 0 {
            return Err(OddWidth { rows: bits.rows, cols: bits.cols });
        }
        Ok(HaarPackedLinear { bits, alpha, mu })
    }

    /// Quantize a dense W (row-Haar, one group per band, shared-mean style).
    /// Odd `w.cols` is a typed error: see [`OddWidth`].
    pub fn from_dense(w: &Matrix) -> Result<HaarPackedLinear, OddWidth> {
        if w.cols % 2 != 0 {
            return Err(OddWidth { rows: w.rows, cols: w.cols });
        }
        let c = haar::fwd_rows(w);
        let h = c.cols / 2;
        let mut alpha = Vec::with_capacity(c.rows);
        let mut mu = Vec::with_capacity(c.rows);
        let mut signs = Matrix::zeros(c.rows, c.cols);
        for i in 0..c.rows {
            let row = c.row(i);
            let mut ab = [0f32; 2];
            let mut ub = [0f32; 2];
            for (b, range) in [(0usize, 0..h), (1usize, h..c.cols)] {
                let vals = &row[range];
                let m = vals.iter().sum::<f32>() / vals.len() as f32;
                let a = vals.iter().map(|v| (v - m).abs()).sum::<f32>() / vals.len() as f32;
                ab[b] = a;
                ub[b] = m;
            }
            alpha.push(ab);
            mu.push(ub);
            for (j, &v) in row.iter().enumerate() {
                let b = if j < h { 0 } else { 1 };
                signs.set(i, j, if v - ub[b] >= 0.0 { 1.0 } else { -1.0 });
            }
        }
        Ok(HaarPackedLinear { bits: BitMatrix::from_signs(&signs), alpha, mu })
    }

    /// Adjoint-transformed activation: z with `<c_i, z> = <HaarInv(c_i), x>`.
    /// From the synthesis map: `z_lo[k] = x[2k] + x[2k+1]`, `z_hi[k] = x[2k] - x[2k+1]`.
    pub fn adjoint_activation(x: &[f32]) -> Vec<f32> {
        let h = x.len() / 2;
        let mut z = vec![0.0f32; x.len()];
        for k in 0..h {
            z[k] = x[2 * k] + x[2 * k + 1];
            z[h + k] = x[2 * k] - x[2 * k + 1];
        }
        z
    }

    pub fn gemv(&self, x: &[f32], y: &mut [f32]) {
        let (z, sum_lo, sum_hi) = self.prepare_activation(x);
        self.gemv_rows(&z, sum_lo, sum_hi, 0, y);
    }

    /// Adjoint-transform `x` once and precompute the per-band sums — the
    /// O(m) prologue shared by every row of the GEMV. Split out so callers
    /// (the engine's row-parallel GEMV) can run `gemv_rows` over disjoint
    /// row ranges against one shared `z`.
    pub fn prepare_activation(&self, x: &[f32]) -> (Vec<f32>, f32, f32) {
        let mut z = Vec::new();
        let (sum_lo, sum_hi) = self.prepare_activation_into(x, &mut z);
        (z, sum_lo, sum_hi)
    }

    /// As [`Self::prepare_activation`], but reusing `z` (resized to fit) —
    /// the engine hot loop's allocation-free path.
    pub fn prepare_activation_into(&self, x: &[f32], z: &mut Vec<f32>) -> (f32, f32) {
        let m = self.bits.cols;
        z.resize(m, 0.0);
        self.prepare_activation_slice(x, &mut z[..m])
    }

    /// As [`Self::prepare_activation`], but writing into an exactly-sized
    /// slice — used by the multi-lane GEMV to lay several lanes' adjoint
    /// activations side by side in one scratch buffer.
    pub fn prepare_activation_slice(&self, x: &[f32], z: &mut [f32]) -> (f32, f32) {
        let m = self.bits.cols;
        debug_assert_eq!(x.len(), m);
        debug_assert_eq!(z.len(), m);
        // even width is a construction invariant (`OddWidth`): h pairs
        // cover x exactly, no column is dropped
        debug_assert_eq!(m % 2, 0);
        let h = m / 2;
        for k in 0..h {
            z[k] = x[2 * k] + x[2 * k + 1];
            z[h + k] = x[2 * k] - x[2 * k + 1];
        }
        let sum_lo: f32 = z[..h].iter().sum();
        let sum_hi: f32 = z[h..].iter().sum();
        (sum_lo, sum_hi)
    }

    /// GEMV over rows [i0, i0 + y.len()) given a prepared activation.
    /// `y[k]` receives row `i0 + k`.
    pub fn gemv_rows(&self, z: &[f32], sum_lo: f32, sum_hi: f32, i0: usize, y: &mut [f32]) {
        let m = self.bits.cols;
        let h = m / 2;
        let kern = kernels::active();
        for (k, out) in y.iter_mut().enumerate() {
            let i = i0 + k;
            let words = self.bits.row_words(i);
            let dot_s_lo = kern.dot_range(words, z, 0, h);
            let dot_s_hi = kern.dot_range(words, z, h, m);
            let dot_lo = self.alpha[i][0] * dot_s_lo + self.mu[i][0] * sum_lo;
            let dot_hi = self.alpha[i][1] * dot_s_hi + self.mu[i][1] * sum_hi;
            *out = dot_lo + dot_hi;
        }
    }

    /// Low-band adjoint activation: the first half of
    /// [`Self::prepare_activation`]'s output (`z_lo[k] = x[2k] + x[2k+1]`)
    /// plus its sum — all a low-band-only draft GEMV needs. `z` is resized
    /// to `cols/2`; the high-band butterfly is never computed, so the
    /// prologue costs half of the full prepare.
    pub fn prepare_activation_low(&self, x: &[f32], z: &mut Vec<f32>) -> f32 {
        let m = self.bits.cols;
        debug_assert_eq!(x.len(), m);
        let h = m / 2;
        z.resize(h, 0.0);
        for k in 0..h {
            z[k] = x[2 * k] + x[2 * k + 1];
        }
        z.iter().sum()
    }

    /// Low-band-only GEMV over rows `[i0, i0 + y.len())`: the frequency
    /// cascade's *draft* view of this layer. Reads the same packed sign
    /// words as [`Self::gemv_rows`] but only the low-band bit range
    /// `[0, cols/2)` and only the band-0 `(α, μ)` — the high-band words and
    /// scales are skipped entirely, so the draft costs roughly half the
    /// dots with zero extra weight storage. Row `i`'s output equals
    /// [`Self::gemv_rows`] with `alpha[i][1] = mu[i][1] = 0`: the deepest
    /// Haar low band as a coarse approximation of the full row.
    pub fn gemv_rows_low(&self, z: &[f32], sum_lo: f32, i0: usize, y: &mut [f32]) {
        let h = self.bits.cols / 2;
        debug_assert!(z.len() >= h);
        let kern = kernels::active();
        for (k, out) in y.iter_mut().enumerate() {
            let i = i0 + k;
            let words = self.bits.row_words(i);
            let dot_s_lo = kern.dot_range(words, z, 0, h);
            *out = self.alpha[i][0] * dot_s_lo + self.mu[i][0] * sum_lo;
        }
    }

    /// Convenience low-band GEMV (allocating); the draft hot loop uses
    /// [`Self::prepare_activation_low`] + [`Self::gemv_rows_low`] with a
    /// reused scratch instead.
    pub fn gemv_low(&self, x: &[f32], y: &mut [f32]) {
        let mut z = Vec::new();
        let sum_lo = self.prepare_activation_low(x, &mut z);
        self.gemv_rows_low(&z, sum_lo, 0, y);
    }

    /// Multi-lane GEMV over rows `[i0, i0 + ys[l].len())`: one sweep of the
    /// packed sign words serves every lane. `z_all` holds the lanes'
    /// prepared activations back to back (`lane l` at `[l*m, (l+1)*m)`, see
    /// [`Self::prepare_activation_slice`]) and `sums[l]` the matching
    /// per-band sums.
    ///
    /// The sweep is cache-blocked: rows are processed in blocks whose sign
    /// words fit an L2-sized budget, and within a block the lane loop is
    /// outermost. The first lane's pass streams the block's words into L2;
    /// every later lane re-reads them from cache while its own `z` slice
    /// streams — so the working set is one row block + *one* lane's
    /// activation, and the sign words cross L2 once per token no matter how
    /// many lanes are batched. (The previous row-major order kept all
    /// lanes' activations live at once, which fell out of L2 as the batch
    /// grew.) Per-row-per-lane arithmetic is identical to
    /// [`Self::gemv_rows`], and blocking only reorders *which* (row, lane)
    /// output is computed when — never the arithmetic inside one — so
    /// single-lane, batched, and blocked-vs-unblocked decoding all produce
    /// bit-identical results (pinned by `tests/kernels_conformance.rs`).
    pub fn gemv_rows_lanes(
        &self,
        z_all: &[f32],
        sums: &[(f32, f32)],
        i0: usize,
        ys: &mut [&mut [f32]],
    ) {
        self.gemv_rows_lanes_blocked(z_all, sums, i0, ys, GEMV_BLOCK_BYTES);
    }

    /// [`Self::gemv_rows_lanes`] with an explicit per-block sign-word byte
    /// budget. Exposed (hidden) so the conformance suite can pin blocked
    /// and unblocked sweeps against each other; production callers use the
    /// default budget via `gemv_rows_lanes`.
    #[doc(hidden)]
    pub fn gemv_rows_lanes_blocked(
        &self,
        z_all: &[f32],
        sums: &[(f32, f32)],
        i0: usize,
        ys: &mut [&mut [f32]],
        block_bytes: usize,
    ) {
        let m = self.bits.cols;
        let h = m / 2;
        debug_assert_eq!(ys.len(), sums.len());
        debug_assert_eq!(z_all.len(), ys.len() * m);
        let kern = kernels::active();
        let rows = ys.first().map_or(0, |y| y.len());
        let row_bytes = self.bits.words_per_row * 8;
        let block_rows = (block_bytes / row_bytes.max(1)).max(1);
        let mut k0 = 0;
        while k0 < rows {
            let k1 = (k0 + block_rows).min(rows);
            for (l, y) in ys.iter_mut().enumerate() {
                let z = &z_all[l * m..(l + 1) * m];
                let (sum_lo, sum_hi) = sums[l];
                for (k, out) in y[k0..k1].iter_mut().enumerate() {
                    let i = i0 + k0 + k;
                    let words = self.bits.row_words(i);
                    let dot_s_lo = kern.dot_range(words, z, 0, h);
                    let dot_s_hi = kern.dot_range(words, z, h, m);
                    let dot_lo = self.alpha[i][0] * dot_s_lo + self.mu[i][0] * sum_lo;
                    let dot_hi = self.alpha[i][1] * dot_s_hi + self.mu[i][1] * sum_hi;
                    *out = dot_lo + dot_hi;
                }
            }
            k0 = k1;
        }
    }


    /// Dense reconstruction (for correctness tests).
    pub fn to_dense(&self) -> Matrix {
        let n = self.bits.rows;
        let m = self.bits.cols;
        let h = m / 2;
        let mut c = Matrix::zeros(n, m);
        for i in 0..n {
            for j in 0..m {
                let b = if j < h { 0 } else { 1 };
                c.set(i, j, self.alpha[i][b] * self.bits.sign_f32(i, j) + self.mu[i][b]);
            }
        }
        haar::inv_rows(&c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};
    use crate::util::rng::Pcg32;

    fn rand_mat(rng: &mut Pcg32, n: usize, m: usize) -> Matrix {
        Matrix::from_fn(n, m, |_, _| rng.normal_f32())
    }

    #[test]
    fn bitmatrix_roundtrip() {
        check(
            "bitmatrix-roundtrip",
            30,
            |g: &mut Gen| {
                let n = g.size(1, 20);
                let m = g.size(1, 200);
                let mut mat = Matrix::from_vec(n, m, g.vec_f32(n * m, 1.0));
                // avoid exact zeros (sign ambiguity)
                for v in mat.data.iter_mut() {
                    if *v == 0.0 {
                        *v = 1.0;
                    }
                }
                mat
            },
            |m| {
                let b = BitMatrix::from_signs(m);
                for i in 0..m.rows {
                    for j in 0..m.cols {
                        let want = m.get(i, j) >= 0.0;
                        if b.get(i, j) != want {
                            return Err(format!("bit mismatch at ({i},{j})"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_signed_dot_range_matches_scalar() {
        // head / byte-body / tail paths against a scalar ±1 reference on
        // random unaligned [j0, j1) ranges
        check(
            "signed-dot-range",
            60,
            |g: &mut Gen| {
                let m = g.size(1, 300);
                let j0 = g.size(0, m - 1);
                let j1 = g.size(j0, m);
                let seed = g.rng.next_u64();
                (m, j0, j1, seed)
            },
            |&(m, j0, j1, seed)| {
                let mut rng = Pcg32::seeded(seed);
                let mat = Matrix::from_fn(1, m, |_, _| {
                    let v = rng.normal_f32();
                    if v == 0.0 {
                        1.0
                    } else {
                        v
                    }
                });
                let bits = BitMatrix::from_signs(&mat);
                let x: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
                let got = signed_dot_range(bits.row_words(0), &x, j0, j1);
                let want: f32 = (j0..j1)
                    .map(|j| if bits.get(0, j) { x[j] } else { -x[j] })
                    .sum();
                if (got - want).abs() < 1e-3 * (1.0 + want.abs()) {
                    Ok(())
                } else {
                    Err(format!("[{j0},{j1}) of {m}: {got} vs {want}"))
                }
            },
        );
    }

    /// The canonical reduction order, computed naively per bit (see the
    /// `kernels` module docs): eight buckets by absolute column index
    /// mod 8, filled in ascending-`j` order, reduced left-to-right.
    fn canonical_dot(words: &[u64], x: &[f32], j0: usize, j1: usize) -> f32 {
        let mut lanes = [0f32; 8];
        for j in j0..j1 {
            let bit = (words[j / 64] >> (j % 64)) & 1;
            lanes[j % 8] += if bit == 1 { x[j] } else { -x[j] };
        }
        let mut acc = 0f32;
        for l in lanes {
            acc += l;
        }
        acc
    }

    #[test]
    fn every_kernel_matches_the_naive_per_bit_loop_exactly() {
        // directed word-straddling / sub-byte / empty ranges plus random
        // ones: each supported kernel must reproduce the canonical
        // reduction order bit-for-bit, whatever the alignment of [j0, j1)
        let mut rng = Pcg32::seeded(21);
        let m = 200;
        let mat = rand_mat(&mut rng, 1, m);
        let bits = BitMatrix::from_signs(&mat);
        let x: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let words = bits.row_words(0);
        let mut ranges = vec![
            (0usize, 0usize),
            (7, 7),
            (64, 64), // empty, at and off word boundaries
            (3, 7),
            (63, 64),
            (62, 66),
            (127, 130), // j1 - j0 < 8, some straddling a u64 boundary
            (60, 68),
            (1, 129),
            (0, 64),
            (64, 128),
            (5, 200),
            (0, 200),
        ];
        for _ in 0..40 {
            let j0 = rng.below(m);
            let j1 = j0 + rng.below(m - j0 + 1);
            ranges.push((j0, j1));
        }
        for &(j0, j1) in &ranges {
            let want = canonical_dot(words, &x, j0, j1);
            for k in kernels::all().iter().filter(|k| k.supported()) {
                let got = k.dot_range(words, &x, j0, j1);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "kernel {} diverged on [{j0},{j1}): {got} vs {want}",
                    k.name
                );
            }
        }
    }

    #[test]
    fn odd_width_is_a_typed_construction_error() {
        let mut rng = Pcg32::seeded(22);
        let w = rand_mat(&mut rng, 4, 5);
        let err = HaarPackedLinear::from_dense(&w).unwrap_err();
        assert_eq!(err, OddWidth { rows: 4, cols: 5 });
        assert!(err.to_string().contains("even input width"), "{err}");
        // the load-path constructor rejects the same shape...
        let parts_err = HaarPackedLinear::from_parts(
            BitMatrix::zeros(4, 5),
            vec![[0.0f32; 2]; 4],
            vec![[0.0f32; 2]; 4],
        )
        .unwrap_err();
        assert_eq!(parts_err, OddWidth { rows: 4, cols: 5 });
        // ...and even widths construct through both
        assert!(HaarPackedLinear::from_dense(&rand_mat(&mut rng, 4, 6)).is_ok());
        assert!(HaarPackedLinear::from_parts(
            BitMatrix::zeros(4, 6),
            vec![[0.0f32; 2]; 4],
            vec![[0.0f32; 2]; 4],
        )
        .is_ok());
    }

    #[test]
    fn gemv_rows_partial_ranges_agree_with_full() {
        let mut rng = Pcg32::seeded(9);
        let w = rand_mat(&mut rng, 23, 128);
        let p = HaarPackedLinear::from_dense(&w).unwrap();
        let x: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
        let mut full = vec![0.0; 23];
        p.gemv(&x, &mut full);
        let (z, slo, shi) = p.prepare_activation(&x);
        let mut part = vec![0.0; 23];
        for (i0, i1) in [(0usize, 7usize), (7, 20), (20, 23)] {
            p.gemv_rows(&z, slo, shi, i0, &mut part[i0..i1]);
        }
        assert_eq!(full, part);
    }

    #[test]
    fn gemv_rows_lanes_is_bit_identical_to_per_lane_gemv() {
        let mut rng = Pcg32::seeded(11);
        let w = rand_mat(&mut rng, 17, 64);
        let p = HaarPackedLinear::from_dense(&w).unwrap();
        let m = 64;
        let lanes = 3;
        let xs: Vec<Vec<f32>> = (0..lanes)
            .map(|_| (0..m).map(|_| rng.normal_f32()).collect())
            .collect();
        // single-lane reference
        let mut want: Vec<Vec<f32>> = Vec::new();
        for x in &xs {
            let mut y = vec![0.0; 17];
            p.gemv(x, &mut y);
            want.push(y);
        }
        // batched: adjoint activations side by side, rows swept once
        let mut z_all = vec![0.0f32; lanes * m];
        let mut sums = Vec::new();
        for (l, x) in xs.iter().enumerate() {
            sums.push(p.prepare_activation_slice(x, &mut z_all[l * m..(l + 1) * m]));
        }
        let mut got: Vec<Vec<f32>> = (0..lanes).map(|_| vec![0.0; 17]).collect();
        {
            let mut ys: Vec<&mut [f32]> = got.iter_mut().map(|y| y.as_mut_slice()).collect();
            p.gemv_rows_lanes(&z_all, &sums, 0, &mut ys);
        }
        assert_eq!(got, want, "multi-lane sweep diverged from per-lane gemv");
    }

    #[test]
    fn low_band_gemv_matches_zeroed_high_band() {
        // the draft view must equal the full GEMV with the high band's
        // (α, μ) forced to zero — same sign words, band 1 skipped
        let mut rng = Pcg32::seeded(13);
        for &(n, m) in &[(9usize, 64usize), (5, 130), (3, 2)] {
            let w = rand_mat(&mut rng, n, m);
            let p = HaarPackedLinear::from_dense(&w).unwrap();
            let mut hushed = p.clone();
            for i in 0..n {
                hushed.alpha[i][1] = 0.0;
                hushed.mu[i][1] = 0.0;
            }
            let x: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
            let mut want = vec![0.0; n];
            hushed.gemv(&x, &mut want);
            let mut got = vec![0.0; n];
            p.gemv_low(&x, &mut got);
            assert_eq!(got, want, "(n={n},m={m}) draft view diverged");
        }
    }

    #[test]
    fn low_band_partial_row_ranges_agree_with_full() {
        let mut rng = Pcg32::seeded(14);
        let w = rand_mat(&mut rng, 23, 128);
        let p = HaarPackedLinear::from_dense(&w).unwrap();
        let x: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
        let mut full = vec![0.0; 23];
        p.gemv_low(&x, &mut full);
        let mut z = Vec::new();
        let sum_lo = p.prepare_activation_low(&x, &mut z);
        assert_eq!(z.len(), 64);
        let mut part = vec![0.0; 23];
        for (i0, i1) in [(0usize, 7usize), (7, 20), (20, 23)] {
            p.gemv_rows_low(&z, sum_lo, i0, &mut part[i0..i1]);
        }
        assert_eq!(full, part);
    }

    #[test]
    fn masked_sum_matches_naive() {
        let mut rng = Pcg32::seeded(1);
        for &m in &[1usize, 63, 64, 65, 130, 256] {
            let mat = rand_mat(&mut rng, 4, m);
            let bits = BitMatrix::from_signs(&mat);
            let x: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
            for i in 0..4 {
                let naive: f32 = (0..m).filter(|&j| bits.get(i, j)).map(|j| x[j]).sum();
                let got = bits.masked_sum(i, &x);
                assert!((naive - got).abs() < 1e-4, "m={m} i={i}: {naive} vs {got}");
            }
        }
    }

    #[test]
    fn packed_gemv_matches_dense() {
        let mut rng = Pcg32::seeded(2);
        let w = rand_mat(&mut rng, 32, 128);
        let p = PackedLinear::from_dense(&w);
        let dense = p.to_dense();
        let x: Vec<f32> = (0..128).map(|_| rng.normal_f32()).collect();
        let mut y = vec![0.0; 32];
        p.gemv(&x, &mut y);
        let want = dense.matvec(&x);
        for i in 0..32 {
            assert!((y[i] - want[i]).abs() < 1e-3, "{} vs {}", y[i], want[i]);
        }
    }

    #[test]
    fn haar_packed_gemv_matches_dense_reconstruction() {
        let mut rng = Pcg32::seeded(3);
        for &(n, m) in &[(16usize, 128usize), (8, 256), (5, 128)] {
            let w = rand_mat(&mut rng, n, m);
            let p = HaarPackedLinear::from_dense(&w).unwrap();
            let dense = p.to_dense();
            let x: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
            let mut y = vec![0.0; n];
            p.gemv(&x, &mut y);
            let want = dense.matvec(&x);
            for i in 0..n {
                assert!(
                    (y[i] - want[i]).abs() < 2e-3 * (1.0 + want[i].abs()),
                    "(n={n},m={m}) row {i}: {} vs {}",
                    y[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn adjoint_identity() {
        // <HaarInv(c), x> == <c, adjoint(x)>
        let mut rng = Pcg32::seeded(4);
        let m = 64;
        let c: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let x: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
        let w = crate::haar::inv_1d(&c);
        let z = HaarPackedLinear::adjoint_activation(&x);
        let lhs: f32 = w.iter().zip(&x).map(|(a, b)| a * b).sum();
        let rhs: f32 = c.iter().zip(&z).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-3);
    }

    #[test]
    fn packed_quantization_reduces_storage() {
        let mut rng = Pcg32::seeded(5);
        let w = rand_mat(&mut rng, 64, 256);
        let p = PackedLinear::from_dense(&w);
        let dense_bytes = 64 * 256 * 4;
        assert!(p.bits.storage_bytes() * 8 < dense_bytes);
    }
}
