//! Small CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map_or(false, |n| !n.starts_with("--")) {
                    args.options.insert(rest.to_string(), iter.next().unwrap());
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("quantize file.bin --method hbllm-row --block=128 --quick");
        assert_eq!(a.positional, vec!["quantize", "file.bin"]);
        assert_eq!(a.get("method"), Some("hbllm-row"));
        assert_eq!(a.get_usize("block", 0), 128);
        assert!(a.has_flag("quick"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("lam", 0.01), 0.01);
        assert!(!a.has_flag("nope"));
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--verbose");
        assert!(a.has_flag("verbose"));
    }
}
