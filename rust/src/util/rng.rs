//! Deterministic PCG-XSH-RR 64/32 PRNG (no external crates offline).
//!
//! Every stochastic component of the library (synthetic layers, calibration
//! sampling, FrameQuant rotations, property tests) derives from this
//! generator so runs are exactly reproducible from a seed.

#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
    /// cached second normal from Box-Muller
    spare: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (stream << 1) | 1;
        let mut rng = Pcg32 { state: 0, inc, spare: None };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e39cb94b95bdb)
    }

    /// Split off an independent stream (for per-worker determinism).
    pub fn split(&mut self, tag: u64) -> Pcg32 {
        Pcg32::new(self.next_u64() ^ tag.wrapping_mul(0x9e3779b97f4a7c15), tag)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.f64() * n as f64) as usize % n
    }

    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = self.f64();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.f64();
            let r = (-2.0 * u.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Student-t with `nu` degrees of freedom (heavy-tailed weights).
    pub fn student_t(&mut self, nu: f64) -> f64 {
        // t = z / sqrt(chi2/nu); chi2 via sum of squared normals (nu small).
        let z = self.normal();
        let k = nu.round().max(1.0) as usize;
        let chi2: f64 = (0..k).map(|_| self.normal().powi(2)).sum();
        z / (chi2 / nu).sqrt()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg32::seeded(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(9);
        let n = 40_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_in_range() {
        let mut r = Pcg32::seeded(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn split_streams_independent() {
        let mut base = Pcg32::seeded(11);
        let mut s1 = base.split(1);
        let mut s2 = base.split(2);
        assert_ne!(s1.next_u64(), s2.next_u64());
    }
}
