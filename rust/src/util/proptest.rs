//! Mini property-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, gen, prop)` runs `cases` random inputs from a
//! deterministic seed; on failure it retries with a linear shrink pass (the
//! generator receives a shrink level that should produce "smaller" cases)
//! and panics with the seed so the failure is reproducible.
//!
//! Failures replay exactly: every panic prints the failing `seed=`, and
//! setting `HBLLM_TEST_SEED=<seed>` overrides the name-hash base seed so
//! case 0 of the local rerun regenerates the CI failure's input
//! byte-for-byte (`HBLLM_TEST_SEED=123 cargo test <test_name>`).

use super::rng::Pcg32;

pub struct Gen<'a> {
    pub rng: &'a mut Pcg32,
    /// 0 = full-size cases; higher values ask the generator to shrink.
    pub shrink: u32,
}

impl<'a> Gen<'a> {
    /// Size helper honoring the shrink level: uniform in [lo, hi] at level 0,
    /// biased toward `lo` as shrink grows.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let hi_eff = if self.shrink == 0 {
            hi
        } else {
            let span = (hi - lo) >> self.shrink.min(8);
            lo + span
        };
        lo + self.rng.below(hi_eff - lo + 1)
    }

    pub fn vec_f32(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.rng.normal_f32() * scale).collect()
    }
}

/// Run a property over `cases` generated inputs. Panics on first failure
/// after attempting shrunk reproductions.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: u32,
    mut generate: impl FnMut(&mut Gen) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base_seed =
        resolve_base_seed(name, std::env::var("HBLLM_TEST_SEED").ok().as_deref());
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Pcg32::seeded(seed);
        let mut g = Gen { rng: &mut rng, shrink: 0 };
        let input = generate(&mut g);
        if let Err(msg) = prop(&input) {
            // try shrunk variants of the same seed for a smaller repro
            for level in 1..=4u32 {
                let mut srng = Pcg32::seeded(seed);
                let mut sg = Gen { rng: &mut srng, shrink: level };
                let sinput = generate(&mut sg);
                if let Err(smsg) = prop(&sinput) {
                    panic!(
                        "property '{name}' failed (seed={seed}, shrink={level}): {smsg}\n\
                         replay with HBLLM_TEST_SEED={seed}\ninput: {sinput:?}"
                    );
                }
            }
            panic!(
                "property '{name}' failed (seed={seed}): {msg}\n\
                 replay with HBLLM_TEST_SEED={seed}\ninput: {input:?}"
            );
        }
    }
}

/// The base seed for a property: a decimal `HBLLM_TEST_SEED` override
/// when set (and parseable — anything else falls back), otherwise the
/// FNV-1a hash of the property name. With the override set, case 0 uses
/// exactly that seed, so a `seed=N` from a CI panic replays as the first
/// case locally.
fn resolve_base_seed(name: &str, env: Option<&str>) -> u64 {
    env.and_then(|v| v.trim().parse().ok()).unwrap_or_else(|| hb_seed(name))
}

/// FNV-1a hash of the property name -> base seed.
fn hb_seed(name: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            "sum-commutes",
            50,
            |g| (g.size(0, 100), g.size(0, 100)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |g| g.size(1, 10), |_| Err("nope".into()));
    }

    #[test]
    fn seed_override_parses_and_falls_back() {
        assert_eq!(resolve_base_seed("p", None), hb_seed("p"));
        assert_eq!(resolve_base_seed("p", Some("42")), 42);
        assert_eq!(resolve_base_seed("p", Some(" 7 ")), 7);
        // garbage falls back to the name hash instead of hiding the run
        assert_eq!(resolve_base_seed("p", Some("not-a-seed")), hb_seed("p"));
        // the override is name-independent: one CI seed replays anywhere
        assert_eq!(resolve_base_seed("a", Some("9")), resolve_base_seed("b", Some("9")));
    }

    #[test]
    fn deterministic_generation() {
        let mut first: Vec<usize> = Vec::new();
        check("det", 10, |g| g.size(0, 1000), |&v| {
            first.push(v);
            Ok(())
        });
        let mut second: Vec<usize> = Vec::new();
        check("det", 10, |g| g.size(0, 1000), |&v| {
            second.push(v);
            Ok(())
        });
        assert_eq!(first, second);
    }
}
