//! Minimal JSON parser/writer (serde is unavailable offline).
//!
//! Supports the full JSON grammar minus exotic number forms; good enough for
//! the artifact metadata (`model_*.json`, `manifest.json`) and for emitting
//! experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Path lookup: `j.at(&["config", "d_model"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err("bad \\u".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| "bad \\u")?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape \\{}", esc as char)),
                    }
                }
                Some(_) => {
                    // copy a run of plain bytes (handles multi-byte UTF-8)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {"e": false}}"#).unwrap();
        assert_eq!(j.at(&["d", "e"]), Some(&Json::Bool(false)));
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"config":{"d_model":256,"name":"tiny"},"list":[1,2.5,"x",null,true]}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn parses_real_artifact_meta() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(src) = std::fs::read_to_string(path) {
            let j = Json::parse(&src).unwrap();
            assert!(j.get("entry_points").is_some());
        }
    }
}
