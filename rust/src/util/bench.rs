//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-clock over warmup + timed iterations, reports
//! median / p10 / p90 and derived throughput. Used by every `benches/`
//! target; results are printed as aligned tables so bench output can be
//! pasted straight into EXPERIMENTS.md.

use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl Measurement {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    pub fn median_s(&self) -> f64 {
        self.median_ns / 1e9
    }
}

/// Time `f` with automatic iteration-count calibration toward
/// `target_time_s` total measurement time.
pub fn bench<F: FnMut()>(name: &str, target_time_s: f64, mut f: F) -> Measurement {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_time_s / once).ceil() as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    Measurement {
        name: name.to_string(),
        iters,
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Aligned table printer for bench results / experiment tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", c, width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench("noop-ish", 0.01, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(m.median_ns > 0.0);
        assert!(m.p10_ns <= m.median_ns && m.median_ns <= m.p90_ns);
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["method", "ppl"]);
        t.row(&["hbllm-row".into(), "6.71".into()]);
        t.row(&["billm".into(), "19.57".into()]);
        let s = t.to_string();
        assert!(s.contains("hbllm-row"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
