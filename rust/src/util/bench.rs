//! Tiny benchmark harness (criterion is unavailable offline).
//!
//! Measures wall-clock over warmup + timed iterations, reports
//! median / p10 / p90 and derived throughput. Used by every `benches/`
//! target; results are printed as aligned tables so bench output can be
//! pasted straight into EXPERIMENTS.md, and can be persisted as
//! `BENCH_<name>.json` trajectory files via [`write_json`].

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub p10_ns: f64,
    pub p90_ns: f64,
}

impl Measurement {
    pub fn median_ms(&self) -> f64 {
        self.median_ns / 1e6
    }

    pub fn median_s(&self) -> f64 {
        self.median_ns / 1e9
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("median_ns".to_string(), Json::Num(self.median_ns));
        m.insert("p10_ns".to_string(), Json::Num(self.p10_ns));
        m.insert("p90_ns".to_string(), Json::Num(self.p90_ns));
        Json::Obj(m)
    }
}

/// Persist a bench run as a JSON trajectory file (e.g. `BENCH_engine.json`):
/// `{"context": {...}, "measurements": [...]}`. `context` carries run
/// parameters (shape, token counts, backend) so successive runs are
/// comparable.
pub fn write_json(
    path: &Path,
    context: &[(&str, Json)],
    measurements: &[Measurement],
) -> std::io::Result<()> {
    let mut ctx = BTreeMap::new();
    for (k, v) in context {
        ctx.insert(k.to_string(), v.clone());
    }
    let mut root = BTreeMap::new();
    root.insert("context".to_string(), Json::Obj(ctx));
    root.insert(
        "measurements".to_string(),
        Json::Arr(measurements.iter().map(Measurement::to_json).collect()),
    );
    std::fs::write(path, Json::Obj(root).to_string())
}

/// Time `f` with automatic iteration-count calibration toward
/// `target_time_s` total measurement time.
pub fn bench<F: FnMut()>(name: &str, target_time_s: f64, mut f: F) -> Measurement {
    // Warmup + calibration.
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_time_s / once).ceil() as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    Measurement {
        name: name.to_string(),
        iters,
        median_ns: q(0.5),
        p10_ns: q(0.1),
        p90_ns: q(0.9),
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Aligned table printer for bench results / experiment tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn to_string(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", c, width = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_string());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench("noop-ish", 0.01, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(m.median_ns > 0.0);
        assert!(m.p10_ns <= m.median_ns && m.median_ns <= m.p90_ns);
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(&["method", "ppl"]);
        t.row(&["hbllm-row".into(), "6.71".into()]);
        t.row(&["billm".into(), "19.57".into()]);
        let s = t.to_string();
        assert!(s.contains("hbllm-row"));
        assert_eq!(s.lines().count(), 4);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn write_json_roundtrips() {
        let m = Measurement {
            name: "decode".into(),
            iters: 7,
            median_ns: 1234.5,
            p10_ns: 1000.0,
            p90_ns: 2000.0,
        };
        let path = std::env::temp_dir().join("hbllm_bench_test.json");
        write_json(&path, &[("shape", Json::Str("2x16".into()))], &[m]).unwrap();
        let src = std::fs::read_to_string(&path).unwrap();
        std::fs::remove_file(&path).ok();
        let j = Json::parse(&src).unwrap();
        assert_eq!(
            j.at(&["context", "shape"]).and_then(Json::as_str),
            Some("2x16")
        );
        let ms = j.get("measurements").and_then(Json::as_arr).unwrap();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].get("name").and_then(Json::as_str), Some("decode"));
        assert_eq!(ms[0].get("iters").and_then(Json::as_usize), Some(7));
    }
}
