//! In-tree substrates replacing unavailable crates (offline build):
//! PRNG (rand), JSON (serde_json), property testing (proptest),
//! benchmarking (criterion), CLI parsing (clap), leveled logging
//! (log/env_logger).

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;

/// Format a f64 with engineering-friendly precision for tables.
pub fn fmt_sig(x: f64, sig: usize) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    if x == 0.0 {
        return "0".to_string();
    }
    let mag = x.abs().log10().floor() as i32;
    let decimals = (sig as i32 - 1 - mag).max(0) as usize;
    format!("{:.*}", decimals.min(6), x)
}

#[cfg(test)]
mod tests {
    use super::fmt_sig;

    #[test]
    fn fmt_sig_works() {
        assert_eq!(fmt_sig(6.714, 3), "6.71");
        assert_eq!(fmt_sig(123.4, 3), "123");
        assert_eq!(fmt_sig(0.01234, 3), "0.0123");
        assert_eq!(fmt_sig(0.0, 3), "0");
    }
}
