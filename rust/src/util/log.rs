//! Leveled, grep-able logging shim (std-only, no crates): single-line
//! `key=value` records on stderr, timestamped, filtered by the
//! `HBLLM_LOG` environment variable (`error|warn|info|debug`, default
//! `info`). The serving stack routes its operational messages — progress
//! ticks, evictions, KV exhaustion, client drops — through this module so
//! a soak log can be sliced with `grep 'level=warn'` / `grep
//! 'event=evict'` instead of read line by line.
//!
//! The level is parsed **once** (first use) and cached for the process
//! lifetime; emission is a single `eprintln!` with no allocation beyond
//! the caller's message. This is deliberately not a metrics path — the
//! cumulative counters live in `coordinator::metrics`.

use std::sync::OnceLock;
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, ordered so `Error < Warn < Info < Debug` — a record is
/// emitted when its level is at or above the configured threshold's
/// verbosity (i.e. `record <= threshold`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    /// Parse an `HBLLM_LOG` value (case-insensitive). Unknown values are
    /// `None` so the caller can fall back to the default loudly-ignored.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// The process-wide threshold: `$HBLLM_LOG`, parsed once, default `info`.
pub fn threshold() -> Level {
    static THRESHOLD: OnceLock<Level> = OnceLock::new();
    *THRESHOLD.get_or_init(|| {
        std::env::var("HBLLM_LOG").ok().and_then(|v| Level::parse(&v)).unwrap_or(Level::Info)
    })
}

/// Whether a record at `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level <= threshold()
}

/// Format one record: `ts=<unix-millis> level=<level> <msg>`. Pure so
/// tests can pin the exact shape; `msg` is expected to already be
/// `key=value` pairs (the caller owns its fields).
pub fn format_line(ts_millis: u128, level: Level, msg: &str) -> String {
    format!("ts={ts_millis} level={} {msg}", level.as_str())
}

/// Emit one record to stderr if `level` passes the threshold.
pub fn log(level: Level, msg: &str) {
    if !enabled(level) {
        return;
    }
    let ts = SystemTime::now().duration_since(UNIX_EPOCH).map(|d| d.as_millis()).unwrap_or(0);
    eprintln!("{}", format_line(ts, level, msg));
}

pub fn error(msg: &str) {
    log(Level::Error, msg);
}

pub fn warn(msg: &str) {
    log(Level::Warn, msg);
}

pub fn info(msg: &str) {
    log(Level::Info, msg);
}

pub fn debug(msg: &str) {
    log(Level::Debug, msg);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_case_insensitively() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse(" Info "), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
        assert_eq!(Level::parse(""), None);
    }

    #[test]
    fn level_order_matches_verbosity() {
        // a record passes when its level <= threshold
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn format_is_single_line_key_value() {
        let line = format_line(1723110000123, Level::Warn, "event=evict lane=3 cause=kv_exhausted");
        assert_eq!(line, "ts=1723110000123 level=warn event=evict lane=3 cause=kv_exhausted");
        assert!(!line.contains('\n'));
    }

    #[test]
    fn threshold_defaults_sanely() {
        // whatever HBLLM_LOG says (or doesn't), the threshold is a valid
        // level and warn-or-louder is never filtered below `warn` config
        let t = threshold();
        assert!(Level::parse(t.as_str()) == Some(t));
        if t >= Level::Warn {
            assert!(enabled(Level::Warn));
        }
        // errors are never filtered: Error is the minimum level
        assert!(enabled(Level::Error));
    }
}
