//! CLI command dispatch for the `hbllm` binary.
//!
//! Subcommands:
//!   info                         artifact + platform summary
//!   quantize  --method M         quantize, report per-layer metrics
//!                                (`--save model.hbq` writes the artifact)
//!   eval      --method M         quantize + perplexity/QA row
//!   serve     --method M --addr  continuous-batching generation + scoring
//!                                server (`--lanes`, `--max-new`,
//!                                `--kv-blocks`, `--block-len`, `--spec-k`;
//!                                `--http-port` adds the HTTP/SSE
//!                                front-end; `--load model.hbq` serves a
//!                                saved artifact without re-quantizing)
//!   generate  [--method M]       sample text locally (`--load`, `--spec-k`),
//!                                or stream from a running server's HTTP
//!                                front-end (`--url`, `--priority`)
//!   ciq                          CIQ expressiveness table (§3.1)
//!
//! The serving wire protocols (TCP verbs and HTTP endpoints) are
//! specified in `docs/API.md`.

use crate::coordinator::{
    http, run_router, serve, BatcherConfig, Priority, QuantJobConfig, RouterConfig,
};
use crate::engine::{self, Backend, BackendKind, SpecConfig};
use crate::pipeline::{EvalScope, Session};
use crate::quant::{self, ciq, synth, Quantizer};
use crate::util::bench::Table;
use crate::util::cli::Args;
use crate::util::fmt_sig;
use anyhow::{anyhow, Result};
use std::path::Path;

pub fn run(args: Args) -> Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => info(&args),
        "quantize" => quantize(&args),
        "eval" => eval(&args),
        "serve" => serve_cmd(&args),
        "router" => router_cmd(&args),
        "generate" => generate_cmd(&args),
        "ciq" => ciq_cmd(&args),
        "help" | _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
hbllm — wavelet-enhanced 1-bit PTQ for LLMs (NeurIPS 2025 reproduction)

USAGE: hbllm <command> [options]

COMMANDS:
  info                     show artifacts, model and PJRT platform
  quantize --method M      quantize the model, print per-layer metrics
  eval --method M          quantize + evaluate (perplexity on c4s/wiki2s/ptbs + AvgQA)
  serve --method M         TCP generation + scoring server
                           (`ppl <text>`, `gen <max-new> <temp> <seed> <prompt>`,
                           `prio <interactive|batch> gen ...` verbs;
                           `--http-port` adds HTTP/SSE endpoints; SIGTERM
                           drains gracefully, as do the `drain` verb and
                           POST /v1/drain)
  router --workers A,B     multi-replica front-end over running serve
                           workers: same wire protocols, load-aware sticky
                           placement, transparent retry on replica death
                           (also reachable as `serve --router`)
  generate [--method M]    sample text from the (optionally quantized) model,
                           or from a running server with `--url`
  ciq                      CIQ expressiveness table (paper §3.1)

OPTIONS:
  --artifacts DIR          artifacts root (default: artifacts/ or $HBLLM_ARTIFACTS)
  --method M               rtn|billm|arb-x|arb-rc|pb-llm|framequant-1.1|hbllm-row|hbllm-col
  --backend B              xla (PJRT over dequantized fp32, default) or
                           native (pure-Rust packed engine with KV cache)
  --workers N              quantization worker threads
                           (router: comma-separated worker addresses instead,
                           e.g. --workers 127.0.0.1:7431,127.0.0.1:7441)
  --ppl-windows N          eval windows per corpus (default 64)
  --qa-items N             QA items per family (default 25)
  --calib-windows N        calibration windows (default 16)
  --save FILE              quantize: also write the packed .hbq artifact
  --load FILE              serve/generate: execute a saved .hbq artifact on
                           the native engine instead of re-quantizing at
                           startup (--method not needed)
  --addr HOST:PORT         serve address (default 127.0.0.1:7431)
  --http-port N            serve: also expose the HTTP/SSE front-end on this
                           port, same host as --addr (POST /v1/generate
                           streams SSE, POST /v1/score, GET /v1/stats,
                           GET /v1/metrics in Prometheus text format,
                           GET /v1/trace with --trace;
                           spec in docs/API.md and docs/OBSERVABILITY.md)
  --url http://HOST:PORT   generate: stream from a running server's HTTP
                           front-end instead of loading a model locally
  --priority P             generate --url: admission tier, interactive
                           (default) or batch
  --lanes N                serve: concurrent KV decode lanes (default 4;
                           continuous batching sweeps the packed weights
                           once per token across all active lanes)
  --kv-blocks N            serve: paged KV arena size in blocks (default:
                           worst case, lanes x ceil(seq/block-len); smaller
                           values trade memory for admission backpressure)
  --block-len N            serve: tokens per KV block (default 16)
  --max-new N              serve: per-request generated-token cap (default 256)
                           generate: tokens to sample (default 120)
  --spec-k N               speculative decoding: draft N tokens per round
                           with the Haar low band, verify with the full
                           packed model (greedy only; output is
                           byte-identical to plain decode; default off)
  --prefix-cache N         serve: keep up to N finished prompts' KV prefixes
                           resident; later requests sharing a prefix map the
                           blocks read-only (copy-on-write) instead of
                           re-prefilling (needs the native paged-KV backend;
                           default 0 = off)
  --trace N                serve: flight-record the last N finished requests'
                           span timelines (enqueue/admit/prefill/sweeps/first
                           token/finish) for GET /v1/trace — plain JSON, or
                           ?format=chrome for Perfetto (default 0 = off; the
                           per-token path stays allocation-free when off)
  --pallas                 use the Pallas-attention HLO entry (xla backend)

ROUTER OPTIONS (docs/ARCHITECTURE.md section \"Router tier\"):
  --addr HOST:PORT         router TCP listen address (default 127.0.0.1:7430)
  --http-port N            router HTTP front-end port (same host as --addr)
  --health-interval-ms N   worker /v1/stats poll period (default 50)
  --sticky-prefix N        prompt bytes hashed for sticky placement (default 32)
  --load-slack N           extra load the sticky worker may carry before
                           placement falls back to least-loaded (default 8)

ENVIRONMENT:
  HBLLM_KERNEL=K           force the packed-GEMV kernel (scalar|avx2|neon);
                           unset auto-selects by CPU feature detection. All
                           kernels are pinned bit-identical, so this only
                           changes speed — scalar is the debugging reference
  HBLLM_LOG=LEVEL          log threshold (error|warn|info|debug)
";

fn session(args: &Args) -> Result<Session> {
    let root = args
        .get("artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(Session::default_root);
    Session::open(&root)
}

fn scope(args: &Args) -> EvalScope {
    EvalScope {
        ppl_windows: args.get_usize("ppl-windows", 64),
        qa_items: args.get_usize("qa-items", 25),
        calib_windows: args.get_usize("calib-windows", 16),
    }
}

fn job(args: &Args) -> QuantJobConfig {
    let mut cfg = QuantJobConfig::default();
    if let Some(w) = args.get("workers") {
        cfg.workers = w.parse().unwrap_or(cfg.workers);
    }
    cfg.quiet = args.has_flag("quiet");
    cfg
}

fn method(args: &Args) -> Result<Box<dyn Quantizer>> {
    let name = args.get("method").ok_or_else(|| anyhow!("--method required"))?;
    quant::by_name(name).ok_or_else(|| anyhow!("unknown method {name}"))
}

/// Backend kind from `--backend` / `--pallas`. For the native engine,
/// `pack` selects the 1-bit Haar-packed form (quantized serving) vs dense
/// fp32 (reference serving).
fn backend_kind(args: &Args, pack: bool) -> Result<BackendKind> {
    BackendKind::parse(args.get_or("backend", "xla"), args.has_flag("pallas"), pack)
}

/// Only HBLLM weights have the packed 1-bit deployment form; packing the
/// other baselines' dequantized weights would re-quantize them into HBLLM's
/// 2-band shape and misreport the named method. They serve dense natively.
fn native_pack(method_name: &str) -> bool {
    method_name.starts_with("hbllm")
}

fn info(args: &Args) -> Result<()> {
    let s = session(args)?;
    let cfg = &s.fp_weights().config;
    println!("artifacts : {}", s.root.display());
    println!("platform  : {}", s.runtime.platform());
    println!(
        "model     : {} (d={} L={} heads={} ff={} seq={} vocab={}) — {:.2}M params",
        cfg.name,
        cfg.d_model,
        cfg.n_layers,
        cfg.n_heads,
        cfg.d_ff,
        cfg.seq_len,
        cfg.vocab,
        s.fp_weights().total_elements() as f64 / 1e6
    );
    println!("linears   : {}", cfg.linear_names().len());
    println!("methods   : {}", quant::table_methods().join(", "));
    Ok(())
}

fn quantize(args: &Args) -> Result<()> {
    let mut s = session(args)?;
    let m = method(args)?;
    let (qw, results) = s.quantize(m.as_ref(), &scope(args), &job(args))?;
    let mut t = Table::new(&["layer", "shape", "mse", "wbits", "sec"]);
    for r in &results {
        t.row(&[
            r.name.clone(),
            format!("{}x{}", r.rows, r.cols),
            format!("{:.3e}", r.mse),
            fmt_sig(r.wbits, 4),
            format!("{:.2}", r.seconds),
        ]);
    }
    t.print();
    let agg = crate::coordinator::scheduler::aggregate_wbits(&results);
    println!("aggregate W-bits: {}", fmt_sig(agg, 4));
    if let Some(path) = args.get("save") {
        // HBQ1 *is* the Haar-packed 1-bit form: packing a baseline's
        // weights would silently re-quantize them into HBLLM's shape —
        // the same misreporting native serving refuses (`native_pack`)
        anyhow::ensure!(
            native_pack(&m.name()),
            "--save writes the HBQ1 Haar-packed 1-bit deployment form; packing {} \
             weights would silently re-quantize them (use an hbllm-* method)",
            m.name()
        );
        let art = crate::pack::format::PackedModel::from_weights(&qw);
        art.save(Path::new(path))?;
        println!(
            "saved packed artifact to {path} ({} file bits/linear weight); serve it with --load",
            fmt_sig(art.file_bits_per_linear_weight(), 4)
        );
    }
    Ok(())
}

fn eval(args: &Args) -> Result<()> {
    let mut s = session(args)?;
    let m = method(args)?;
    let sc = scope(args);
    let jb = job(args);
    // fp32 reference serves dense (pack = false); the quantized model is
    // served packed when the native backend is selected
    let fp_kind = backend_kind(args, false)?;
    let q_kind = backend_kind(args, native_pack(&m.name()))?;

    let mut fp_be = s.backend(s.fp_weights(), fp_kind)?;
    let fp = s.evaluate(fp_be.as_mut(), &sc)?;
    let (qw, results) = s.quantize(m.as_ref(), &sc, &jb)?;
    let mut q_be = s.backend(&qw, q_kind)?;
    let report = s.evaluate(q_be.as_mut(), &sc)?;
    println!("backend: {}", q_be.name());

    let mut t = Table::new(&["method", "W-bits", "c4s", "wiki2s", "ptbs", "AvgQA", "relPPL"]);
    t.row(&[
        "fp32".into(),
        "32.00".into(),
        fmt_sig(fp.ppl_of("c4s"), 4),
        fmt_sig(fp.ppl_of("wiki2s"), 4),
        fmt_sig(fp.ppl_of("ptbs"), 4),
        format!("{:.1}%", 100.0 * fp.avg_qa),
        "1.00".into(),
    ]);
    let agg = crate::coordinator::scheduler::aggregate_wbits(&results);
    t.row(&[
        m.name(),
        fmt_sig(agg, 4),
        fmt_sig(report.ppl_of("c4s"), 4),
        fmt_sig(report.ppl_of("wiki2s"), 4),
        fmt_sig(report.ppl_of("ptbs"), 4),
        format!("{:.1}%", 100.0 * report.avg_qa),
        fmt_sig(report.mean_rel_ppl(&fp), 3),
    ]);
    t.print();
    Ok(())
}

fn serve_cmd(args: &Args) -> Result<()> {
    // `serve --router` is the router tier under the familiar verb — no
    // model, no engine; it fans out to already-running workers
    if args.has_flag("router") {
        return router_cmd(args);
    }
    // SIGTERM = graceful drain: admission closes, queued requests get
    // `err draining`, active lanes finish, then the process exits
    serve::install_sigterm_drain();
    let mut s = session(args)?;
    let lanes = args.get_usize("lanes", 4);
    let kv_blocks = args.get("kv-blocks").and_then(|v| v.parse().ok());
    let block_len = args.get("block-len").and_then(|v| v.parse().ok());
    // either execute a saved .hbq artifact directly (native engine, no
    // startup re-quantization) or quantize from the session weights
    let (mut be, label) = match args.get("load") {
        Some(path) => {
            let be = s.loaded_backend(Path::new(path), lanes, kv_blocks, block_len)?;
            (be, format!("artifact {path}"))
        }
        None => {
            let m = method(args)?;
            let (qw, _) = s.quantize(m.as_ref(), &scope(args), &job(args))?;
            let be = s.serve_backend(
                &qw,
                backend_kind(args, native_pack(&m.name()))?,
                lanes,
                kv_blocks,
                block_len,
            )?;
            (be, m.name())
        }
    };
    // effective config: backends without a draft path report it disabled
    // and the scheduler falls back to plain decoding
    let spec = be.set_spec(SpecConfig::with_k(args.get_usize("spec-k", 0)));
    let cfg = BatcherConfig {
        max_new_cap: args.get_usize("max-new", BatcherConfig::default().max_new_cap),
        spec,
        prefix_cache: args.get_usize("prefix-cache", 0),
        trace: args.get_usize("trace", 0),
        ..Default::default()
    };
    let addr = args.get_or("addr", "127.0.0.1:7431");
    let (listener, local) = serve::bind(addr)?;
    // --http-port binds the HTTP/SSE front-end on the same host; both
    // listeners feed one engine loop (shared lanes, fairness, KV budget)
    let http = match args.get("http-port") {
        Some(p) => {
            let port: u16 = p.parse().map_err(|_| anyhow!("bad --http-port {p}"))?;
            let http_addr = std::net::SocketAddr::new(local.ip(), port);
            Some(serve::bind(&http_addr.to_string())?)
        }
        None => None,
    };
    println!(
        "serving quantized ({label}) model on {local} [backend {}, {} lanes, max-new {}, \
         gemv kernel {}]",
        be.name(),
        be.lanes(),
        cfg.max_new_cap,
        crate::pack::kernels::active().name
    );
    if let Some((_, http_addr)) = &http {
        println!(
            "http front-end on {http_addr}: POST /v1/generate (SSE) | POST /v1/score | GET /v1/stats | GET /v1/metrics{}",
            if cfg.trace > 0 { " | GET /v1/trace" } else { "" }
        );
    }
    if cfg.trace > 0 {
        println!(
            "request tracing: flight recorder keeps the last {} finished requests' \
             span timelines (plus the slowest-TTFT exemplars); fetch GET /v1/trace, \
             or ?format=chrome for Perfetto",
            cfg.trace
        );
    }
    if let Some(st) = be.kv_stats() {
        println!(
            "paged kv: {} blocks x {} tokens ({:.2} MiB arena); undersized arenas \
             apply admission backpressure and evict with `err kv exhausted`",
            st.total_blocks,
            st.block_len,
            st.arena_bytes as f64 / (1024.0 * 1024.0)
        );
    }
    if spec.enabled {
        println!(
            "speculative decoding: Haar low-band draft, k={} (greedy requests only; \
             byte-identical output; draft KV allocated lazily per speculating lane, \
             outside the paged arena above; acceptance reported on shutdown)",
            spec.k
        );
    }
    if cfg.prefix_cache > 0 {
        println!(
            "prefix cache: up to {} finished prompts keep their KV blocks resident \
             (shared read-only via copy-on-write; hit rate reported on shutdown)",
            cfg.prefix_cache
        );
    }
    println!(
        "protocol: `ppl <text>` -> `ppl <v>` | `[prio <interactive|batch>] gen <max-new> <temp> <seed> <prompt>` -> `tok <byte>`* `done <n>`"
    );
    let mut fronts = vec![serve::FrontEnd::line(listener, None)];
    if let Some((http_listener, _)) = http {
        fronts.push(http::HttpConn::front_end(http_listener, None));
    }
    let metrics = serve::serve_fronts(fronts, be.as_mut(), cfg)?;
    if let Some(st) = be.spec_stats() {
        if st.enabled && st.drafted > 0 {
            println!(
                "spec acceptance: {:.1}% ({} of {} drafts over {} rounds; \
                 draft kv {:.1} KiB)",
                100.0 * st.acceptance(),
                st.accepted,
                st.drafted,
                st.rounds,
                st.draft_kv_bytes as f64 / 1024.0
            );
        }
    }
    let (hits, misses) =
        (metrics.prefix_cache_hits.get(), metrics.prefix_cache_misses.get());
    if hits + misses > 0 {
        let hwm = be.kv_stats().map_or(0, |st| st.shared_hwm);
        println!(
            "prefix cache: {:.1}% hit rate ({hits} of {} admissions; \
             shared-block high water {hwm})",
            100.0 * hits as f64 / (hits + misses) as f64,
            hits + misses
        );
    }
    // per-tier latency quantiles — the same bucket-interpolated estimator
    // `/v1/stats.latency` serves, so the shutdown line matches monitoring
    for (name, t) in [("interactive", metrics.tier(0)), ("batch", metrics.tier(1))] {
        let p = |q| t.ttft_us.quantile(q);
        if let (Some(p50), Some(p95), Some(p99)) = (p(0.5), p(0.95), p(0.99)) {
            let opt = |v: Option<f64>| v.map_or("-".to_string(), |x| format!("{x:.0}"));
            println!(
                "latency [{name}]: ttft p50/p95/p99 {p50:.0}/{p95:.0}/{p99:.0} us | \
                 inter-token p99 {} us | queue-wait p99 {} us",
                opt(t.inter_token_us.quantile(0.99)),
                opt(t.queue_wait_us.quantile(0.99)),
            );
        }
    }
    Ok(())
}

/// The router tier (`router --workers a:p,b:p` or `serve --router`): a
/// front-end over already-running `serve` worker processes. Speaks the
/// same TCP/HTTP protocols to clients; placement, stickiness and retry
/// semantics are documented in `docs/ARCHITECTURE.md` §Router tier.
fn router_cmd(args: &Args) -> Result<()> {
    let workers: Vec<String> = args
        .get("workers")
        .ok_or_else(|| anyhow!("--workers host:port[,host:port,...] required"))?
        .split(',')
        .map(|w| w.trim().to_string())
        .filter(|w| !w.is_empty())
        .collect();
    anyhow::ensure!(!workers.is_empty(), "--workers needs at least one host:port");
    let mut cfg = RouterConfig::default();
    if let Some(ms) = args.get("health-interval-ms") {
        let ms: u64 = ms.parse().map_err(|_| anyhow!("bad --health-interval-ms {ms}"))?;
        cfg.health_interval = std::time::Duration::from_millis(ms.max(1));
    }
    cfg.sticky_prefix = args.get_usize("sticky-prefix", cfg.sticky_prefix);
    cfg.load_slack = args.get_usize("load-slack", cfg.load_slack as usize) as u64;
    let addr = args.get_or("addr", "127.0.0.1:7430");
    let (listener, local) = serve::bind(addr)?;
    let http = match args.get("http-port") {
        Some(p) => {
            let port: u16 = p.parse().map_err(|_| anyhow!("bad --http-port {p}"))?;
            let http_addr = std::net::SocketAddr::new(local.ip(), port);
            Some(serve::bind(&http_addr.to_string())?)
        }
        None => None,
    };
    println!(
        "router on {local} over {} worker{}: {}",
        workers.len(),
        if workers.len() == 1 { "" } else { "s" },
        workers.join(", ")
    );
    if let Some((_, http_addr)) = &http {
        println!(
            "http front-end on {http_addr}: POST /v1/generate (SSE) | POST /v1/score | \
             GET /v1/stats (fleet) | GET /v1/metrics | GET|POST /v1/workers"
        );
    }
    println!(
        "placement: sticky prefix hash over {} prompt bytes, load slack {}, \
         health poll every {:?}",
        cfg.sticky_prefix, cfg.load_slack, cfg.health_interval
    );
    let metrics =
        run_router(Some((listener, None)), http.map(|(l, _)| (l, None)), workers, cfg)?;
    println!(
        "router done: {} tcp + {} http requests, {} retried",
        metrics.requests[0].get(),
        metrics.requests[1].get(),
        metrics.retries.get()
    );
    Ok(())
}

fn generate_cmd(args: &Args) -> Result<()> {
    // thin-client mode: stream from a running server's HTTP front-end —
    // no session, no artifacts, no local model
    if let Some(url) = args.get("url") {
        use std::io::Write as _;
        let prompt = args.get_or("prompt", "ta kivo ");
        let n_new = args.get_usize("max-new", args.get_usize("tokens", 120));
        let temp = args.get_f64("temperature", 0.8) as f32;
        let seed = args.get_usize("seed", 0) as u64;
        let priority = match args.get("priority") {
            Some(p) => Priority::parse(p)
                .ok_or_else(|| anyhow!("unknown --priority {p} (expected interactive|batch)"))?,
            None => Priority::Interactive,
        };
        print!("{prompt}");
        std::io::stdout().flush().ok();
        let n = http::client_generate(url, prompt, n_new, temp, seed, priority, |b| {
            let mut out = std::io::stdout();
            out.write_all(&[b]).ok();
            out.flush().ok();
        })?;
        println!();
        eprintln!("[{n} bytes streamed from {url}, priority {}]", priority.as_str());
        return Ok(());
    }
    let mut s = session(args)?;
    let mut be = match args.get("load") {
        Some(path) => s.loaded_backend(Path::new(path), 1, None, None)?,
        None => {
            let (weights, pack) = match args.get("method") {
                Some(_) => {
                    let m = method(args)?;
                    eprintln!("quantizing with {}...", m.name());
                    let w = s.quantize(m.as_ref(), &scope(args), &job(args))?.0;
                    let pack = native_pack(&m.name());
                    (w, pack)
                }
                None => (s.clone_weights(), false),
            };
            s.gen_backend(&weights, backend_kind(args, pack)?)?
        }
    };
    let prompt = args.get_or("prompt", "ta kivo ").as_bytes().to_vec();
    let n_new = args.get_usize("max-new", args.get_usize("tokens", 120));
    let temp = args.get_f64("temperature", 0.8) as f32;
    let spec_k = args.get_usize("spec-k", 0);
    let out = if spec_k > 0 && temp <= 0.0 {
        be.set_spec(SpecConfig::with_k(spec_k));
        engine::generate_spec(be.as_mut(), &prompt, n_new, spec_k)?
    } else {
        if spec_k > 0 {
            eprintln!("--spec-k needs greedy decoding (--temperature 0); sampling plainly");
        }
        let mut rng = crate::util::rng::Pcg32::seeded(args.get_usize("seed", 0) as u64);
        engine::generate(be.as_mut(), &prompt, n_new, temp, &mut rng)?
    };
    println!("{}", String::from_utf8_lossy(&out));
    if let Some(st) = be.spec_stats() {
        if st.drafted > 0 {
            eprintln!(
                "[spec k={} acceptance {:.1}% — {} of {} drafts over {} rounds]",
                spec_k,
                100.0 * st.acceptance(),
                st.accepted,
                st.drafted,
                st.rounds
            );
        }
    }
    Ok(())
}

fn ciq_cmd(_args: &Args) -> Result<()> {
    // §3.1 expressiveness table on a synthetic LLM-like layer
    let (w, ctx) = synth::llm_like_layer(64, 128, 1);
    let mut t = Table::new(&["method", "CIQ max", "CIQ mean", "theory bound"]);
    for name in ["rtn", "billm", "arb-x", "arb-rc", "hbllm-col", "hbllm-row"] {
        let q = quant::by_name(name).unwrap();
        let out = q.quantize(&w, &ctx);
        let bound = ciq::theoretical_bound(name, 128);
        t.row(&[
            name.into(),
            format!("{}", ciq::row_ciq_max(&out.w_hat)),
            format!("{:.1}", ciq::row_ciq_mean(&out.w_hat)),
            if bound == usize::MAX { "-".into() } else { format!("{bound}") },
        ]);
    }
    t.print();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::cli::Args;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn help_runs() {
        run(parse("help")).unwrap();
    }

    #[test]
    fn unknown_method_errors() {
        let args = parse("eval --method bogus");
        assert!(method(&args).is_err());
        assert!(method(&parse("eval --method hbllm-row")).is_ok());
    }

    #[test]
    fn scope_defaults_and_overrides() {
        let sc = scope(&parse("eval --ppl-windows 5"));
        assert_eq!(sc.ppl_windows, 5);
        assert_eq!(sc.qa_items, 25);
    }

    #[test]
    fn ciq_command_runs() {
        run(parse("ciq")).unwrap();
    }

    #[test]
    fn serve_flags_parse() {
        let a = parse("serve --method hbllm-row --lanes 8 --max-new 64");
        assert_eq!(a.get_usize("lanes", 4), 8);
        assert_eq!(a.get_usize("max-new", 256), 64);
        // defaults
        let a = parse("serve --method hbllm-row");
        assert_eq!(a.get_usize("lanes", 4), 4);
    }

    #[test]
    fn serve_kv_flags_parse() {
        let a = parse("serve --method hbllm-row --kv-blocks 32 --block-len 8");
        assert_eq!(a.get("kv-blocks").and_then(|v| v.parse::<usize>().ok()), Some(32));
        assert_eq!(a.get("block-len").and_then(|v| v.parse::<usize>().ok()), Some(8));
        // absent flags mean worst-case defaults (None reaches the backend)
        let a = parse("serve --method hbllm-row");
        assert_eq!(a.get("kv-blocks"), None);
        assert_eq!(a.get("block-len"), None);
    }

    #[test]
    fn http_and_url_flags_parse() {
        let a = parse("serve --method hbllm-row --http-port 7432");
        assert_eq!(a.get("http-port").and_then(|v| v.parse::<u16>().ok()), Some(7432));
        // absent flag keeps the HTTP front-end off
        assert_eq!(parse("serve --method hbllm-row").get("http-port"), None);
        let a = parse("generate --url http://127.0.0.1:7432 --priority batch");
        assert_eq!(a.get("url"), Some("http://127.0.0.1:7432"));
        assert_eq!(a.get("priority").and_then(Priority::parse), Some(Priority::Batch));
        assert_eq!(parse("generate --url http://h --priority urgent")
            .get("priority")
            .and_then(Priority::parse), None);
    }

    #[test]
    fn prefix_cache_flag_parses() {
        let a = parse("serve --method hbllm-row --prefix-cache 16");
        assert_eq!(a.get_usize("prefix-cache", 0), 16);
        // absent flag keeps prompt-prefix caching off
        assert_eq!(parse("serve --method hbllm-row").get_usize("prefix-cache", 0), 0);
    }

    #[test]
    fn trace_flag_parses() {
        let a = parse("serve --method hbllm-row --trace 128");
        assert_eq!(a.get_usize("trace", 0), 128);
        // absent flag keeps the flight recorder off (no per-request
        // timeline allocation on the decode path)
        assert_eq!(parse("serve --method hbllm-row").get_usize("trace", 0), 0);
    }

    #[test]
    fn spec_load_save_flags_parse() {
        let a = parse("serve --method hbllm-row --spec-k 4");
        assert_eq!(a.get_usize("spec-k", 0), 4);
        let a = parse("serve --load model.hbq");
        assert_eq!(a.get("load"), Some("model.hbq"));
        assert_eq!(a.get_usize("spec-k", 0), 0, "spec defaults off");
        let a = parse("quantize --method hbllm-row --save out.hbq");
        assert_eq!(a.get("save"), Some("out.hbq"));
    }

    #[test]
    fn router_flags_parse() {
        let a = parse("router --workers 127.0.0.1:7431,127.0.0.1:7441 --http-port 7430");
        let workers: Vec<&str> = a.get("workers").unwrap().split(',').collect();
        assert_eq!(workers, ["127.0.0.1:7431", "127.0.0.1:7441"]);
        assert_eq!(a.get("http-port").and_then(|v| v.parse::<u16>().ok()), Some(7430));
        // tuning knobs fall back to RouterConfig defaults when absent
        assert_eq!(a.get_usize("sticky-prefix", 32), 32);
        assert_eq!(a.get_usize("load-slack", 8), 8);
        assert_eq!(a.get("health-interval-ms"), None);
        let a = parse("router --workers a:1 --sticky-prefix 16 --load-slack 2 --health-interval-ms 25");
        assert_eq!(a.get_usize("sticky-prefix", 32), 16);
        assert_eq!(a.get_usize("load-slack", 8), 2);
        assert_eq!(a.get("health-interval-ms"), Some("25"));
        // `serve --router` delegates to router mode
        assert!(parse("serve --router --workers a:1").has_flag("router"));
        assert!(!parse("serve --method hbllm-row").has_flag("router"));
        // a router with no fleet is a usage error, not a hang
        assert!(router_cmd(&parse("router")).is_err());
        assert!(router_cmd(&parse("router --workers ,")).is_err());
    }

    #[test]
    fn backend_flag_parses() {
        use crate::engine::BackendKind;
        let a = parse("eval --method hbllm-row --backend native");
        assert_eq!(backend_kind(&a, true).unwrap(), BackendKind::Native { pack: true });
        let a = parse("eval --method hbllm-row --pallas");
        assert_eq!(backend_kind(&a, false).unwrap(), BackendKind::Xla { pallas: true });
        assert!(backend_kind(&parse("eval --backend gpu"), false).is_err());
    }
}
