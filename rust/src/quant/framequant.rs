//! FrameQuant baseline (Adepu et al., ICML 2024): 2-bit quantization in a
//! redundant tight-frame basis. We realize the frame as a randomized
//! butterfly orthogonal transform (O(d log d), exactly orthogonal) on the
//! row space, optionally expanded by redundancy r ≥ 1; quantization is
//! 2-bit with per-group scales. Dequantization costs a full O(d²)-equivalent
//! inverse mix — the inference-latency contrast HBLLM draws in §3.6.

use super::{storage, BitsBreakdown, HessianCtx, QuantOut, Quantizer};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

pub struct FrameQuant {
    pub redundancy: f64,
    pub group: usize,
    pub seed: u64,
}

impl FrameQuant {
    pub fn new(redundancy: f64) -> FrameQuant {
        FrameQuant { redundancy, group: 128, seed: 0x46524d51 }
    }
}

/// Randomized butterfly orthogonal transform on vectors of length 2^k ≥ len:
/// pad to the next power of two, apply `rounds` of (random diagonal ±1,
/// Hadamard-style butterfly), giving an exactly orthogonal mixing matrix.
pub struct Butterfly {
    pub n_pad: usize,
    signs: Vec<Vec<f32>>, // per round random ±1 diagonal
}

impl Butterfly {
    pub fn new(len: usize, seed: u64, rounds: usize) -> Butterfly {
        let n_pad = len.next_power_of_two();
        let mut rng = Pcg32::seeded(seed);
        let signs = (0..rounds)
            .map(|_| (0..n_pad).map(|_| if rng.f32() < 0.5 { -1.0 } else { 1.0 }).collect())
            .collect();
        Butterfly { n_pad, signs }
    }

    fn hadamard_inplace(x: &mut [f32]) {
        let n = x.len();
        let mut h = 1;
        while h < n {
            for i in (0..n).step_by(2 * h) {
                for j in i..i + h {
                    let a = x[j];
                    let b = x[j + h];
                    x[j] = a + b;
                    x[j + h] = a - b;
                }
            }
            h *= 2;
        }
        let scale = 1.0 / (n as f32).sqrt();
        for v in x.iter_mut() {
            *v *= scale;
        }
    }

    pub fn fwd(&self, x: &[f32]) -> Vec<f32> {
        let mut v = vec![0.0f32; self.n_pad];
        v[..x.len()].copy_from_slice(x);
        for s in &self.signs {
            for (a, b) in v.iter_mut().zip(s.iter()) {
                *a *= b;
            }
            Self::hadamard_inplace(&mut v);
        }
        v
    }

    pub fn inv(&self, y: &[f32]) -> Vec<f32> {
        let mut v = y.to_vec();
        for s in self.signs.iter().rev() {
            // hadamard is its own inverse (orthonormal), then undo diagonal
            Self::hadamard_inplace(&mut v);
            for (a, b) in v.iter_mut().zip(s.iter()) {
                *a *= b;
            }
        }
        v
    }
}

/// 2-bit symmetric quantization with per-group absmax scales.
fn quant_2bit(vals: &mut [f32], group: usize) {
    for chunk in vals.chunks_mut(group) {
        let amax = chunk.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        if amax == 0.0 {
            continue;
        }
        // levels {-3,-1,1,3} / 3 * amax  (uniform symmetric 2-bit)
        let step = amax / 3.0;
        for v in chunk.iter_mut() {
            let q = (*v / step).round().clamp(-3.0, 3.0);
            // force odd levels (sign-magnitude 2-bit): {-3,-1,1,3}
            let q = if q == 0.0 {
                1.0f32.copysign(*v)
            } else if q == 2.0 || q == -2.0 {
                (q + q.signum()) .clamp(-3.0, 3.0)
            } else {
                q
            };
            *v = q * step;
        }
    }
}

impl Quantizer for FrameQuant {
    fn name(&self) -> String {
        format!("framequant-{:.1}", self.redundancy)
    }

    fn quantize(&self, w: &Matrix, _ctx: &HessianCtx) -> QuantOut {
        // Frame analysis on the column (input) axis per row: y = B(x_pad),
        // with redundancy realized by keeping the padded length ≥ r·m.
        let target = ((w.cols as f64) * self.redundancy).ceil() as usize;
        let bf = Butterfly::new(target, self.seed, 3);
        let mut out = Matrix::zeros(w.rows, w.cols);
        for i in 0..w.rows {
            let mut y = bf.fwd(w.row(i));
            quant_2bit(&mut y, self.group);
            let back = bf.inv(&y);
            out.row_mut(i).copy_from_slice(&back[..w.cols]);
        }
        let mse = w.mse(&out);
        QuantOut { bits: self.storage_bits(w.rows, w.cols), w_hat: out, mse }
    }

    fn storage_bits(&self, n: usize, m: usize) -> BitsBreakdown {
        storage::framequant_bits(n, m, self.redundancy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::quant::synth;
    use crate::quant::Quantizer;
    use crate::util::rng::Pcg32;

    #[test]
    fn butterfly_is_orthogonal() {
        let bf = Butterfly::new(64, 7, 3);
        let mut rng = Pcg32::seeded(1);
        let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let y = bf.fwd(&x);
        // norm preserved
        let nx: f32 = x.iter().map(|v| v * v).sum();
        let ny: f32 = y.iter().map(|v| v * v).sum();
        assert!((nx - ny).abs() / nx < 1e-4, "{nx} vs {ny}");
        // exact inverse
        let back = bf.inv(&y);
        for (a, b) in x.iter().zip(back.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn two_bits_beat_one_bit() {
        let (w, ctx) = synth::llm_like_layer(16, 64, 40);
        let f = FrameQuant::new(1.0).quantize(&w, &ctx);
        let r = Rtn.quantize(&w, &ctx);
        assert!(f.mse < r.mse, "framequant {} !< rtn {}", f.mse, r.mse);
    }

    #[test]
    fn redundancy_helps() {
        let (w, ctx) = synth::llm_like_layer(16, 96, 41);
        let f10 = FrameQuant::new(1.0).quantize(&w, &ctx);
        let f11 = FrameQuant::new(1.5).quantize(&w, &ctx);
        // more redundancy, (weakly) better reconstruction
        assert!(f11.mse < f10.mse * 1.2, "r=1.5 {} vs r=1.0 {}", f11.mse, f10.mse);
    }

    #[test]
    fn wbits_2_2_at_r11() {
        let b = FrameQuant::new(1.1).avg_wbits(4096, 4096);
        assert!((b - 2.2).abs() < 0.2, "{b}");
    }
}
