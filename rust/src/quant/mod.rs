//! Quantization methods: HBLLM (the paper's contribution) and every baseline
//! it is compared against (BiLLM, ARB-LLM_X/RC, PB-LLM, FrameQuant, RTN).
//!
//! Convention: quantizers receive W in **paper orientation** `[out, in]`
//! (rows = output neurons). The calibration Hessian H = 2XXᵀ is `[in, in]`.
//! The model stores weights as `[in, out]` (x @ W); `model::Weights`
//! transposes on the way in/out of the quantizers.

pub mod arbllm;
pub mod billm;
pub mod binarize;
pub mod ciq;
pub mod framequant;
pub mod gptq;
pub mod gptq2;
pub mod grouping;
pub mod hbllm;
pub mod pbllm;
pub mod rtn;
pub mod salient;
pub mod storage;
pub mod synth;

use crate::tensor::linalg::{gptq_factor, Sq};
use crate::tensor::Matrix;

/// Default damping fraction λ/mean(diag H), as in GPTQ.
pub const DEFAULT_LAMBDA: f64 = 0.01;
/// Default OBQ block size (paper: 128 everywhere).
pub const DEFAULT_BETA: usize = 128;

/// Calibration context shared by all OBQ-based quantizers.
pub struct HessianCtx {
    /// H = 2 X Xᵀ, [in, in]
    pub h: Sq,
    /// Upper-triangular U with (H + λI)^{-1} = Uᵀ U
    pub u: Sq,
    /// diag of (H + λI)^{-1} (salient scoring denominators)
    pub hinv_diag: Vec<f64>,
}

impl HessianCtx {
    pub fn new(h: Sq, lambda_frac: f64) -> Result<HessianCtx, String> {
        let u = gptq_factor(&h, lambda_frac)?;
        let n = h.n;
        let mut hinv_diag = vec![0.0; n];
        // (UᵀU)_jj = Σ_k U_kj²
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..=j {
                s += u.get(k, j) * u.get(k, j);
            }
            hinv_diag[j] = s;
        }
        Ok(HessianCtx { h, u, hinv_diag })
    }

    /// Identity Hessian: no calibration signal (uniform column importance).
    pub fn identity(d: usize) -> HessianCtx {
        let mut h = Sq::zeros(d);
        h.add_diag(1.0);
        HessianCtx::new(h, DEFAULT_LAMBDA).expect("identity hessian always factors")
    }
}

/// Exact storage accounting for one quantized matrix.
#[derive(Clone, Debug, Default)]
pub struct BitsBreakdown {
    pub sign_bits: f64,
    pub scale_bits: f64,
    pub index_bits: f64,  // split indices, permutations, bitmaps
    pub salient_bits: f64, // residual/int8 extras on salient weights
}

impl BitsBreakdown {
    pub fn total(&self) -> f64 {
        self.sign_bits + self.scale_bits + self.index_bits + self.salient_bits
    }

    pub fn per_weight(&self, n: usize, m: usize) -> f64 {
        self.total() / (n as f64 * m as f64)
    }
}

/// Result of quantizing one matrix.
pub struct QuantOut {
    /// Dequantized weights, paper orientation [out, in].
    pub w_hat: Matrix,
    pub bits: BitsBreakdown,
    /// Plain reconstruction error ‖W − Ŵ‖²_F / nm (against the ORIGINAL W).
    pub mse: f64,
}

/// A post-training quantization method.
pub trait Quantizer: Send + Sync {
    fn name(&self) -> String;

    /// Quantize `w` (paper orientation) given calibration context.
    fn quantize(&self, w: &Matrix, ctx: &HessianCtx) -> QuantOut;

    /// Storage model evaluated on an arbitrary shape (used to extrapolate
    /// W-bits to the paper's LLaMA dims for Table 1/4).
    fn storage_bits(&self, n: usize, m: usize) -> BitsBreakdown;

    fn avg_wbits(&self, n: usize, m: usize) -> f64 {
        self.storage_bits(n, m).per_weight(n, m)
    }
}

/// Construct a quantizer by name (CLI / harness registry).
pub fn by_name(name: &str) -> Option<Box<dyn Quantizer>> {
    let q: Box<dyn Quantizer> = match name {
        "rtn" => Box::new(rtn::Rtn::default()),
        "gptq-2bit" | "gptq2" => Box::new(gptq2::Gptq2::default()),
        "billm" => Box::new(billm::BiLlm::default()),
        "arb-x" | "arbllm-x" => Box::new(arbllm::ArbLlm::x()),
        "arb-rc" | "arbllm-rc" => Box::new(arbllm::ArbLlm::rc()),
        "pb-llm" | "pbllm" => Box::new(pbllm::PbLlm::default()),
        "framequant" | "framequant-1.0" => Box::new(framequant::FrameQuant::new(1.0)),
        "framequant-1.1" => Box::new(framequant::FrameQuant::new(1.1)),
        "hbllm-row" => Box::new(hbllm::Hbllm::row()),
        "hbllm-col" => Box::new(hbllm::Hbllm::col()),
        _ => return None,
    };
    Some(q)
}

/// All method names in the order the paper's tables list them.
pub fn table_methods() -> Vec<&'static str> {
    vec![
        "framequant-1.1",
        "pb-llm",
        "billm",
        "arb-x",
        "arb-rc",
        "hbllm-row",
        "hbllm-col",
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_all_table_methods() {
        for name in table_methods() {
            assert!(by_name(name).is_some(), "missing {name}");
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn identity_hessian_scores_uniform() {
        let ctx = HessianCtx::identity(16);
        let d0 = ctx.hinv_diag[0];
        for &d in &ctx.hinv_diag {
            assert!((d - d0).abs() < 1e-9);
        }
    }
}
