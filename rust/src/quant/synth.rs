//! Synthetic LLM-like layer generator (DESIGN.md §Substitutions):
//! heavy-tailed (student-t) weights with row-scale anisotropy and planted
//! outlier columns, plus calibration activations whose second moment spikes
//! on the same columns — reproducing the structure salient-column selection
//! exists for (cf. published OPT/LLaMA weight statistics).

use super::{HessianCtx, DEFAULT_LAMBDA};
use crate::tensor::linalg::Sq;
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;

pub struct SynthOpts {
    pub outlier_cols: usize,
    pub outlier_scale: f32,
    pub tail_nu: f64,
    pub calib_samples: usize,
}

impl Default for SynthOpts {
    fn default() -> Self {
        SynthOpts { outlier_cols: 0, outlier_scale: 6.0, tail_nu: 4.0, calib_samples: 0 }
    }
}

/// Generate (W [n×m, paper orientation], HessianCtx) for unit tests/benches.
pub fn llm_like_layer(n: usize, m: usize, seed: u64) -> (Matrix, HessianCtx) {
    let opts = SynthOpts {
        outlier_cols: (m / 32).max(1),
        calib_samples: (2 * m).max(64),
        ..Default::default()
    };
    llm_like_layer_with(n, m, seed, &opts)
}

pub fn llm_like_layer_with(n: usize, m: usize, seed: u64, opts: &SynthOpts) -> (Matrix, HessianCtx) {
    let mut rng = Pcg32::seeded(seed);
    // per-row scale anisotropy (log-normal-ish)
    let row_scale: Vec<f32> = (0..n).map(|_| (0.5 * rng.normal()).exp() as f32 * 0.05).collect();
    let mut w = Matrix::from_fn(n, m, |i, _| {
        row_scale[i] * rng.student_t(opts.tail_nu) as f32
    });
    // planted outlier columns
    let mut outliers: Vec<usize> = (0..m).collect();
    rng.shuffle(&mut outliers);
    outliers.truncate(opts.outlier_cols);
    for &j in &outliers {
        let amp = opts.outlier_scale * (1.0 + rng.f32());
        for i in 0..n {
            let v = w.get(i, j);
            w.set(i, j, v * amp);
        }
    }
    // calibration activations: correlated features + spikes on outlier cols
    let samples = opts.calib_samples.max(m / 2).max(16);
    let mut h = Sq::zeros(m);
    let mut x = vec![0f32; m];
    for _ in 0..samples {
        // AR(1)-correlated base signal
        let mut prev = 0f32;
        for j in 0..m {
            let z = rng.normal_f32();
            prev = 0.6 * prev + z;
            x[j] = prev;
        }
        for &j in &outliers {
            x[j] *= 3.0;
        }
        for a in 0..m {
            if x[a] == 0.0 {
                continue;
            }
            let xa = 2.0 * x[a] as f64; // H = 2 X Xᵀ
            for b in 0..m {
                h.data[a * m + b] += xa * x[b] as f64;
            }
        }
    }
    let ctx = HessianCtx::new(h, DEFAULT_LAMBDA).expect("synthetic hessian factors");
    (w, ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let (w1, ctx1) = llm_like_layer(8, 32, 42);
        let (w2, _) = llm_like_layer(8, 32, 42);
        assert_eq!(w1.data, w2.data);
        assert_eq!(ctx1.h.n, 32);
    }

    #[test]
    fn has_heavy_tails_and_outliers() {
        let (w, _) = llm_like_layer(64, 128, 1);
        let l2 = w.col_l2();
        let mut sorted = l2.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = sorted[64];
        let max = sorted[127];
        assert!(max > 3.0 * median, "no outlier columns: max {max} median {median}");
    }

    #[test]
    fn hessian_diag_positive() {
        let (_, ctx) = llm_like_layer(8, 48, 2);
        for j in 0..48 {
            assert!(ctx.h.get(j, j) > 0.0);
            assert!(ctx.hinv_diag[j] > 0.0);
        }
    }
}
