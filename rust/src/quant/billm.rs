//! BiLLM baseline (Huang et al., ICML 2024): Hessian-salient column
//! separation with residual binarization + bell-shaped magnitude split of
//! non-salient weights, on the blockwise OBQ substrate. CIQ = 8.

use super::binarize::{self, BinParams};
use super::gptq::obq_blockwise;
use super::grouping;
use super::salient::{self, Criterion};
use super::{storage, BitsBreakdown, HessianCtx, QuantOut, Quantizer, DEFAULT_BETA};
use crate::tensor::Matrix;

pub struct BiLlm {
    pub beta: usize,
    /// salient columns per block = beta / salient_div
    pub salient_div: usize,
    /// break-point candidates for the concentrated/sparse split
    pub n_candidates: usize,
}

impl Default for BiLlm {
    fn default() -> Self {
        BiLlm { beta: DEFAULT_BETA, salient_div: 16, n_candidates: 32 }
    }
}

impl BiLlm {
    fn block(&self, blk: &Matrix, off: usize, ctx: &HessianCtx) -> Matrix {
        // 1. salient columns by the BiLLM importance metric (ℓ2/Hinv² form)
        let scores: Vec<f64> = {
            let l2 = blk.col_l2();
            l2.iter()
                .enumerate()
                .map(|(j, n)| {
                    let d = ctx.hinv_diag[off + j].max(1e-30);
                    (n * n) / (d * d)
                })
                .collect()
        };
        let k = (blk.cols / self.salient_div).max(1).min(blk.cols / 2);
        let sal = salient::top_k(&scores, k);
        let is_sal = {
            let mut v = vec![false; blk.cols];
            for &j in &sal {
                v[j] = true;
            }
            v
        };
        let nonsal: Vec<usize> = (0..blk.cols).filter(|&j| !is_sal[j]).collect();

        let mut out = Matrix::zeros(blk.rows, blk.cols);

        // 2. salient: residual (two-stage) binarization, per row over the
        //    salient column set
        for i in 0..blk.rows {
            let vals: Vec<f32> = sal.iter().map(|&j| blk.get(i, j)).collect();
            if vals.is_empty() {
                continue;
            }
            let rp = binarize::fit_residual(&vals);
            for (s_idx, &j) in sal.iter().enumerate() {
                out.set(i, j, binarize::dequant_residual(vals[s_idx], rp));
            }
        }

        // 3. non-salient: concentrated/sparse split by magnitude rank
        //    (deployable shared-order encoding, cf. DESIGN.md), optimal
        //    break searched per row
        if !nonsal.is_empty() {
            let col_l2: Vec<f64> = nonsal
                .iter()
                .map(|&j| {
                    (0..blk.rows)
                        .map(|i| (blk.get(i, j) as f64).powi(2))
                        .sum::<f64>()
                        .sqrt()
                })
                .collect();
            let order = grouping::shared_order(&col_l2);
            let cand = grouping::candidates(nonsal.len(), self.n_candidates);
            for i in 0..blk.rows {
                let vals: Vec<f32> = nonsal.iter().map(|&j| blk.get(i, j)).collect();
                let fit = grouping::fit_row(&vals, &order, &cand, false);
                for (rank, &oi) in order.iter().enumerate() {
                    let p: BinParams = if rank < fit.t { fit.p1 } else { fit.p2 };
                    out.set(i, nonsal[oi], binarize::dequant(vals[oi], p));
                }
            }
        }
        out
    }
}

impl Quantizer for BiLlm {
    fn name(&self) -> String {
        "billm".into()
    }

    fn quantize(&self, w: &Matrix, ctx: &HessianCtx) -> QuantOut {
        let beta = self.beta.min(w.cols);
        let b = obq_blockwise(w, ctx, beta, |blk, off| self.block(blk, off, ctx));
        let mse = w.mse(&b);
        QuantOut { bits: self.storage_bits(w.rows, w.cols), w_hat: b, mse }
    }

    fn storage_bits(&self, n: usize, m: usize) -> BitsBreakdown {
        storage::billm_bits(n, m, self.beta)
    }
}

// salience criterion is fixed (BiLLM's own metric), silence unused import
#[allow(unused)]
fn _criterion_unused(_c: Criterion) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ciq::row_ciq_max;
    use crate::quant::rtn::Rtn;
    use crate::quant::synth;

    #[test]
    fn beats_rtn() {
        let (w, ctx) = synth::llm_like_layer(32, 64, 10);
        let b = BiLlm { beta: 32, ..Default::default() }.quantize(&w, &ctx);
        let r = Rtn.quantize(&w, &ctx);
        assert!(b.mse < r.mse, "billm {} !< rtn {}", b.mse, r.mse);
    }

    #[test]
    fn ciq_is_eight() {
        // §3.1: BiLLM CIQ = 8 (4 salient residual values + 2×2 group values)
        let (w, ctx) = synth::llm_like_layer(16, 64, 11);
        let b = BiLlm { beta: 64, ..Default::default() }.quantize(&w, &ctx);
        let c = row_ciq_max(&b.w_hat);
        assert!(c <= 8, "BiLLM CIQ must be ≤ 8 per block-row, got {c}");
    }

    #[test]
    fn wbits_matches_paper_ballpark() {
        let b = BiLlm::default().avg_wbits(4096, 4096);
        assert!(b > 1.0 && b < 1.3, "{b}");
    }
}
