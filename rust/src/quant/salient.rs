//! ℓ2-norm saliency-driven column selection (§3.4) + FillAvg (Fig. 2).
//!
//! Column scores combine the BiLLM parameter-importance metric
//! s_i = w_i² / [H⁻¹]_ii² aggregated per column: under the ℓ2 criterion a
//! column's score is ‖w_:j‖₂ / [H⁻¹]_jj (ℓ1: ‖w_:j‖₁ / [H⁻¹]_jj).

use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Criterion {
    L1,
    L2,
}

/// Column saliency scores for a block whose global column range starts at
/// `col_offset`. `hinv_diag` is indexed globally.
pub fn column_scores(
    block: &Matrix,
    hinv_diag: &[f64],
    col_offset: usize,
    criterion: Criterion,
) -> Vec<f64> {
    let norms = match criterion {
        Criterion::L2 => block.col_l2(),
        Criterion::L1 => block.col_l1(),
    };
    norms
        .into_iter()
        .enumerate()
        .map(|(j, n)| {
            let d = hinv_diag[col_offset + j].max(1e-30);
            n / d
        })
        .collect()
}

/// Indices of the top-k scored columns (within the block), in ascending
/// index order.
pub fn top_k(scores: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap().then(a.cmp(&b)));
    let mut out: Vec<usize> = idx.into_iter().take(k.min(scores.len())).collect();
    out.sort();
    out
}

/// FillAvg: replace each salient column with the average of its nearest
/// non-salient neighbours (left + right; one-sided at the edges). Keeps the
/// row-wise Haar transform of the non-salient part smooth (Fig. 2).
pub fn fill_avg(block: &Matrix, salient: &[usize]) -> Matrix {
    let mut filled = block.clone();
    if salient.is_empty() {
        return filled;
    }
    let is_sal = {
        let mut v = vec![false; block.cols];
        for &j in salient {
            v[j] = true;
        }
        v
    };
    if is_sal.iter().all(|&s| s) {
        // degenerate: everything salient — nothing to average from
        return filled;
    }
    for &j in salient {
        // nearest non-salient to the left / right
        let left = (0..j).rev().find(|&p| !is_sal[p]);
        let right = (j + 1..block.cols).find(|&p| !is_sal[p]);
        for i in 0..block.rows {
            let v = match (left, right) {
                (Some(l), Some(r)) => 0.5 * (block.get(i, l) + block.get(i, r)),
                (Some(l), None) => block.get(i, l),
                (None, Some(r)) => block.get(i, r),
                (None, None) => unreachable!("guarded above"),
            };
            filled.set(i, j, v);
        }
    }
    filled
}

/// Candidate salient-count options searched per block (the paper selects
/// "the subset with the lowest quantization error").
pub fn k_options(block_cols: usize) -> Vec<usize> {
    let mut ks: Vec<usize> = [0usize, 2, 4, 8, 16]
        .iter()
        .copied()
        .filter(|&k| k < block_cols / 2)
        .collect();
    // keep row pairing possible for the column-wise Haar of salient columns
    ks.dedup();
    ks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn block_with_outlier_cols(n: usize, m: usize, outliers: &[usize]) -> Matrix {
        let mut rng = Pcg32::seeded(9);
        let mut b = Matrix::from_fn(n, m, |_, _| rng.normal_f32() * 0.1);
        for &j in outliers {
            for i in 0..n {
                let v = b.get(i, j);
                b.set(i, j, v + 3.0 * if i % 2 == 0 { 1.0 } else { -1.0 });
            }
        }
        b
    }

    #[test]
    fn l2_finds_outlier_columns() {
        let b = block_with_outlier_cols(16, 32, &[5, 17]);
        let hd = vec![1.0f64; 32];
        let scores = column_scores(&b, &hd, 0, Criterion::L2);
        let top = top_k(&scores, 2);
        assert_eq!(top, vec![5, 17]);
    }

    #[test]
    fn hessian_diag_reweights() {
        let b = block_with_outlier_cols(16, 8, &[1, 6]);
        let mut hd = vec![1.0f64; 8];
        hd[1] = 1e6; // column 1's importance is crushed by a huge Hinv diag
        let scores = column_scores(&b, &hd, 0, Criterion::L2);
        let top = top_k(&scores, 1);
        assert_eq!(top, vec![6]);
    }

    #[test]
    fn l1_l2_differ_on_sparse_columns() {
        // a column with one huge element has high l2 but moderate l1
        let mut b = Matrix::zeros(16, 4);
        for i in 0..16 {
            b.set(i, 0, 1.0); // dense moderate column
        }
        b.set(0, 1, 4.0); // sparse spike
        let hd = vec![1.0f64; 4];
        let l1 = column_scores(&b, &hd, 0, Criterion::L1);
        let l2 = column_scores(&b, &hd, 0, Criterion::L2);
        assert!(l1[0] > l1[1], "l1 prefers dense: {l1:?}");
        assert!(l2[1] == 4.0 && l2[0] == 4.0, "l2 ties: {l2:?}");
    }

    #[test]
    fn top_k_sorted_and_bounded() {
        let scores = vec![0.5, 3.0, 1.0, 2.0];
        assert_eq!(top_k(&scores, 2), vec![1, 3]);
        assert_eq!(top_k(&scores, 10), vec![0, 1, 2, 3]);
    }

    #[test]
    fn fill_avg_interpolates() {
        let b = Matrix::from_vec(1, 5, vec![1.0, 99.0, 3.0, 99.0, 5.0]);
        let f = fill_avg(&b, &[1, 3]);
        assert_eq!(f.row(0), &[1.0, 2.0, 3.0, 4.0, 5.0]);
    }

    #[test]
    fn fill_avg_edges() {
        let b = Matrix::from_vec(1, 4, vec![99.0, 2.0, 4.0, 99.0]);
        let f = fill_avg(&b, &[0, 3]);
        assert_eq!(f.row(0), &[2.0, 2.0, 4.0, 4.0]);
    }

    #[test]
    fn fill_avg_no_salient_is_identity() {
        let b = Matrix::from_fn(3, 6, |i, j| (i + j) as f32);
        assert_eq!(fill_avg(&b, &[]), b);
    }

    #[test]
    fn fill_avg_skips_adjacent_salient() {
        let b = Matrix::from_vec(1, 5, vec![1.0, 99.0, 98.0, 97.0, 5.0]);
        let f = fill_avg(&b, &[1, 2, 3]);
        // all three salient columns interpolate between 1 and 5
        assert_eq!(f.row(0), &[1.0, 3.0, 3.0, 3.0, 5.0]);
    }

    #[test]
    fn k_options_reasonable() {
        let ks = k_options(128);
        assert!(ks.contains(&0) && ks.contains(&8));
        assert!(ks.iter().all(|&k| k < 64));
        assert_eq!(k_options(4), vec![0]);
    }
}
