//! PB-LLM baseline (Shang et al., ICLR 2024): partial binarization — the
//! top ~10% most salient weights (element-wise, Hessian-scaled magnitude)
//! stay in 8-bit, the rest are binarized per row. ~1.7 avg W-bits.

use super::binarize;
use super::gptq::obq_blockwise;
use super::{storage, BitsBreakdown, HessianCtx, QuantOut, Quantizer, DEFAULT_BETA};
use crate::tensor::Matrix;

pub struct PbLlm {
    pub beta: usize,
    pub salient_frac: f64,
}

impl Default for PbLlm {
    fn default() -> Self {
        PbLlm { beta: DEFAULT_BETA, salient_frac: 0.10 }
    }
}

impl PbLlm {
    fn block(&self, blk: &Matrix, off: usize, ctx: &HessianCtx) -> Matrix {
        let (n, m) = (blk.rows, blk.cols);
        let mut out = Matrix::zeros(n, m);
        for i in 0..n {
            let row = blk.row(i);
            // element scores: w² / Hinv_jj²
            let mut idx: Vec<usize> = (0..m).collect();
            idx.sort_by(|&a, &b| {
                let sa = (row[a] as f64).powi(2) / ctx.hinv_diag[off + a].powi(2);
                let sb = (row[b] as f64).powi(2) / ctx.hinv_diag[off + b].powi(2);
                sb.partial_cmp(&sa).unwrap()
            });
            let k = ((m as f64 * self.salient_frac).round() as usize).min(m);
            let (sal, rest) = idx.split_at(k);
            // salient: symmetric int8 with a per-row scale
            let max_abs = sal.iter().map(|&j| row[j].abs()).fold(0.0f32, f32::max);
            let scale = if max_abs > 0.0 { max_abs / 127.0 } else { 1.0 };
            for &j in sal {
                let q = (row[j] / scale).round().clamp(-127.0, 127.0);
                out.set(i, j, q * scale);
            }
            // rest: 1-bit binarization
            let vals: Vec<f32> = rest.iter().map(|&j| row[j]).collect();
            let p = binarize::fit(vals.iter().copied());
            for &j in rest {
                out.set(i, j, binarize::dequant(row[j], p));
            }
        }
        out
    }
}

impl Quantizer for PbLlm {
    fn name(&self) -> String {
        "pb-llm".into()
    }

    fn quantize(&self, w: &Matrix, ctx: &HessianCtx) -> QuantOut {
        let beta = self.beta.min(w.cols);
        let b = obq_blockwise(w, ctx, beta, |blk, off| self.block(blk, off, ctx));
        let mse = w.mse(&b);
        QuantOut { bits: self.storage_bits(w.rows, w.cols), w_hat: b, mse }
    }

    fn storage_bits(&self, n: usize, m: usize) -> BitsBreakdown {
        storage::pbllm_bits(n, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rtn::Rtn;
    use crate::quant::synth;

    #[test]
    fn beats_rtn_with_more_bits() {
        let (w, ctx) = synth::llm_like_layer(32, 64, 30);
        let p = PbLlm { beta: 32, ..Default::default() }.quantize(&w, &ctx);
        let r = Rtn.quantize(&w, &ctx);
        assert!(p.mse < r.mse, "pb {} !< rtn {}", p.mse, r.mse);
    }

    #[test]
    fn salient_elements_nearly_exact() {
        let (w, ctx) = synth::llm_like_layer(16, 64, 31);
        let out = PbLlm { beta: 64, ..Default::default() }.quantize(&w, &ctx);
        // the largest |w| element per row should be reconstructed closely
        // (identity-ish hessian spikes aside, int8 error ≤ scale/2)
        let mut close = 0;
        for i in 0..16 {
            let row = w.row(i);
            let jmax = (0..64)
                .max_by(|&a, &b| row[a].abs().partial_cmp(&row[b].abs()).unwrap())
                .unwrap();
            let rel = (w.get(i, jmax) - out.w_hat.get(i, jmax)).abs() / w.get(i, jmax).abs().max(1e-6);
            if rel < 0.05 {
                close += 1;
            }
        }
        assert!(close >= 12, "only {close}/16 max elements preserved");
    }

    #[test]
    fn wbits_about_1_7() {
        let b = PbLlm::default().avg_wbits(4096, 4096);
        assert!((b - 1.7).abs() < 0.1, "{b}");
    }
}
