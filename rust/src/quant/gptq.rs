//! Blockwise OBQ (GPTQ-style) error propagation — the substrate every
//! binarization method here rides on (Algorithm 1, lines 4–12).
//!
//! For each β-column block: the caller's `quant_block` produces the
//! binarized block B; the quantization error is propagated into the not-yet-
//! quantized columns through the Cholesky factor of the damped inverse
//! Hessian:
//!     E = (W_blk − B_blk) · U_bb^{-1}
//!     W[:, future] −= E · U_{blk, future}

use super::HessianCtx;
use crate::tensor::linalg::{solve_right_upper, Sq};
use crate::tensor::Matrix;

/// Extract the square sub-block U[b0..b1, b0..b1].
fn u_block(u: &Sq, b0: usize, b1: usize) -> Sq {
    let k = b1 - b0;
    let mut out = Sq::zeros(k);
    for i in 0..k {
        for j in 0..k {
            out.set(i, j, u.get(b0 + i, b0 + j));
        }
    }
    out
}

/// Run blockwise OBQ. `quant_block(block, col_offset)` receives the
/// *error-compensated* current block and must return its binarized (already
/// dequantized) replacement of the same shape.
pub fn obq_blockwise(
    w: &Matrix,
    ctx: &HessianCtx,
    beta: usize,
    mut quant_block: impl FnMut(&Matrix, usize) -> Matrix,
) -> Matrix {
    let (n, m) = (w.rows, w.cols);
    assert_eq!(ctx.u.n, m, "hessian dim must match paper-orientation cols");
    let mut work = w.clone();
    let mut out = Matrix::zeros(n, m);

    let mut b0 = 0;
    while b0 < m {
        let b1 = (b0 + beta).min(m);
        let wb = work.slice_cols(b0, b1);
        let bb = quant_block(&wb, b0);
        assert_eq!((bb.rows, bb.cols), (wb.rows, wb.cols), "quant_block shape");
        out.set_cols(b0, &bb);

        if b1 < m {
            // E = (W - B) · U_bb^{-1}
            let resid = wb.sub(&bb);
            let ubb = u_block(&ctx.u, b0, b1);
            let e = solve_right_upper(&ubb, &resid);
            // W[:, b1..] -= E · U[b0..b1, b1..]
            let k = b1 - b0;
            let fut = m - b1;
            // accumulate in f64 rows for stability
            for i in 0..n {
                let e_row = e.row(i);
                let w_row = &mut work.data[i * m + b1..(i + 1) * m];
                for p in 0..k {
                    let ev = e_row[p] as f64;
                    if ev == 0.0 {
                        continue;
                    }
                    for j in 0..fut {
                        w_row[j] -= (ev * ctx.u.get(b0 + p, b1 + j)) as f32;
                    }
                }
            }
        }
        b0 = b1;
    }
    out
}

/// Hessian-weighted proxy loss tr((W−Ŵ) H (W−Ŵ)ᵀ) / nm — the objective OBQ
/// minimizes; used by tests to verify propagation helps.
pub fn hessian_loss(w: &Matrix, w_hat: &Matrix, ctx: &HessianCtx) -> f64 {
    let d = w.sub(w_hat);
    let m = d.cols;
    let mut total = 0.0f64;
    for i in 0..d.rows {
        let row = d.row(i);
        // row · H · rowᵀ
        for a in 0..m {
            let ra = row[a] as f64;
            if ra == 0.0 {
                continue;
            }
            let hrow = &ctx.h.data[a * m..(a + 1) * m];
            let mut s = 0.0f64;
            for b in 0..m {
                s += hrow[b] * row[b] as f64;
            }
            total += ra * s;
        }
    }
    total / (d.rows as f64 * m as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::synth;
    use crate::quant::HessianCtx;
    use crate::util::rng::Pcg32;

    fn simple_binarize_block(blk: &Matrix, _off: usize) -> Matrix {
        // per-row α·sign(w−μ)+μ
        let mut out = Matrix::zeros(blk.rows, blk.cols);
        for i in 0..blk.rows {
            let row = blk.row(i);
            let mu = row.iter().sum::<f32>() / row.len() as f32;
            let alpha = row.iter().map(|v| (v - mu).abs()).sum::<f32>() / row.len() as f32;
            for (j, &v) in row.iter().enumerate() {
                out.set(i, j, if v >= mu { mu + alpha } else { mu - alpha });
            }
        }
        out
    }

    #[test]
    fn covers_all_columns() {
        let mut rng = Pcg32::seeded(1);
        let w = Matrix::from_fn(8, 70, |_, _| rng.normal_f32());
        let ctx = HessianCtx::identity(70);
        let b = obq_blockwise(&w, &ctx, 32, simple_binarize_block);
        // every column binarized: exactly 2 distinct |v - mu| magnitudes per row
        assert_eq!(b.rows, 8);
        assert_eq!(b.cols, 70);
        assert!(b.data.iter().all(|v| v.is_finite()));
        assert!(b.frob_norm() > 0.0);
    }

    #[test]
    fn identity_hessian_equals_blockwise_independent() {
        // With H = I the propagation term is zero only if U is diagonal —
        // which it is for identity H. So OBQ == independent blocks.
        let mut rng = Pcg32::seeded(2);
        let w = Matrix::from_fn(6, 64, |_, _| rng.normal_f32());
        let ctx = HessianCtx::identity(64);
        let via_obq = obq_blockwise(&w, &ctx, 16, simple_binarize_block);
        let mut direct = Matrix::zeros(6, 64);
        for b0 in (0..64).step_by(16) {
            let blk = w.slice_cols(b0, b0 + 16);
            direct.set_cols(b0, &simple_binarize_block(&blk, b0));
        }
        assert!(via_obq.mse(&direct) < 1e-10);
    }

    #[test]
    fn propagation_reduces_hessian_loss() {
        // On a correlated Hessian, OBQ must beat independent blockwise
        // quantization on the hessian-weighted objective.
        let (w, ctx) = synth::llm_like_layer(32, 96, 7);
        let with_prop = obq_blockwise(&w, &ctx, 24, simple_binarize_block);
        let mut without = Matrix::zeros(w.rows, w.cols);
        for b0 in (0..96).step_by(24) {
            let blk = w.slice_cols(b0, b0 + 24);
            without.set_cols(b0, &simple_binarize_block(&blk, b0));
        }
        let l_with = hessian_loss(&w, &with_prop, &ctx);
        let l_without = hessian_loss(&w, &without, &ctx);
        assert!(
            l_with < l_without * 1.001,
            "OBQ did not help: {l_with} vs {l_without}"
        );
    }
}
