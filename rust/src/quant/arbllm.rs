//! ARB-LLM baseline (Li et al., 2024): alternating refined binarization.
//!
//! * `X`  — per-row (α, μ) refined by coordinate-descent alternation with
//!   sign recomputation, plus column grouping (CGB) and residual salient
//!   columns, on the OBQ substrate.
//! * `RC` — row AND column scaling: w_ij ≈ μ_i + α_i·c_j·s_ij, fit by
//!   alternating least squares. CIQ grows to O(block) — the paper's
//!   "up to 128 at block size 128".

use super::binarize;
use super::gptq::obq_blockwise;
use super::salient;
use super::{storage, BitsBreakdown, HessianCtx, QuantOut, Quantizer, DEFAULT_BETA};
use crate::tensor::Matrix;

#[derive(Clone, Copy, PartialEq, Eq)]
pub enum ArbVariant {
    X,
    Rc,
}

pub struct ArbLlm {
    pub variant: ArbVariant,
    pub beta: usize,
    pub iters: usize,
    pub salient_div: usize,
}

impl ArbLlm {
    pub fn x() -> ArbLlm {
        ArbLlm { variant: ArbVariant::X, beta: DEFAULT_BETA, iters: 4, salient_div: 16 }
    }

    pub fn rc() -> ArbLlm {
        ArbLlm { variant: ArbVariant::Rc, beta: DEFAULT_BETA, iters: 6, salient_div: 16 }
    }

    /// X variant block: salient residual + per-row ARB-refined binarization
    /// over column sub-groups of 16 (column-group bitmap granularity).
    fn block_x(&self, blk: &Matrix, off: usize, ctx: &HessianCtx) -> Matrix {
        let scores: Vec<f64> = blk
            .col_l2()
            .iter()
            .enumerate()
            .map(|(j, n)| (n * n) / ctx.hinv_diag[off + j].max(1e-30).powi(2))
            .collect();
        let k = (blk.cols / self.salient_div).max(1).min(blk.cols / 2);
        let sal = salient::top_k(&scores, k);
        let is_sal = {
            let mut v = vec![false; blk.cols];
            for &j in &sal {
                v[j] = true;
            }
            v
        };
        let mut out = Matrix::zeros(blk.rows, blk.cols);
        // salient: residual binarization (as BiLLM) but ARB-refined stage 1
        for i in 0..blk.rows {
            let vals: Vec<f32> = sal.iter().map(|&j| blk.get(i, j)).collect();
            if vals.is_empty() {
                continue;
            }
            let p1 = binarize::fit_arb(&vals, self.iters);
            let resid: Vec<f32> = vals.iter().map(|&v| v - binarize::dequant(v, p1)).collect();
            let a2 = if resid.is_empty() {
                0.0
            } else {
                resid.iter().map(|r| r.abs()).sum::<f32>() / resid.len() as f32
            };
            for (si, &j) in sal.iter().enumerate() {
                let s1 = binarize::dequant(vals[si], p1);
                let r = vals[si] - s1;
                out.set(i, j, s1 + if r >= 0.0 { a2 } else { -a2 });
            }
        }
        // non-salient: CGB column grouping — two column groups per block by
        // column ℓ2 rank (the per-block group bitmap), ARB-refined (α, μ)
        // per (row, group)
        let nonsal: Vec<usize> = (0..blk.cols).filter(|&j| !is_sal[j]).collect();
        if !nonsal.is_empty() {
            let col_l2: Vec<f64> = nonsal
                .iter()
                .map(|&j| {
                    (0..blk.rows)
                        .map(|i| (blk.get(i, j) as f64).powi(2))
                        .sum::<f64>()
                        .sqrt()
                })
                .collect();
            let mut rank: Vec<usize> = (0..nonsal.len()).collect();
            rank.sort_by(|&a, &b| col_l2[b].partial_cmp(&col_l2[a]).unwrap());
            let t = (nonsal.len() / 4).max(1); // dense/sparse column split
            let (g1, g2) = rank.split_at(t);
            for i in 0..blk.rows {
                for g in [g1, g2] {
                    let vals: Vec<f32> = g.iter().map(|&oi| blk.get(i, nonsal[oi])).collect();
                    let p = binarize::fit_arb(&vals, self.iters);
                    for (vi, &oi) in g.iter().enumerate() {
                        out.set(i, nonsal[oi], binarize::dequant(vals[vi], p));
                    }
                }
            }
        }
        out
    }

    /// RC variant block: salient residual columns + alternating row/column
    /// scaling fit on the rest (row×column scales are RC's signature).
    fn block_rc(&self, blk: &Matrix, off: usize, ctx: &HessianCtx) -> Matrix {
        // salient columns as in X
        let scores: Vec<f64> = blk
            .col_l2()
            .iter()
            .enumerate()
            .map(|(j, n)| (n * n) / ctx.hinv_diag[off + j].max(1e-30).powi(2))
            .collect();
        let k = (blk.cols / self.salient_div).max(1).min(blk.cols / 2);
        let sal = salient::top_k(&scores, k);
        let mut out = self.block_rc_core(blk);
        // residual binarization per salient column
        for &j in &sal {
            let resid: Vec<f32> = (0..blk.rows).map(|i| blk.get(i, j) - out.get(i, j)).collect();
            let p = binarize::fit(resid.iter().copied());
            for i in 0..blk.rows {
                let v = out.get(i, j) + binarize::dequant(resid[i], p);
                out.set(i, j, v);
            }
        }
        out
    }

    fn block_rc_core(&self, blk: &Matrix) -> Matrix {
        let (n, m) = (blk.rows, blk.cols);
        // μ_i = row mean; r_ij = w_ij − μ_i
        let mu: Vec<f32> = (0..n)
            .map(|i| blk.row(i).iter().sum::<f32>() / m as f32)
            .collect();
        let mut alpha: Vec<f64> = (0..n)
            .map(|i| {
                blk.row(i).iter().map(|&v| ((v - mu[i]).abs()) as f64).sum::<f64>() / m as f64
            })
            .collect();
        let mut cscale: Vec<f64> = vec![1.0; m];
        // signs track sign(r)
        let sign = |i: usize, j: usize| -> f64 {
            if blk.get(i, j) - mu[i] >= 0.0 {
                1.0
            } else {
                -1.0
            }
        };
        for _ in 0..self.iters {
            // c_j = Σ_i r_ij s_ij α_i / Σ_i α_i²
            let denom_a: f64 = alpha.iter().map(|a| a * a).sum::<f64>() * 1.0;
            if denom_a > 0.0 {
                for j in 0..m {
                    let mut num = 0.0;
                    for i in 0..n {
                        num += (blk.get(i, j) - mu[i]) as f64 * sign(i, j) * alpha[i];
                    }
                    cscale[j] = (num / denom_a).max(0.0);
                }
            }
            // α_i = Σ_j r_ij s_ij c_j / Σ_j c_j²
            let denom_c: f64 = cscale.iter().map(|c| c * c).sum();
            if denom_c > 0.0 {
                for i in 0..n {
                    let mut num = 0.0;
                    for j in 0..m {
                        num += (blk.get(i, j) - mu[i]) as f64 * sign(i, j) * cscale[j];
                    }
                    alpha[i] = (num / denom_c).max(0.0);
                }
            }
        }
        Matrix::from_fn(n, m, |i, j| {
            mu[i] + (alpha[i] * cscale[j]) as f32 * sign(i, j) as f32
        })
    }
}

impl Quantizer for ArbLlm {
    fn name(&self) -> String {
        match self.variant {
            ArbVariant::X => "arb-x".into(),
            ArbVariant::Rc => "arb-rc".into(),
        }
    }

    fn quantize(&self, w: &Matrix, ctx: &HessianCtx) -> QuantOut {
        let beta = self.beta.min(w.cols);
        let b = obq_blockwise(w, ctx, beta, |blk, off| match self.variant {
            ArbVariant::X => self.block_x(blk, off, ctx),
            ArbVariant::Rc => self.block_rc(blk, off, ctx),
        });
        let mse = w.mse(&b);
        QuantOut { bits: self.storage_bits(w.rows, w.cols), w_hat: b, mse }
    }

    fn storage_bits(&self, n: usize, m: usize) -> BitsBreakdown {
        match self.variant {
            ArbVariant::X => storage::arb_x_bits(n, m, self.beta),
            ArbVariant::Rc => storage::arb_rc_bits(n, m, self.beta),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::ciq::row_ciq_max;
    use crate::quant::rtn::Rtn;
    use crate::quant::synth;

    #[test]
    fn x_beats_rtn() {
        let (w, ctx) = synth::llm_like_layer(32, 64, 20);
        let mut q = ArbLlm::x();
        q.beta = 32;
        let a = q.quantize(&w, &ctx);
        let r = Rtn.quantize(&w, &ctx);
        assert!(a.mse < r.mse, "arb-x {} !< rtn {}", a.mse, r.mse);
    }

    #[test]
    fn rc_has_high_ciq() {
        // RC's per-column scale expands the inverse-quantization set toward
        // the block size (§3.1: "up to 128 when block = 128")
        let (w, ctx) = synth::llm_like_layer(16, 64, 21);
        let mut q = ArbLlm::rc();
        q.beta = 64;
        let out = q.quantize(&w, &ctx);
        let ciq = row_ciq_max(&out.w_hat);
        assert!(ciq > 16, "RC CIQ should be large, got {ciq}");
    }

    #[test]
    fn rc_finite_and_better_than_plain_sign() {
        let (w, ctx) = synth::llm_like_layer(24, 48, 22);
        let mut q = ArbLlm::rc();
        q.beta = 48;
        let out = q.quantize(&w, &ctx);
        assert!(out.w_hat.data.iter().all(|v| v.is_finite()));
        let r = Rtn.quantize(&w, &ctx);
        assert!(out.mse < r.mse * 1.05, "rc {} vs rtn {}", out.mse, r.mse);
    }
}
