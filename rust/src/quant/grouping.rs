//! Frequency-aware multi-parameter intra-row grouping (§3.4) and the
//! intra-band shared-mean strategy (§3.5).
//!
//! Deployable encoding (DESIGN.md §Group-membership): for each block+band,
//! one *shared* column order ranks columns by band column-ℓ2; every row then
//! stores only a split index `t` chosen among `n_candidates` percentile
//! positions — group 1 = the t highest-magnitude-ranked columns, group 2 =
//! the rest. Membership is exactly decodable from (order, t).

use super::binarize::{self, BinParams};

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Granularity {
    /// one split index per row (paper default)
    RowWise,
    /// one split index shared by all rows (Table 2b baseline)
    Global,
}

#[derive(Clone, Copy, Debug)]
pub struct GroupOpts {
    pub n_candidates: usize,
    pub shared_mean: bool,
    pub granularity: Granularity,
}

impl Default for GroupOpts {
    fn default() -> Self {
        GroupOpts { n_candidates: 40, shared_mean: true, granularity: Granularity::RowWise }
    }
}

/// Rank column indices of a band by descending column ℓ2 norm.
/// `band_cols(j)` yields the values of column j across rows.
pub fn shared_order(col_l2: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..col_l2.len()).collect();
    idx.sort_by(|&a, &b| col_l2[b].partial_cmp(&col_l2[a]).unwrap().then(a.cmp(&b)));
    idx
}

/// Split-candidate positions: `n_candidates` points spread over (0, m)
/// percentile-style, always including the no-split candidate t = m.
pub fn candidates(m: usize, n_candidates: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(n_candidates + 1);
    for c in 1..=n_candidates {
        let t = (c * m) / (n_candidates + 1);
        if t >= 1 && t < m && out.last() != Some(&t) {
            out.push(t);
        }
    }
    out.push(m); // single-group fallback
    out
}

/// Result of quantizing one row's band.
#[derive(Clone, Debug)]
pub struct RowGroupFit {
    pub t: usize, // split position in the shared order
    pub p1: BinParams,
    pub p2: BinParams,
    pub err: f64,
}

/// Search the best split for one row's band values (`vals[j]` is the value
/// at band-column j, `order` the shared magnitude order).
pub fn fit_row(
    vals: &[f32],
    order: &[usize],
    cand: &[usize],
    shared_mean: bool,
) -> RowGroupFit {
    debug_assert_eq!(vals.len(), order.len());
    let mut best: Option<RowGroupFit> = None;
    for &t in cand {
        let g1 = order[..t].iter().map(|&j| vals[j]);
        let g2 = order[t..].iter().map(|&j| vals[j]);
        let (p1, p2, err) = if shared_mean {
            // one μ over both groups (§3.5), per-group α
            let all_mu = binarize::fit(vals.iter().copied()).mu;
            let fit_alpha = |idxs: &[usize]| -> f32 {
                if idxs.is_empty() {
                    return 0.0;
                }
                let dev: f64 = idxs.iter().map(|&j| (vals[j] - all_mu).abs() as f64).sum();
                (dev / idxs.len() as f64) as f32
            };
            let p1 = BinParams { alpha: fit_alpha(&order[..t]), mu: all_mu };
            let p2 = BinParams { alpha: fit_alpha(&order[t..]), mu: all_mu };
            let err = binarize::error(g1.clone(), p1) + binarize::error(g2.clone(), p2);
            (p1, p2, err)
        } else {
            let (p1, e1) = binarize::fit_and_error(g1);
            let (p2, e2) = binarize::fit_and_error(g2);
            (p1, p2, e1 + e2)
        };
        if best.as_ref().map_or(true, |b| err < b.err) {
            best = Some(RowGroupFit { t, p1, p2, err });
        }
    }
    best.expect("candidates non-empty")
}

/// Dequantize a row's band in place given its fit.
pub fn dequant_row(vals: &mut [f32], order: &[usize], fit: &RowGroupFit) {
    for (rank, &j) in order.iter().enumerate() {
        let p = if rank < fit.t { fit.p1 } else { fit.p2 };
        vals[j] = binarize::dequant(vals[j], p);
    }
}

/// Quantize a whole band of a block: rows × band-columns, with either
/// row-wise or global split granularity. Returns per-row fits; `band[i]`
/// is mutated to the reconstruction.
pub fn quantize_band(
    rows: &mut [Vec<f32>],
    col_l2: &[f64],
    opts: &GroupOpts,
) -> Vec<RowGroupFit> {
    let m = col_l2.len();
    let order = shared_order(col_l2);
    let cand = candidates(m, opts.n_candidates);
    match opts.granularity {
        Granularity::RowWise => {
            let mut fits = Vec::with_capacity(rows.len());
            for row in rows.iter_mut() {
                let f = fit_row(row, &order, &cand, opts.shared_mean);
                dequant_row(row, &order, &f);
                fits.push(f);
            }
            fits
        }
        Granularity::Global => {
            // pick the single t minimizing total error across rows
            let mut best_t = m;
            let mut best_err = f64::INFINITY;
            for &t in &cand {
                let mut total = 0.0;
                for row in rows.iter() {
                    let f = fit_row(row, &order, &[t], opts.shared_mean);
                    total += f.err;
                }
                if total < best_err {
                    best_err = total;
                    best_t = t;
                }
            }
            let mut fits = Vec::with_capacity(rows.len());
            for row in rows.iter_mut() {
                let f = fit_row(row, &order, &[best_t], opts.shared_mean);
                dequant_row(row, &order, &f);
                fits.push(f);
            }
            fits
        }
    }
}

/// Oracle (non-deployable) grouping: per-row magnitude threshold with a
/// per-element bitmap. Used only by the group-encoding ablation to measure
/// the fidelity cost of the deployable encoding.
pub fn fit_row_oracle(vals: &[f32], cand_fracs: usize, shared_mean: bool) -> (Vec<f32>, f64) {
    let m = vals.len();
    let mut mags: Vec<usize> = (0..m).collect();
    mags.sort_by(|&a, &b| vals[b].abs().partial_cmp(&vals[a].abs()).unwrap());
    let cand = candidates(m, cand_fracs);
    let f = fit_row(vals, &mags, &cand, shared_mean);
    let mut out = vals.to_vec();
    dequant_row(&mut out, &mags, &f);
    (out, f.err)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    fn band_err(vals: &[f32], recon: &[f32]) -> f64 {
        vals.iter().zip(recon).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
    }

    #[test]
    fn candidates_cover_range() {
        let c = candidates(128, 40);
        assert!(c.len() >= 30);
        assert!(c.iter().all(|&t| t >= 1 && t <= 128));
        assert_eq!(*c.last().unwrap(), 128);
        let c1 = candidates(4, 40);
        assert!(c1.windows(2).all(|w| w[0] < w[1]), "{c1:?}");
    }

    #[test]
    fn two_groups_never_worse_than_one() {
        check(
            "grouping-beats-single",
            40,
            |g: &mut Gen| {
                let m = 2 * g.size(2, 40);
                // mixture: half small, half large magnitude
                let mut v = g.vec_f32(m, 0.3);
                for x in v.iter_mut().take(m / 3) {
                    *x *= 8.0;
                }
                v
            },
            |vals| {
                let l2: Vec<f64> = vals.iter().map(|v| v.abs() as f64).collect();
                let order = shared_order(&l2);
                let cand = candidates(vals.len(), 40);
                let split = fit_row(vals, &order, &cand, false);
                let single = fit_row(vals, &order, &[vals.len()], false);
                if split.err <= single.err + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("{} > {}", split.err, single.err))
                }
            },
        );
    }

    #[test]
    fn dequant_reduces_to_four_values_per_band() {
        let vals: Vec<f32> = (0..32).map(|i| (i as f32 - 16.0) * 0.13).collect();
        let l2: Vec<f64> = vals.iter().map(|v| v.abs() as f64).collect();
        let order = shared_order(&l2);
        let cand = candidates(32, 10);
        let f = fit_row(&vals, &order, &cand, false);
        let mut recon = vals.clone();
        dequant_row(&mut recon, &order, &f);
        let mut distinct: Vec<i64> = recon.iter().map(|&v| (v * 1e5) as i64).collect();
        distinct.sort();
        distinct.dedup();
        assert!(distinct.len() <= 4, "CIQ per band must be ≤ 4, got {}", distinct.len());
    }

    #[test]
    fn shared_mean_costs_little() {
        check(
            "shared-mean-close",
            25,
            |g: &mut Gen| { let n = 2 * g.size(8, 40); g.vec_f32(n, 1.0) },
            |vals| {
                let l2: Vec<f64> = vals.iter().map(|v| v.abs() as f64).collect();
                let order = shared_order(&l2);
                let cand = candidates(vals.len(), 20);
                let sep = fit_row(vals, &order, &cand, false);
                let sha = fit_row(vals, &order, &cand, true);
                // shared mean may lose a bit but not catastrophically
                if sha.err <= sep.err * 3.0 + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("shared mean err {} vs {}", sha.err, sep.err))
                }
            },
        );
    }

    #[test]
    fn rowwise_beats_global() {
        // heterogeneous rows: row-wise split must win (Table 2b)
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for i in 0..8 {
            let scale = 1.0 + i as f32;
            rows.push((0..32).map(|j| ((i * 37 + j * 11) % 17) as f32 * 0.1 * scale - 0.8).collect());
        }
        let orig = rows.clone();
        let l2: Vec<f64> = (0..32)
            .map(|j| orig.iter().map(|r| (r[j] as f64).powi(2)).sum::<f64>().sqrt())
            .collect();
        let mut rows_g = orig.clone();
        let e_row: f64 = {
            let opts = GroupOpts { granularity: Granularity::RowWise, ..Default::default() };
            quantize_band(&mut rows, &l2, &opts);
            orig.iter().zip(&rows).map(|(a, b)| band_err(a, b)).sum()
        };
        let e_glob: f64 = {
            let opts = GroupOpts { granularity: Granularity::Global, ..Default::default() };
            quantize_band(&mut rows_g, &l2, &opts);
            orig.iter().zip(&rows_g).map(|(a, b)| band_err(a, b)).sum()
        };
        assert!(e_row <= e_glob + 1e-9, "row {e_row} vs global {e_glob}");
    }

    #[test]
    fn oracle_at_least_as_good_as_deployable() {
        check(
            "oracle-vs-deployable",
            20,
            |g: &mut Gen| {
                let m = 2 * g.size(8, 40);
                g.vec_f32(m, 1.0)
            },
            |vals| {
                let (_, oracle_err) = fit_row_oracle(vals, 40, false);
                let l2: Vec<f64> = vals.iter().map(|v| v.abs() as f64).collect();
                let order = shared_order(&l2);
                let cand = candidates(vals.len(), 40);
                let dep = fit_row(vals, &order, &cand, false);
                // single row: the shared order IS the magnitude order, so equal
                if (oracle_err - dep.err).abs() < 1e-6 {
                    Ok(())
                } else {
                    Err(format!("oracle {oracle_err} vs dep {}", dep.err))
                }
            },
        );
    }
}
