//! Binarization primitives: α·sign(w − μ) + μ fits, residual binarization,
//! and the alternating refinement used by ARB-LLM.

/// Parameters of a 1-bit group: value ∈ {μ − α, μ + α}.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BinParams {
    pub alpha: f32,
    pub mu: f32,
}

/// L2-optimal fit for sign binarization of `vals`:
/// μ = mean, α = mean |v − μ| (minimizes Σ (v − μ − α·sign(v−μ))²).
pub fn fit(vals: impl Iterator<Item = f32> + Clone) -> BinParams {
    let mut n = 0usize;
    let mut sum = 0.0f64;
    for v in vals.clone() {
        sum += v as f64;
        n += 1;
    }
    if n == 0 {
        return BinParams::default();
    }
    let mu = (sum / n as f64) as f32;
    let mut dev = 0.0f64;
    for v in vals {
        dev += (v - mu).abs() as f64;
    }
    BinParams { alpha: (dev / n as f64) as f32, mu }
}

/// Reconstruction of one value under `p`.
#[inline]
pub fn dequant(v: f32, p: BinParams) -> f32 {
    if v >= p.mu {
        p.mu + p.alpha
    } else {
        p.mu - p.alpha
    }
}

/// Squared reconstruction error of a group under `p`.
pub fn error(vals: impl Iterator<Item = f32>, p: BinParams) -> f64 {
    vals.map(|v| {
        let d = (v - dequant(v, p)) as f64;
        d * d
    })
    .sum()
}

/// Fit + error in one pass pair (the candidate-search inner loop).
pub fn fit_and_error(vals: impl Iterator<Item = f32> + Clone) -> (BinParams, f64) {
    let p = fit(vals.clone());
    (p, error(vals, p))
}

/// Residual (two-stage) binarization used for salient weights (BiLLM-style):
/// w ≈ μ + α₁·s₁ + α₂·s₂ where s₂ binarizes the residual. Returns the
/// reconstruction of each value.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResidualParams {
    pub mu: f32,
    pub alpha1: f32,
    pub alpha2: f32,
}

pub fn fit_residual(vals: &[f32]) -> ResidualParams {
    let p1 = fit(vals.iter().copied());
    // residual r = v - dequant1(v); second stage is zero-mean by symmetry,
    // fit α₂ = mean |r|
    let mut dev = 0.0f64;
    for &v in vals {
        dev += (v - dequant(v, p1)).abs() as f64;
    }
    let alpha2 = if vals.is_empty() { 0.0 } else { (dev / vals.len() as f64) as f32 };
    ResidualParams { mu: p1.mu, alpha1: p1.alpha, alpha2 }
}

pub fn dequant_residual(v: f32, p: ResidualParams) -> f32 {
    let stage1 = if v >= p.mu { p.mu + p.alpha1 } else { p.mu - p.alpha1 };
    let r = v - stage1;
    stage1 + if r >= 0.0 { p.alpha2 } else { -p.alpha2 }
}

/// ARB-style alternating refinement: re-estimate (α, μ) against the *current
/// signs*, then recompute signs, for `iters` rounds. Returns the refined
/// params (signs are implied by v ≥ μ after convergence).
pub fn fit_arb(vals: &[f32], iters: usize) -> BinParams {
    let mut p = fit(vals.iter().copied());
    for _ in 0..iters {
        // signs under current μ
        // closed-form refit: μ' = mean(v − α·s), α' = mean(s·(v − μ'))
        let n = vals.len() as f64;
        if n == 0.0 {
            return p;
        }
        let mut sum_vs = 0.0f64; // Σ v·s
        let mut sum_s = 0.0f64;
        let mut sum_v = 0.0f64;
        for &v in vals {
            let s = if v >= p.mu { 1.0f64 } else { -1.0 };
            sum_vs += v as f64 * s;
            sum_s += s;
            sum_v += v as f64;
        }
        // jointly optimal (α, μ) for fixed signs:
        //   μ = (Σv − α Σs)/n,  α = (Σ v s − μ Σ s)/n
        // solve the 2x2 system
        let det = n * n - sum_s * sum_s;
        if det.abs() < 1e-12 {
            break;
        }
        let alpha = (n * sum_vs - sum_s * sum_v) / det;
        let mu = (sum_v - alpha * sum_s) / n;
        let new_p = BinParams { alpha: alpha.max(0.0) as f32, mu: mu as f32 };
        if (new_p.alpha - p.alpha).abs() < 1e-7 && (new_p.mu - p.mu).abs() < 1e-7 {
            p = new_p;
            break;
        }
        p = new_p;
    }
    p
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, Gen};

    #[test]
    fn fit_known() {
        let p = fit([1.0f32, 3.0].into_iter());
        assert_eq!(p.mu, 2.0);
        assert_eq!(p.alpha, 1.0);
        assert_eq!(dequant(3.0, p), 3.0);
        assert_eq!(dequant(1.0, p), 1.0);
        assert_eq!(error([1.0f32, 3.0].into_iter(), p), 0.0);
    }

    #[test]
    fn fit_is_l2_optimal_alpha() {
        // given μ = mean, perturbing α must not reduce error
        check(
            "binarize-alpha-optimal",
            40,
            |g: &mut Gen| { let n = g.size(2, 60); g.vec_f32(n, 2.0) },
            |vals| {
                let (p, e) = fit_and_error(vals.iter().copied());
                for da in [-0.05f32, 0.05] {
                    let p2 = BinParams { alpha: p.alpha + da, mu: p.mu };
                    let e2 = error(vals.iter().copied(), p2);
                    if e2 < e - 1e-6 {
                        return Err(format!("α not optimal: {e2} < {e}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn residual_beats_single() {
        check(
            "residual-beats-single",
            30,
            |g: &mut Gen| { let n = g.size(4, 80); g.vec_f32(n, 1.0) },
            |vals| {
                let (p, e1) = fit_and_error(vals.iter().copied());
                let rp = fit_residual(vals);
                let e2: f64 = vals
                    .iter()
                    .map(|&v| ((v - dequant_residual(v, rp)) as f64).powi(2))
                    .sum();
                let _ = p;
                if e2 <= e1 + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("residual worse: {e2} > {e1}"))
                }
            },
        );
    }

    #[test]
    fn arb_refinement_never_hurts() {
        check(
            "arb-never-hurts",
            30,
            |g: &mut Gen| {
                // skewed data where initial mean-split is suboptimal
                let n = g.size(4, 60);
                let mut v = g.vec_f32(n, 1.0);
                for x in v.iter_mut().take(n / 4) {
                    *x = x.abs() * 5.0;
                }
                v
            },
            |vals| {
                let (_, e0) = fit_and_error(vals.iter().copied());
                let p = fit_arb(vals, 8);
                let e1 = error(vals.iter().copied(), p);
                if e1 <= e0 + 1e-4 {
                    Ok(())
                } else {
                    Err(format!("ARB hurt: {e1} > {e0}"))
                }
            },
        );
    }

    #[test]
    fn empty_group_is_safe() {
        let p = fit(std::iter::empty());
        assert_eq!(p, BinParams::default());
        let rp = fit_residual(&[]);
        assert_eq!(rp.alpha1, 0.0);
    }
}
