//! HBLLM (§3): HaarQuant with frequency-aware intra-row grouping, ℓ2
//! saliency-driven column selection, FillAvg, intra-band mean sharing —
//! riding the blockwise OBQ substrate (Algorithm 1).
//!
//! Two variants, as in Fig. 2:
//!  * `Row` — non-salient part row-Haar'd (per row, within the block),
//!    per-band grouped quantization; salient columns carry a column-Haar
//!    residual correction (extra sign bits on K columns).
//!  * `Col` — whole block column-Haar'd; one grouped quantization per
//!    coefficient row; salient columns only steer the fit (no extra bits),
//!    which is why its W-bits ≈ 1.00.
//!
//! Scale scope (appendix-D storage): with `ScaleScope::RowGlobal` (default,
//! paper-faithful W-bits ≈ 1.1/1.0) the per-block (α, μ) fits used during
//! OBQ are *repacked* after quantization: signs and group assignments are
//! kept, and one (α₁, α₂, shared μ) triple per (row, band) is refit in
//! closed form across the full width. `ScaleScope::Block` keeps the
//! per-block fp16 fits (higher fidelity, ~0.75 extra bits/weight at
//! β = 128) — the trade-off is an ablation in `examples/ablations.rs`.

use super::binarize;
use super::gptq::obq_blockwise;
use super::grouping::{self, Granularity, GroupOpts};
use super::salient::{self, Criterion};
use super::storage;
use super::{BitsBreakdown, HessianCtx, QuantOut, Quantizer, DEFAULT_BETA};
use crate::haar;
use crate::tensor::Matrix;
use std::cell::RefCell;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    Row,
    Col,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ScaleScope {
    /// fp16 (α, μ) per row per band per OBQ block (max fidelity)
    Block,
    /// one (α₁, α₂, μ) per row per band, refit over the full width (paper)
    RowGlobal,
}

#[derive(Clone, Debug)]
pub struct HbllmOpts {
    pub beta: usize,
    pub n_candidates: usize,
    pub shared_mean: bool,
    pub criterion: Criterion,
    pub granularity: Granularity,
    /// search K over `salient::k_options` (paper default) vs a fixed K
    pub search_salient_k: bool,
    pub fixed_k: usize,
    /// Haar decomposition levels (1 = paper; >1 is our extension)
    pub levels: usize,
    pub scale_scope: ScaleScope,
    /// Oracle grouping: per-row magnitude order with an (undeployable)
    /// per-element membership bitmap — quantifies the fidelity cost of the
    /// deployable shared-order encoding (DESIGN.md §Group-membership).
    pub oracle_grouping: bool,
}

impl Default for HbllmOpts {
    fn default() -> Self {
        HbllmOpts {
            beta: DEFAULT_BETA,
            n_candidates: 40,
            shared_mean: true,
            criterion: Criterion::L2,
            granularity: Granularity::RowWise,
            search_salient_k: true,
            fixed_k: 8,
            levels: 1,
            scale_scope: ScaleScope::RowGlobal,
            oracle_grouping: false,
        }
    }
}

pub struct Hbllm {
    pub variant: Variant,
    pub opts: HbllmOpts,
}

/// Per-block quantization record used by the RowGlobal repack: Haar-domain
/// coefficients, their sign/band/group assignment, and the (already dense)
/// salient residual correction added after synthesis.
struct BlockAux {
    off: usize,
    width: usize,
    /// pre-quantization coefficients (row-Haar of the filled block for Row,
    /// col-Haar of the block for Col)
    c_orig: Matrix,
    /// +1/-1 per coefficient
    sign: Vec<i8>,
    /// band id per coefficient (0 = deepest low band)
    band: Vec<u8>,
    /// group id within band (0 or 1)
    group: Vec<u8>,
    /// band boundaries for Row synthesis (from fwd_rows_multi)
    bounds: Vec<usize>,
    /// dense correction added after inverse transform (salient residual)
    salient_add: Option<Matrix>,
    /// quantized coefficients as produced at block time; elements marked
    /// `fixed` keep this value through the RowGlobal repack (per-column
    /// salient fits in the Col variant)
    c_hat: Matrix,
    fixed: Vec<bool>,
    variant: Variant,
}

impl Hbllm {
    pub fn row() -> Hbllm {
        Hbllm { variant: Variant::Row, opts: HbllmOpts::default() }
    }

    pub fn col() -> Hbllm {
        Hbllm { variant: Variant::Col, opts: HbllmOpts::default() }
    }

    pub fn with_opts(variant: Variant, opts: HbllmOpts) -> Hbllm {
        Hbllm { variant, opts }
    }

    // ----- row variant -------------------------------------------------

    /// Quantize the non-salient (filled) part: row-Haar + per-band grouped
    /// binarization. Returns (reconstruction, aux fields).
    fn row_quant_filled(
        &self,
        filled: &Matrix,
        n_candidates: usize,
    ) -> (Matrix, Matrix, Matrix, Vec<i8>, Vec<u8>, Vec<u8>, Vec<usize>) {
        let (c, bounds) = haar::fwd_rows_multi(filled, self.opts.levels);
        let (n, m) = (c.rows, c.cols);
        let mut c_hat = c.clone();
        let mut sign = vec![1i8; n * m];
        let mut band_id = vec![0u8; n * m];
        let mut group_id = vec![0u8; n * m];
        for (bi, band) in bounds.windows(2).enumerate() {
            let (j0, j1) = (band[0], band[1]);
            if j1 == j0 {
                continue;
            }
            let mut col_l2 = vec![0f64; j1 - j0];
            for i in 0..n {
                for (jj, v) in c.row(i)[j0..j1].iter().enumerate() {
                    col_l2[jj] += (*v as f64) * (*v as f64);
                }
            }
            for v in col_l2.iter_mut() {
                *v = v.sqrt();
            }
            let order = grouping::shared_order(&col_l2);
            let cand = grouping::candidates(j1 - j0, n_candidates);
            let rank_of = {
                let mut r = vec![0usize; j1 - j0];
                for (rank, &j) in order.iter().enumerate() {
                    r[j] = rank;
                }
                r
            };
            match self.opts.granularity {
                Granularity::RowWise => {
                    for i in 0..n {
                        let vals = c.row(i)[j0..j1].to_vec();
                        // oracle mode ranks by this row's own |values|
                        // (needs a per-element bitmap at deployment)
                        let row_order: Vec<usize>;
                        let row_rank: Vec<usize>;
                        let (ord, rank) = if self.opts.oracle_grouping {
                            let mut o: Vec<usize> = (0..vals.len()).collect();
                            o.sort_by(|&a, &b| {
                                vals[b].abs().partial_cmp(&vals[a].abs()).unwrap()
                            });
                            let mut r = vec![0usize; vals.len()];
                            for (rk, &j) in o.iter().enumerate() {
                                r[j] = rk;
                            }
                            row_order = o;
                            row_rank = r;
                            (&row_order[..], &row_rank[..])
                        } else {
                            (&order[..], &rank_of[..])
                        };
                        let f = grouping::fit_row(&vals, ord, &cand, self.opts.shared_mean);
                        let mut recon = vals.clone();
                        grouping::dequant_row(&mut recon, ord, &f);
                        c_hat.row_mut(i)[j0..j1].copy_from_slice(&recon);
                        for jj in 0..j1 - j0 {
                            let idx = i * m + j0 + jj;
                            let g = (rank[jj] >= f.t) as u8;
                            let p = if g == 0 { f.p1 } else { f.p2 };
                            sign[idx] = if vals[jj] >= p.mu { 1 } else { -1 };
                            band_id[idx] = bi as u8;
                            group_id[idx] = g;
                        }
                    }
                }
                Granularity::Global => {
                    let mut rows: Vec<Vec<f32>> =
                        (0..n).map(|i| c.row(i)[j0..j1].to_vec()).collect();
                    let opts = GroupOpts {
                        n_candidates,
                        shared_mean: self.opts.shared_mean,
                        granularity: Granularity::Global,
                    };
                    let orig_rows: Vec<Vec<f32>> = rows.clone();
                    let fits = grouping::quantize_band(&mut rows, &col_l2, &opts);
                    for i in 0..n {
                        c_hat.row_mut(i)[j0..j1].copy_from_slice(&rows[i]);
                        let f = &fits[i];
                        for jj in 0..j1 - j0 {
                            let idx = i * m + j0 + jj;
                            let g = (rank_of[jj] >= f.t) as u8;
                            let p = if g == 0 { f.p1 } else { f.p2 };
                            sign[idx] = if orig_rows[i][jj] >= p.mu { 1 } else { -1 };
                            band_id[idx] = bi as u8;
                            group_id[idx] = g;
                        }
                    }
                }
            }
        }
        let recon = haar::inv_rows_multi(&c_hat, &bounds);
        (recon, c, c_hat, sign, band_id, group_id, bounds)
    }

    /// Column-Haar residual binarization of the salient columns: per
    /// column, per frequency half, a two-stage residual binarization
    /// (outlier columns carry most of the block energy, so they get 2
    /// extra sign bits per element — charged in `storage::hbllm_row_bits`).
    fn col_quant_salient(resid: &Matrix, salient: &[usize]) -> Matrix {
        let n = resid.rows;
        let mut out = Matrix::zeros(n, resid.cols);
        if salient.is_empty() {
            return out;
        }
        if n % 2 != 0 || n < 2 {
            for &j in salient {
                let col = resid.col(j);
                let p = binarize::fit_residual(&col);
                for i in 0..n {
                    out.set(i, j, binarize::dequant_residual(col[i], p));
                }
            }
            return out;
        }
        let h = n / 2;
        for &j in salient {
            let col = resid.col(j);
            let mut lo = vec![0f32; h];
            let mut hi = vec![0f32; h];
            for k in 0..h {
                lo[k] = (col[2 * k] + col[2 * k + 1]) * 0.5;
                hi[k] = (col[2 * k] - col[2 * k + 1]) * 0.5;
            }
            let plo = binarize::fit_residual(&lo);
            let phi = binarize::fit_residual(&hi);
            for k in 0..h {
                let dl = binarize::dequant_residual(lo[k], plo);
                let dh = binarize::dequant_residual(hi[k], phi);
                out.set(2 * k, j, dl + dh);
                out.set(2 * k + 1, j, dl - dh);
            }
        }
        out
    }

    fn row_block(&self, blk: &Matrix, off: usize, ctx: &HessianCtx) -> (Matrix, BlockAux) {
        // 1. salient selection: score, then pick the K minimizing block error
        let scores = salient::column_scores(blk, &ctx.hinv_diag, off, self.opts.criterion);
        let ks: Vec<usize> = if self.opts.search_salient_k {
            salient::k_options(blk.cols)
        } else {
            vec![self.opts.fixed_k.min(blk.cols / 2)]
        };
        let mut best: Option<(Vec<usize>, f64)> = None;
        // K is chosen by the Hessian-weighted block error — the objective
        // the OBQ pipeline actually minimizes (Eq. 1), diag approximation.
        let hdiag: Vec<f64> = (0..blk.cols).map(|j| ctx.h.get(off + j, off + j)).collect();
        for &k in &ks {
            let sal = salient::top_k(&scores, k);
            let (recon, ..) = self.row_reconstruct(blk, &sal, 8.min(self.opts.n_candidates));
            let mut err = 0f64;
            for i in 0..blk.rows {
                for (j, (&a, &b)) in blk.row(i).iter().zip(recon.row(i)).enumerate() {
                    let d = (a - b) as f64;
                    err += hdiag[j] * d * d;
                }
            }
            if best.as_ref().map_or(true, |(_, e)| err < *e) {
                best = Some((sal, err));
            }
        }
        let (sal, _) = best.unwrap();
        let (recon, c, c_hat, sign, band, group, bounds, sal_add) =
            self.row_reconstruct(blk, &sal, self.opts.n_candidates);
        let fixed = vec![false; c.rows * c.cols];
        let aux = BlockAux {
            off,
            width: blk.cols,
            c_orig: c,
            sign,
            band,
            group,
            bounds,
            salient_add: sal_add,
            c_hat,
            fixed,
            variant: Variant::Row,
        };
        (recon, aux)
    }

    #[allow(clippy::type_complexity)]
    fn row_reconstruct(
        &self,
        blk: &Matrix,
        sal: &[usize],
        n_candidates: usize,
    ) -> (Matrix, Matrix, Matrix, Vec<i8>, Vec<u8>, Vec<u8>, Vec<usize>, Option<Matrix>) {
        let filled = salient::fill_avg(blk, sal);
        let (mut b, c, c_hat, sign, band, group, bounds) = self.row_quant_filled(&filled, n_candidates);
        let mut sal_add = None;
        if !sal.is_empty() {
            let resid = blk.sub(&b);
            let b_sal = Self::col_quant_salient(&resid, sal);
            for &j in sal {
                for i in 0..blk.rows {
                    let v = b.get(i, j) + b_sal.get(i, j);
                    b.set(i, j, v);
                }
            }
            sal_add = Some(b_sal);
        }
        (b, c, c_hat, sign, band, group, bounds, sal_add)
    }

    // ----- col variant -------------------------------------------------

    fn col_block(&self, blk: &Matrix, off: usize, ctx: &HessianCtx) -> (Matrix, BlockAux) {
        let n = blk.rows;
        if n % 2 != 0 || n < 2 {
            return self.row_block(blk, off, ctx);
        }
        let scores = salient::column_scores(blk, &ctx.hinv_diag, off, self.opts.criterion);
        let k = self.opts.fixed_k.min(blk.cols / 2);
        let sal = salient::top_k(&scores, k);
        let is_sal = {
            let mut v = vec![false; blk.cols];
            for &j in &sal {
                v[j] = true;
            }
            v
        };

        let c = haar::fwd_cols(blk);
        let m = blk.cols;
        let h = n / 2;
        let mut c_hat = c.clone();
        let mut sign = vec![1i8; n * m];
        let mut band_id = vec![0u8; n * m];
        let mut group_id = vec![0u8; n * m];
        for (bi, (r0, r1)) in [(0usize, h), (h, n)].into_iter().enumerate() {
            let mut col_l2 = vec![0f64; m];
            for i in r0..r1 {
                for (j, v) in c.row(i).iter().enumerate() {
                    if !is_sal[j] {
                        col_l2[j] += (*v as f64) * (*v as f64);
                    }
                }
            }
            for v in col_l2.iter_mut() {
                *v = v.sqrt();
            }
            let order = grouping::shared_order(&col_l2);
            let rank_of = {
                let mut r = vec![0usize; m];
                for (rank, &j) in order.iter().enumerate() {
                    r[j] = rank;
                }
                r
            };
            let mut cand = grouping::candidates(m, self.opts.n_candidates);
            if self.opts.granularity == Granularity::Global {
                // one split shared by all rows of the band (Table 2b arm):
                // pick the t minimizing the summed per-row error
                let mut best_t = m;
                let mut best_err = f64::INFINITY;
                for &t in &cand.clone() {
                    let mut total = 0.0;
                    for i in r0..r1 {
                        let vals = c.row(i).to_vec();
                        let f = fit_row_excluding(&vals, &order, &[t], self.opts.shared_mean, &is_sal);
                        total += f.err;
                    }
                    if total < best_err {
                        best_err = total;
                        best_t = t;
                    }
                }
                cand = vec![best_t];
            }
            // salient (outlier) columns get their own per-column (α, μ) per
            // band — a handful of fp16 pairs per block, no extra sign bits
            let mut sal_params: Vec<binarize::BinParams> = Vec::with_capacity(sal.len());
            for &j in &sal {
                let vals: Vec<f32> = (r0..r1).map(|i| c.get(i, j)).collect();
                sal_params.push(binarize::fit(vals.iter().copied()));
            }
            for i in r0..r1 {
                let vals = c.row(i).to_vec();
                let fit = fit_row_excluding(&vals, &order, &cand, self.opts.shared_mean, &is_sal);
                let mut recon = vals.clone();
                grouping::dequant_row(&mut recon, &order, &fit);
                for (si, &j) in sal.iter().enumerate() {
                    recon[j] = binarize::dequant(vals[j], sal_params[si]);
                }
                c_hat.row_mut(i).copy_from_slice(&recon);
                for j in 0..m {
                    let idx = i * m + j;
                    let g = (rank_of[j] >= fit.t) as u8;
                    let p = if g == 0 { fit.p1 } else { fit.p2 };
                    sign[idx] = if vals[j] >= p.mu { 1 } else { -1 };
                    band_id[idx] = bi as u8;
                    group_id[idx] = g;
                }
            }
        }
        let recon = haar::inv_cols(&c_hat);
        let mut fixed = vec![false; n * m];
        for &j in &sal {
            for i in 0..n {
                fixed[i * m + j] = true;
            }
        }
        let aux = BlockAux {
            off,
            width: m,
            c_orig: c.clone(),
            sign,
            band: band_id,
            group: group_id,
            bounds: vec![],
            salient_add: None,
            c_hat,
            fixed,
            variant: Variant::Col,
        };
        (recon, aux)
    }

    // ----- RowGlobal repack --------------------------------------------

    /// Refit one (α₁, α₂, shared μ) triple per (row, band) across all
    /// blocks, keeping signs and group assignments; rebuild Ŵ from the
    /// refit scales. Closed-form 3×3 normal equations per (row, band).
    fn repack_row_global(&self, n: usize, m: usize, auxes: &[BlockAux]) -> Matrix {
        let n_bands = auxes
            .iter()
            .flat_map(|a| a.band.iter().copied())
            .max()
            .unwrap_or(0) as usize
            + 1;
        // stats[(row, band)]: per group g: n_g, Σc, Σs, Σs·c
        #[derive(Clone, Copy, Default)]
        struct G {
            n: f64,
            sc: f64,   // Σ c
            ss: f64,   // Σ s
            ssc: f64,  // Σ s·c
        }
        let mut stats = vec![[G::default(); 2]; n * n_bands];
        for a in auxes {
            let w = a.width;
            let rows = a.c_orig.rows;
            for i in 0..rows {
                for j in 0..w {
                    let idx = i * w + j;
                    if a.fixed[idx] {
                        continue;
                    }
                    let key = i * n_bands + a.band[idx] as usize;
                    let g = &mut stats[key][a.group[idx] as usize];
                    let c = a.c_orig.get(i, j) as f64;
                    let s = a.sign[idx] as f64;
                    g.n += 1.0;
                    g.sc += c;
                    g.ss += s;
                    g.ssc += s * c;
                }
            }
        }
        // solve per (row, band): unknowns x = (α₁, α₂, μ)
        //   α_g·n_g + μ·ss_g = ssc_g                (g = 1, 2)
        //   α₁·ss₁ + α₂·ss₂ + μ·(n₁+n₂) = sc₁+sc₂
        let mut alphas = vec![[0f32; 2]; n * n_bands];
        let mut mus = vec![0f32; n * n_bands];
        for key in 0..n * n_bands {
            let [g1, g2] = stats[key];
            let a = [
                [g1.n, 0.0, g1.ss],
                [0.0, g2.n, g2.ss],
                [g1.ss, g2.ss, g1.n + g2.n],
            ];
            let b = [g1.ssc, g2.ssc, g1.sc + g2.sc];
            if let Some(x) = solve3(a, b) {
                alphas[key] = [x[0].max(0.0) as f32, x[1].max(0.0) as f32];
                mus[key] = x[2] as f32;
            } else if g1.n + g2.n > 0.0 {
                // degenerate (e.g. empty group): single-group fallback
                let nall = g1.n + g2.n;
                let mu = (g1.sc + g2.sc) / nall;
                let al = ((g1.ssc + g2.ssc) - mu * (g1.ss + g2.ss)) / nall;
                alphas[key] = [al.max(0.0) as f32; 2];
                mus[key] = mu as f32;
            }
        }
        // rebuild
        let mut out = Matrix::zeros(n, m);
        for a in auxes {
            let w = a.width;
            let mut c_hat = Matrix::zeros(a.c_orig.rows, w);
            for i in 0..a.c_orig.rows {
                for j in 0..w {
                    let idx = i * w + j;
                    let v = if a.fixed[idx] {
                        a.c_hat.get(i, j)
                    } else {
                        let key = i * n_bands + a.band[idx] as usize;
                        let al = alphas[key][a.group[idx] as usize];
                        al * a.sign[idx] as f32 + mus[key]
                    };
                    c_hat.set(i, j, v);
                }
            }
            let mut dense = match a.variant {
                Variant::Row => haar::inv_rows_multi(&c_hat, &a.bounds),
                Variant::Col => haar::inv_cols(&c_hat),
            };
            if let Some(add) = &a.salient_add {
                dense.add_scaled(add, 1.0);
            }
            out.set_cols(a.off, &dense);
        }
        out
    }
}

/// Solve a 3×3 linear system (Cramer); None if near-singular.
fn solve3(a: [[f64; 3]; 3], b: [f64; 3]) -> Option<[f64; 3]> {
    let det = |m: [[f64; 3]; 3]| -> f64 {
        m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
            - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
            + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
    };
    let d = det(a);
    if d.abs() < 1e-9 {
        return None;
    }
    let mut out = [0.0; 3];
    for k in 0..3 {
        let mut ak = a;
        for r in 0..3 {
            ak[r][k] = b[r];
        }
        out[k] = det(ak) / d;
    }
    Some(out)
}

/// fit_row variant that fits params on non-excluded indices only.
fn fit_row_excluding(
    vals: &[f32],
    order: &[usize],
    cand: &[usize],
    shared_mean: bool,
    excluded: &[bool],
) -> grouping::RowGroupFit {
    if excluded.iter().all(|&e| !e) {
        return grouping::fit_row(vals, order, cand, shared_mean);
    }
    let kept: Vec<usize> = (0..vals.len()).filter(|&j| !excluded[j]).collect();
    if kept.is_empty() {
        return grouping::fit_row(vals, order, cand, shared_mean);
    }
    let filt_vals: Vec<f32> = kept.iter().map(|&j| vals[j]).collect();
    let rank_of: Vec<usize> = {
        let mut r = vec![0usize; vals.len()];
        for (rank, &j) in order.iter().enumerate() {
            r[j] = rank;
        }
        r
    };
    let mut filt_order: Vec<usize> = (0..kept.len()).collect();
    filt_order.sort_by_key(|&fi| rank_of[kept[fi]]);
    let mut filt_cand: Vec<usize> = cand
        .iter()
        .map(|&t| {
            let c = kept.iter().filter(|&&j| rank_of[j] < t).count();
            c.max(1).min(kept.len())
        })
        .collect();
    filt_cand.dedup();
    let f = grouping::fit_row(&filt_vals, &filt_order, &filt_cand, shared_mean);
    let t_full = if f.t >= filt_order.len() {
        vals.len()
    } else {
        rank_of[kept[filt_order[f.t]]]
    };
    grouping::RowGroupFit { t: t_full, ..f }
}

impl Quantizer for Hbllm {
    fn name(&self) -> String {
        match self.variant {
            Variant::Row => "hbllm-row".into(),
            Variant::Col => "hbllm-col".into(),
        }
    }

    fn quantize(&self, w: &Matrix, ctx: &HessianCtx) -> QuantOut {
        let beta = self.opts.beta.min(w.cols);
        let auxes: RefCell<Vec<BlockAux>> = RefCell::new(Vec::new());
        let b = obq_blockwise(w, ctx, beta, |blk, off| {
            let (recon, aux) = match self.variant {
                Variant::Row => self.row_block(blk, off, ctx),
                Variant::Col => self.col_block(blk, off, ctx),
            };
            auxes.borrow_mut().push(aux);
            recon
        });
        let b = match self.opts.scale_scope {
            ScaleScope::Block => b,
            ScaleScope::RowGlobal => self.repack_row_global(w.rows, w.cols, &auxes.borrow()),
        };
        let mse = w.mse(&b);
        QuantOut { bits: self.storage_bits(w.rows, w.cols), w_hat: b, mse }
    }

    fn storage_bits(&self, n: usize, m: usize) -> BitsBreakdown {
        match self.variant {
            Variant::Row => storage::hbllm_row_bits(n, m, &self.opts),
            Variant::Col => storage::hbllm_col_bits(n, m, &self.opts),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::synth;

    fn run_opts(variant: Variant, n: usize, m: usize, seed: u64, f: impl Fn(&mut HbllmOpts)) -> (Matrix, QuantOut) {
        let (w, ctx) = synth::llm_like_layer(n, m, seed);
        let mut opts = HbllmOpts { beta: 32, n_candidates: 10, ..Default::default() };
        f(&mut opts);
        let q = Hbllm::with_opts(variant, opts);
        let out = q.quantize(&w, &ctx);
        (w, out)
    }

    fn run(variant: Variant, n: usize, m: usize, seed: u64) -> (Matrix, QuantOut) {
        run_opts(variant, n, m, seed, |_| {})
    }

    #[test]
    fn row_variant_reconstructs_better_than_sign_rtn() {
        let (w, out) = run(Variant::Row, 32, 64, 1);
        let mut rtn_err = 0.0f64;
        for i in 0..w.rows {
            let p = binarize::fit(w.row(i).iter().copied());
            rtn_err += binarize::error(w.row(i).iter().copied(), p);
        }
        let rtn_mse = rtn_err / (w.rows * w.cols) as f64;
        assert!(out.mse < rtn_mse, "hbllm-row mse {} !< rtn {}", out.mse, rtn_mse);
    }

    #[test]
    fn col_variant_valid_and_finite() {
        let (w, out) = run(Variant::Col, 32, 64, 2);
        assert_eq!((out.w_hat.rows, out.w_hat.cols), (w.rows, w.cols));
        assert!(out.w_hat.data.iter().all(|v| v.is_finite()));
        assert!(out.mse.is_finite() && out.mse > 0.0);
    }

    #[test]
    fn block_scope_beats_rowglobal_fidelity() {
        // the storage/fidelity trade: per-block scales fit tighter
        let (_, blk) = run_opts(Variant::Row, 32, 96, 3, |o| o.scale_scope = ScaleScope::Block);
        let (_, glob) = run_opts(Variant::Row, 32, 96, 3, |o| o.scale_scope = ScaleScope::RowGlobal);
        assert!(
            blk.mse <= glob.mse * 1.05,
            "block {} vs rowglobal {}",
            blk.mse,
            glob.mse
        );
        // but rowglobal must not be catastrophically worse
        assert!(glob.mse <= blk.mse * 3.0, "repack degraded too much: {} vs {}", glob.mse, blk.mse);
    }

    #[test]
    fn row_beats_col_on_fidelity() {
        let (_, row_out) = run(Variant::Row, 32, 64, 3);
        let (_, col_out) = run(Variant::Col, 32, 64, 3);
        assert!(
            row_out.mse <= col_out.mse * 1.35,
            "row {} vs col {}",
            row_out.mse,
            col_out.mse
        );
        let row_bits = Hbllm::row().avg_wbits(4096, 4096);
        let col_bits = Hbllm::col().avg_wbits(4096, 4096);
        assert!(col_bits < row_bits, "col {col_bits} !< row {row_bits}");
    }

    #[test]
    fn odd_rows_fall_back_safely() {
        let (w, ctx) = synth::llm_like_layer(15, 32, 4);
        let q = Hbllm::col();
        let out = q.quantize(&w, &ctx);
        assert_eq!((out.w_hat.rows, out.w_hat.cols), (w.rows, w.cols));
        assert!(out.w_hat.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic() {
        let (_, a) = run(Variant::Row, 16, 32, 5);
        let (_, b) = run(Variant::Row, 16, 32, 5);
        assert_eq!(a.w_hat.data, b.w_hat.data);
    }

    #[test]
    fn multi_level_roundtrip_sane() {
        let (_, out) = run_opts(Variant::Row, 16, 64, 6, |o| {
            o.levels = 2;
            o.beta = 64;
            o.n_candidates = 8;
        });
        assert!(out.mse.is_finite());
    }

    #[test]
    fn wbits_in_paper_range() {
        let row = Hbllm::row().avg_wbits(4096, 4096);
        let col = Hbllm::col().avg_wbits(4096, 4096);
        assert!(row > 1.0 && row < 1.3, "row wbits {row}");
        assert!(col >= 1.0 && col < 1.1, "col wbits {col}");
    }
}
