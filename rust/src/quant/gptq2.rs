//! GPTQ 2-bit baseline (Frantar et al., ICLR 2023): per-column round-to-
//! nearest onto a 4-level grid with *per-column* error propagation through
//! the Cholesky factor — the classic OBQ reference point the paper's
//! related-work positions everything against.
//!
//! Unlike the blockwise methods, this one propagates error after every
//! single column (the original GPTQ recipe), which makes it a good
//! cross-check of the substrate: with the same Hessian it must beat
//! blockwise 2-bit RTN on the hessian-weighted objective.

use super::{storage, BitsBreakdown, HessianCtx, QuantOut, Quantizer};
use crate::tensor::Matrix;

pub struct Gptq2 {
    /// group size for the absmax scale (paper-standard 128)
    pub group: usize,
}

impl Default for Gptq2 {
    fn default() -> Self {
        Gptq2 { group: 128 }
    }
}

/// 2-bit symmetric grid {-3, -1, 1, 3} · (absmax/3) per (row, group).
fn quant_col_value(v: f32, scale: f32) -> f32 {
    if scale == 0.0 {
        return 0.0;
    }
    let q = (v / scale).round().clamp(-3.0, 3.0);
    let q = if q == 0.0 {
        1.0f32.copysign(v)
    } else if q.abs() == 2.0 {
        3.0f32.copysign(q)
    } else {
        q
    };
    q * scale
}

impl Quantizer for Gptq2 {
    fn name(&self) -> String {
        "gptq-2bit".into()
    }

    fn quantize(&self, w: &Matrix, ctx: &HessianCtx) -> QuantOut {
        let (n, m) = (w.rows, w.cols);
        let mut work = w.clone();
        let mut out = Matrix::zeros(n, m);
        // per-(row, group) scales fit on the *incoming* weights of each group
        let mut scales = vec![0f32; n];
        for j in 0..m {
            if j % self.group == 0 {
                // refresh scales from the current (compensated) group window
                let g1 = (j + self.group).min(m);
                for i in 0..n {
                    let amax = work.row(i)[j..g1]
                        .iter()
                        .fold(0f32, |a, &v| a.max(v.abs()));
                    scales[i] = amax / 3.0;
                }
            }
            let ujj = ctx.u.get(j, j);
            for i in 0..n {
                let v = work.get(i, j);
                let q = quant_col_value(v, scales[i]);
                out.set(i, j, q);
                // propagate the error into future columns: w_fut -= e/Ujj * U[j, fut]
                let e = (v - q) as f64 / ujj;
                if e != 0.0 {
                    let row = work.row_mut(i);
                    for f in j + 1..m {
                        row[f] -= (e * ctx.u.get(j, f)) as f32;
                    }
                }
            }
        }
        let mse = w.mse(&out);
        QuantOut { bits: self.storage_bits(n, m), w_hat: out, mse }
    }

    fn storage_bits(&self, n: usize, m: usize) -> BitsBreakdown {
        BitsBreakdown {
            sign_bits: 2.0 * (n * m) as f64,
            scale_bits: (n as f64) * (m as f64 / self.group as f64).ceil() * storage::FP16,
            index_bits: 0.0,
            salient_bits: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::gptq::hessian_loss;
    use crate::quant::{by_name, synth};

    #[test]
    fn registered() {
        assert!(by_name("gptq-2bit").is_some());
    }

    #[test]
    fn beats_one_bit_rtn_on_the_obq_objective() {
        // OBQ trades plain MSE for the hessian-weighted loss, so compare on
        // the objective it actually minimizes.
        let (w, ctx) = synth::llm_like_layer(24, 96, 60);
        let g = Gptq2::default().quantize(&w, &ctx);
        let r = by_name("rtn").unwrap().quantize(&w, &ctx);
        let lg = hessian_loss(&w, &g.w_hat, &ctx);
        let lr = hessian_loss(&w, &r.w_hat, &ctx);
        assert!(lg < lr, "gptq2 {lg} !< rtn {lr}");
    }

    #[test]
    fn propagation_beats_no_propagation() {
        let (w, ctx) = synth::llm_like_layer(16, 64, 61);
        let with = Gptq2::default().quantize(&w, &ctx);
        // no-propagation variant: identity hessian context (U diagonal)
        let ident = crate::quant::HessianCtx::identity(64);
        let without = Gptq2::default().quantize(&w, &ident);
        let l_with = hessian_loss(&w, &with.w_hat, &ctx);
        let l_without = hessian_loss(&w, &without.w_hat, &ctx);
        assert!(
            l_with < l_without * 1.01,
            "per-column propagation did not help: {l_with} vs {l_without}"
        );
    }

    #[test]
    fn wbits_just_over_two() {
        let b = Gptq2::default().avg_wbits(4096, 4096);
        assert!(b > 2.0 && b < 2.2, "{b}");
    }

    #[test]
    fn grid_levels_are_four() {
        let (w, ctx) = synth::llm_like_layer(4, 32, 62);
        let out = Gptq2 { group: 32 }.quantize(&w, &ctx);
        for i in 0..4 {
            let mut vals: Vec<i64> = out.w_hat.row(i).iter().map(|&v| (v * 1e5) as i64).collect();
            vals.sort();
            vals.dedup();
            assert!(vals.len() <= 4, "row {i}: {} levels", vals.len());
        }
    }
}
