//! Storage accounting (paper appendix D, our reading — DESIGN.md
//! §Group-membership). Scale/mean parameters are fp16; split indices use
//! ⌈log2(candidates+1)⌉ bits; the deployable group encoding adds one shared
//! per-block column permutation (β·⌈log2 β⌉ bits amortized over all n rows).
//!
//! Scale scope follows each method's deployment configuration:
//! HBLLM/BiLLM/ARB repack (α, μ) per row across the full width (the
//! `ScaleScope::RowGlobal` path), which is what makes ~1.1-bit budgets
//! possible; `ScaleScope::Block` charges fp16 per row-block instead.

use super::hbllm::{HbllmOpts, ScaleScope};
use super::BitsBreakdown;

pub const FP16: f64 = 16.0;

fn log2_ceil(x: usize) -> f64 {
    (x.max(2) as f64).log2().ceil()
}

/// Number of β-blocks along the column dimension.
fn nblocks(m: usize, beta: usize) -> f64 {
    ((m + beta - 1) / beta) as f64
}

/// Shared per-block column-permutation cost (deployable grouping).
fn perm_bits(m: usize, beta: usize, bands: f64) -> f64 {
    nblocks(m, beta) * bands * beta.min(m) as f64 * log2_ceil(beta.min(m))
}

/// HBLLM-row storage:
/// * 1 sign bit per weight;
/// * per (row, band): 2 α + shared μ (fp16) under RowGlobal scope, or the
///   same triple per row-block-band under Block scope;
/// * per (row, band, block): split index among the candidates;
/// * per block: salient bitmap (β bits) + K·(2 bands)·(α, μ) column params,
///   plus 1 extra sign bit on the K salient columns (residual correction);
/// * the shared per-block band permutations.
pub fn hbllm_row_bits(n: usize, m: usize, opts: &HbllmOpts) -> BitsBreakdown {
    let beta = opts.beta.min(m);
    let nb = nblocks(m, beta);
    let bands = (opts.levels + 1) as f64;
    let nf = n as f64;
    let split_bits = log2_ceil(opts.n_candidates + 1);
    let mu_per_band = if opts.shared_mean { 1.0 } else { 2.0 };
    let k_avg = 8.0_f64.min(beta as f64 / 4.0); // typical searched K per block

    let scale_group = match opts.scale_scope {
        ScaleScope::RowGlobal => nf * bands * (2.0 + mu_per_band) * FP16,
        ScaleScope::Block => nf * nb * bands * (2.0 + mu_per_band) * FP16,
    };

    let sign_bits = (n * m) as f64;
    let scale_bits = scale_group + nb * k_avg * 2.0 * 3.0 * FP16; // (μ, α₁, α₂)/band
    let index_bits = nf * nb * bands * split_bits
        + perm_bits(m, beta, bands)
        + nb * beta as f64; // salient bitmap
    let salient_bits = 2.0 * nb * k_avg * nf; // two-stage residual sign bits
    BitsBreakdown { sign_bits, scale_bits, index_bits, salient_bits }
}

/// HBLLM-col: one grouped quantization per coefficient row; no salient
/// extras (selection only steers the fit). Per (row, band-of-row): one
/// (α₁, α₂, μ) triple RowGlobal, split index per block, global band orders.
pub fn hbllm_col_bits(n: usize, m: usize, opts: &HbllmOpts) -> BitsBreakdown {
    let beta = opts.beta.min(m);
    let nb = nblocks(m, beta);
    let nf = n as f64;
    let split_bits = log2_ceil(opts.n_candidates + 1);
    let mu = if opts.shared_mean { 1.0 } else { 2.0 };
    let scale_group = match opts.scale_scope {
        ScaleScope::RowGlobal => nf * (2.0 + mu) * FP16,
        ScaleScope::Block => nf * nb * (2.0 + mu) * FP16,
    };
    let sign_bits = (n * m) as f64;
    let index_bits = nf * nb * split_bits + 2.0 * m as f64 * log2_ceil(m);
    BitsBreakdown { sign_bits, scale_bits: scale_group, index_bits, salient_bits: 0.0 }
}

/// BiLLM: salient residual binarization + concentrated/sparse split.
/// Full-width repacked scales (2 non-salient α + residual params per row).
pub fn billm_bits(n: usize, m: usize, beta: usize) -> BitsBreakdown {
    let nb = nblocks(m, beta);
    let nf = n as f64;
    let k_avg = (beta as f64 / 16.0).max(1.0);
    let sign_bits = (n * m) as f64;
    let scale_bits = nf * 4.0 * FP16 + nb * k_avg * 2.0 * FP16;
    let index_bits = nb * beta as f64
        + perm_bits(m, beta, 1.0)
        + nf * nb * log2_ceil(32); // break index per row-block
    let salient_bits = nb * k_avg * nf; // residual second sign bit
    BitsBreakdown { sign_bits, scale_bits, index_bits, salient_bits }
}

/// ARB-LLM_X: alternating refined binarization + CGB bitmaps.
pub fn arb_x_bits(n: usize, m: usize, beta: usize) -> BitsBreakdown {
    let nb = nblocks(m, beta);
    let nf = n as f64;
    let k_avg = (beta as f64 / 16.0).max(1.0);
    let sign_bits = (n * m) as f64;
    let scale_bits = nf * 2.0 * FP16 + nb * k_avg * 2.0 * FP16;
    let index_bits = nb * 2.0 * beta as f64; // CGB: column + group bitmaps
    let salient_bits = nb * k_avg * nf;
    BitsBreakdown { sign_bits, scale_bits, index_bits, salient_bits }
}

/// ARB-LLM_RC: adds a per-column scale vector (row×column scaling).
pub fn arb_rc_bits(n: usize, m: usize, beta: usize) -> BitsBreakdown {
    let mut b = arb_x_bits(n, m, beta);
    b.scale_bits += m as f64 * FP16;
    b
}

/// PB-LLM at 10% salient kept int8 (their own accounting: mask omitted).
pub fn pbllm_bits(n: usize, m: usize) -> BitsBreakdown {
    let total = (n * m) as f64;
    let frac = 0.10;
    BitsBreakdown {
        sign_bits: total * (1.0 - frac),
        scale_bits: n as f64 * 2.0 * FP16,
        index_bits: 0.0,
        salient_bits: total * frac * 8.0,
    }
}

/// FrameQuant at redundancy r: 2-bit codes in the expanded frame + per-group
/// fp16 scales (group 128).
pub fn framequant_bits(n: usize, m: usize, r: f64) -> BitsBreakdown {
    let total = (n as f64 * r).ceil() * m as f64;
    BitsBreakdown {
        sign_bits: 2.0 * total,
        scale_bits: (total / 128.0) * FP16,
        index_bits: 0.0,
        salient_bits: 0.0,
    }
}

/// 1-bit RTN: per-row (α, μ).
pub fn rtn_bits(n: usize, m: usize) -> BitsBreakdown {
    BitsBreakdown {
        sign_bits: (n * m) as f64,
        scale_bits: n as f64 * 2.0 * FP16,
        index_bits: 0.0,
        salient_bits: 0.0,
    }
}

/// Bytes for a whole model given per-matrix W-bits, for Table 4:
/// Σ over matrices of (n·m·wbits)/8, plus fp16 embeddings/norms.
pub fn model_storage_gb(
    matrix_dims: &[(usize, usize)],
    wbits_fn: impl Fn(usize, usize) -> f64,
    fp16_params: usize,
) -> f64 {
    let mut bits = 0.0;
    for &(n, m) in matrix_dims {
        bits += n as f64 * m as f64 * wbits_fn(n, m);
    }
    bits += fp16_params as f64 * FP16;
    bits / 8.0 / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::hbllm::HbllmOpts;

    const D: usize = 4096; // LLaMA-7B hidden dim

    #[test]
    fn paper_shape_ordering() {
        let opts = HbllmOpts::default();
        let row = hbllm_row_bits(D, D, &opts).per_weight(D, D);
        let col = hbllm_col_bits(D, D, &opts).per_weight(D, D);
        let billm = billm_bits(D, D, 128).per_weight(D, D);
        let pb = pbllm_bits(D, D).per_weight(D, D);
        let fq = framequant_bits(D, D, 1.1).per_weight(D, D);
        // paper's ordering: col ≈ 1.0x < row ≈ billm ≈ 1.1–1.2 < pb 1.7 < fq 2.2
        assert!(col < row, "col {col} !< row {row}");
        assert!(col >= 1.0 && col < 1.1, "col {col}");
        assert!(row > 1.0 && row < 1.3, "row {row}");
        assert!(billm > 1.0 && billm < 1.3, "billm {billm}");
        assert!((pb - 1.7).abs() < 0.15, "pb {pb}");
        assert!((fq - 2.2).abs() < 0.2, "fq {fq}");
    }

    #[test]
    fn block_scope_costs_more() {
        let mut block = HbllmOpts::default();
        block.scale_scope = ScaleScope::Block;
        let b = hbllm_row_bits(D, D, &block).per_weight(D, D);
        let g = hbllm_row_bits(D, D, &HbllmOpts::default()).per_weight(D, D);
        assert!(b > g + 0.5, "block {b} vs rowglobal {g}");
    }

    #[test]
    fn shared_mean_saves_bits() {
        let mut no_share = HbllmOpts::default();
        no_share.shared_mean = false;
        let with = hbllm_row_bits(D, D, &HbllmOpts::default()).per_weight(D, D);
        let without = hbllm_row_bits(D, D, &no_share).per_weight(D, D);
        assert!(without > with, "{without} !> {with}");
    }

    #[test]
    fn rc_more_than_x() {
        let x = arb_x_bits(D, D, 128).per_weight(D, D);
        let rc = arb_rc_bits(D, D, 128).per_weight(D, D);
        assert!(rc > x);
    }

    #[test]
    fn model_storage_counts_fp16_side() {
        let gb = model_storage_gb(&[(1024, 1024)], |_, _| 1.0, 1024 * 1024);
        assert!((gb - (17.0 * 1024.0 * 1024.0 / 8.0 / 1e9)).abs() < 1e-6);
    }
}
