//! Naive 1-bit round-to-nearest baseline: per-row α·sign(w−μ)+μ, no
//! calibration, no grouping. The floor every structured method must beat.

use super::binarize;
use super::{storage, BitsBreakdown, HessianCtx, QuantOut, Quantizer};
use crate::tensor::Matrix;

#[derive(Default)]
pub struct Rtn;

impl Quantizer for Rtn {
    fn name(&self) -> String {
        "rtn".into()
    }

    fn quantize(&self, w: &Matrix, _ctx: &HessianCtx) -> QuantOut {
        let mut out = Matrix::zeros(w.rows, w.cols);
        for i in 0..w.rows {
            let p = binarize::fit(w.row(i).iter().copied());
            for (j, &v) in w.row(i).iter().enumerate() {
                out.set(i, j, binarize::dequant(v, p));
            }
        }
        let mse = w.mse(&out);
        QuantOut { bits: self.storage_bits(w.rows, w.cols), w_hat: out, mse }
    }

    fn storage_bits(&self, n: usize, m: usize) -> BitsBreakdown {
        storage::rtn_bits(n, m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::synth;

    #[test]
    fn two_values_per_row() {
        let (w, ctx) = synth::llm_like_layer(8, 32, 1);
        let out = Rtn.quantize(&w, &ctx);
        for i in 0..8 {
            let mut vals: Vec<i64> = out.w_hat.row(i).iter().map(|&v| (v * 1e6) as i64).collect();
            vals.sort();
            vals.dedup();
            assert!(vals.len() <= 2, "row {i}: {} distinct", vals.len());
        }
    }

    #[test]
    fn wbits_near_one() {
        let b = Rtn.avg_wbits(4096, 4096);
        assert!(b > 1.0 && b < 1.01);
    }
}
