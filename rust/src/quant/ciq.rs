//! CIQ — cardinality of the inverse-quantization set (§3.1): the number of
//! distinct dequantized values a method can produce within one row. The
//! paper's expressiveness metric: BiLLM 8, ARB-LLM_X 10 (up to 128 with
//! column grouping), HBLLM up to 1024 after the Haar transform.

use crate::tensor::Matrix;

/// Distinct values in one row (quantized to 1e-5 resolution to absorb f32
/// noise in reconstruction arithmetic).
pub fn row_ciq(row: &[f32]) -> usize {
    let mut keys: Vec<i64> = row.iter().map(|&v| (v as f64 * 1e5).round() as i64).collect();
    keys.sort();
    keys.dedup();
    keys.len()
}

/// Max over rows.
pub fn row_ciq_max(w_hat: &Matrix) -> usize {
    (0..w_hat.rows).map(|i| row_ciq(w_hat.row(i))).max().unwrap_or(0)
}

/// Mean over rows.
pub fn row_ciq_mean(w_hat: &Matrix) -> f64 {
    if w_hat.rows == 0 {
        return 0.0;
    }
    (0..w_hat.rows).map(|i| row_ciq(w_hat.row(i))).sum::<usize>() as f64 / w_hat.rows as f64
}

/// Theoretical CIQ upper bounds per block-row (paper §3.1 argument).
pub fn theoretical_bound(method: &str, beta: usize) -> usize {
    match method {
        "rtn" => 2,
        "billm" => 8,
        "arb-x" => 10,
        "arb-rc" => beta, // column scales: up to β distinct magnitudes
        // HBLLM row: per band 4 coefficient values; the inverse butterfly
        // combines (lo, hi) pairs -> 4·4 ordered pairs × 2 outputs, and the
        // salient column correction doubles again: ≤ 1024 over a block
        "hbllm-row" => 1024,
        "hbllm-col" => 64,
        _ => usize::MAX,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{by_name, synth};

    #[test]
    fn row_ciq_counts() {
        assert_eq!(row_ciq(&[1.0, 1.0, 2.0, -1.0]), 3);
        assert_eq!(row_ciq(&[]), 0);
        // resolution absorbs f32 jitter
        assert_eq!(row_ciq(&[1.0, 1.0 + 1e-7]), 1);
    }

    #[test]
    fn empirical_ciq_respects_theory_and_ranks_methods() {
        let (w, ctx) = synth::llm_like_layer(16, 64, 50);
        let mut ciqs = std::collections::BTreeMap::new();
        for name in ["rtn", "billm", "hbllm-row"] {
            let q = by_name(name).unwrap();
            let out = q.quantize(&w, &ctx);
            ciqs.insert(name, row_ciq_max(&out.w_hat));
        }
        assert!(ciqs["rtn"] <= 2);
        assert!(ciqs["billm"] <= theoretical_bound("billm", 64));
        // the paper's §3.1 claim: HBLLM's expressiveness strictly exceeds
        // BiLLM's
        assert!(
            ciqs["hbllm-row"] > ciqs["billm"],
            "hbllm {} !> billm {}",
            ciqs["hbllm-row"],
            ciqs["billm"]
        );
    }
}
