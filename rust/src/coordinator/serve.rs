//! Line-protocol TCP generation + scoring server over the quantized model.
//!
//! Protocol (one UTF-8 line per request; full spec in `README.md`
//! §Serving):
//!
//! * `ppl <text>` → `ppl <value>` (byte-level perplexity) or `err <msg>`.
//!   Empty / whitespace-only text is `err empty input`, never a
//!   perplexity over pad bytes.
//!
//! Verbs take precedence: a line is a verb iff it starts with `ppl ` or
//! `gen`/`gen `; anything else is scored as legacy bare text (the pre-verb
//! protocol). A legacy text that itself begins with a verb keyword must be
//! sent as `ppl <text>` to be scored.
//! * `gen <max-new> <temperature> <seed> <prompt…>` → a stream of
//!   `tok <byte>` lines (one per sampled byte, written as it is decoded),
//!   terminated by `done <n-generated>`, or `err <msg>`.
//!
//! Backend-generic: any [`engine::Backend`](crate::engine::Backend) can be
//! served. The backend stays on the [`run_engine`] thread (xla handles are
//! not Sync, and the native engine's KV lanes are mutable state);
//! connection handlers only exchange messages through the batcher channel.
//! Generation is continuously batched: a [`GenScheduler`] admits queued
//! requests into free KV lanes between decode sweeps, so sequences join
//! and leave the running batch without draining it.
//!
//! KV memory is paged (`serve --kv-blocks`/`--block-len`): on a metered
//! backend admission additionally waits for enough free KV blocks, and a
//! sequence evicted mid-decode because the arena ran dry gets a single
//! `err kv exhausted` line — the sweep itself keeps running for everyone
//! else.
//!
//! Each TCP connection gets its own client id
//! ([`BatcherHandle::connection`]) and generation admission round-robins
//! across clients, so one chatty connection cannot starve the rest. With
//! `serve --spec-k N`, greedy requests decode speculatively (the
//! frequency cascade, `engine::spec`) — byte-identical output, several
//! verified tokens per sweep — while sampling requests share the same
//! lanes on the plain path.

use super::batcher::{Batcher, BatcherConfig, BatcherHandle, Request, Work};
use super::scheduler::{GenEvent, GenScheduler};
use crate::engine::paged::blocks_for;
use crate::engine::Backend;
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

/// Decode steps a pending scoring batch waits for KV blocks before being
/// flushed anyway (each step can evict and free blocks; after this many,
/// the honest `kv exhausted` error beats further starvation).
const SCORE_PATIENCE: usize = 128;

/// Score a batch of texts: mean NLL/byte → perplexity per text.
///
/// Empty and whitespace-only texts short-circuit to `Err("empty input")`
/// without occupying a batch row — their padded token rows would otherwise
/// report a "perplexity" computed over pad bytes.
pub fn score_texts(be: &mut dyn Backend, texts: &[Vec<u8>]) -> Vec<Result<f64, String>> {
    let (batch, seq) = (be.batch(), be.seq());
    let mut out: Vec<Option<Result<f64, String>>> = texts
        .iter()
        .map(|t| {
            t.iter()
                .all(|b| b.is_ascii_whitespace())
                .then(|| Err("empty input".to_string()))
        })
        .collect();
    let scoreable: Vec<usize> = (0..texts.len()).filter(|&i| out[i].is_none()).collect();
    for chunk in scoreable.chunks(batch) {
        let mut tokens = vec![b'\n' as i32; batch * seq];
        let mut lens = Vec::with_capacity(chunk.len());
        for (r, &ti) in chunk.iter().enumerate() {
            let text = &texts[ti];
            let take = text.len().min(seq);
            for (c, &b) in text[..take].iter().enumerate() {
                tokens[r * seq + c] = b as i32;
            }
            lens.push(take);
        }
        match be.nll(&tokens) {
            Ok(nll) => {
                let per_row = seq - 1;
                for (r, (&ti, &len)) in chunk.iter().zip(&lens).enumerate() {
                    let hi = len.saturating_sub(1).max(1).min(per_row);
                    let mean: f64 = nll[r * per_row..r * per_row + hi]
                        .iter()
                        .map(|&v| v as f64)
                        .sum::<f64>()
                        / hi as f64;
                    out[ti] = Some(Ok(mean.exp()));
                }
            }
            Err(e) => {
                for &ti in chunk {
                    out[ti] = Some(Err(e.to_string()));
                }
            }
        }
    }
    out.into_iter().map(|o| o.expect("every text resolved")).collect()
}

/// Stream a generation request's events back over the socket. Returns
/// `false` once the connection is unusable (the dropped receiver then
/// evicts the sequence from its KV lane at the engine's next step).
fn handle_gen(args: &str, handle: &BatcherHandle, writer: &mut TcpStream) -> bool {
    let mut it = args.splitn(4, ' ');
    let parsed = (
        it.next().and_then(|s| s.parse::<usize>().ok()),
        it.next().and_then(|s| s.parse::<f32>().ok()),
        it.next().and_then(|s| s.parse::<u64>().ok()),
    );
    let (max_new, temperature, seed) = match parsed {
        (Some(m), Some(t), Some(s)) => (m, t, s),
        _ => {
            return writer
                .write_all(b"err usage: gen <max-new> <temperature> <seed> <prompt>\n")
                .is_ok()
        }
    };
    let prompt = it.next().unwrap_or("");
    let rx = match handle.generate(prompt.as_bytes(), max_new, temperature, seed) {
        Ok(rx) => rx,
        Err(e) => return writer.write_all(format!("err {e}\n").as_bytes()).is_ok(),
    };
    for ev in rx {
        let ok = match ev {
            GenEvent::Token(b) => writer.write_all(format!("tok {b}\n").as_bytes()).is_ok(),
            GenEvent::Done { generated, .. } => {
                return writer.write_all(format!("done {generated}\n").as_bytes()).is_ok()
            }
            GenEvent::Error(e) => {
                return writer.write_all(format!("err {e}\n").as_bytes()).is_ok()
            }
        };
        if !ok {
            return false;
        }
    }
    // channel closed without a terminal event: server shutting down
    writer.write_all(b"err aborted\n").is_ok()
}

fn handle_conn(stream: TcpStream, handle: BatcherHandle) {
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.is_empty() {
            continue;
        }
        let ok = if let Some(rest) = line.strip_prefix("gen ") {
            handle_gen(rest, &handle, &mut writer)
        } else if line == "gen" {
            handle_gen("", &handle, &mut writer)
        } else {
            // `ppl <text>`, or a legacy bare line scored as-is
            let text = line.strip_prefix("ppl ").unwrap_or(&line);
            let resp = match handle.score(text.as_bytes()) {
                Ok(ppl) => format!("ppl {ppl:.4}\n"),
                Err(e) => format!("err {e}\n"),
            };
            writer.write_all(resp.as_bytes()).is_ok()
        };
        if !ok {
            break;
        }
    }
}

/// Bind the listening socket (separately from serving, so callers can learn
/// the ephemeral port before the blocking serve loop starts).
pub fn bind(addr: &str) -> Result<(TcpListener, std::net::SocketAddr)> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    Ok((listener, local))
}

/// The backend-owning loop: admission-controlled continuous-batching
/// generation interleaved with dynamically batched scoring.
///
/// Policy per iteration: drain whatever requests are queued (admission
/// happens *between* decode sweeps — that is the continuous batching),
/// flush any pending scoring batch in one backend call, then advance every
/// active generation lane by one token. When the service is idle it blocks
/// on the channel; when only scoring traffic exists, a partial batch waits
/// up to `max_wait` for company (the generation step itself provides the
/// batching window otherwise). Returns when every handle has dropped and
/// all admitted work has drained.
///
/// Scoring runs through the backend's lane 0 and resets it; the scheduler
/// therefore admits generation into the highest free lane first, and a
/// sequence that does land in lane 0 transparently re-prefills on its
/// next step (the engine checks its cached prefix against the cache fill
/// level) — mixed traffic costs some recompute but never correctness.
/// On a KV-metered backend a pending scoring batch additionally waits
/// (bounded by `SCORE_PATIENCE` steps) until enough blocks are free for
/// lane 0's full-window sweep, so an undersized arena backpressures
/// scoring the same way it backpressures generation admission.
pub fn run_engine(batcher: Batcher, be: &mut dyn Backend) {
    let cfg = batcher.cfg;
    let mut sched = GenScheduler::with_spec(be.lanes(), cfg.max_new_cap, cfg.spec);
    let mut scores: Vec<Request> = Vec::new();
    let mut inbox: Vec<Work> = Vec::new();
    let mut connected = true;
    let mut score_waited = 0usize;
    loop {
        if connected {
            if !sched.has_work() && scores.is_empty() {
                // idle: block until traffic arrives or everyone hangs up
                match batcher.recv() {
                    Some(w) => inbox.push(w),
                    None => connected = false,
                }
            }
            if connected && !batcher.drain_into(&mut inbox) {
                connected = false;
            }
            for w in inbox.drain(..) {
                match w {
                    Work::Score(r) => scores.push(r),
                    Work::Generate(g) => sched.submit(g),
                }
            }
            // scoring-only service: let a partial batch fill up briefly
            // (generation traffic ends the wait — decoding is the batching
            // window once lanes are busy)
            if connected && !sched.has_work() && !scores.is_empty() {
                connected = batcher.top_up_scores(&mut scores, |g| {
                    sched.submit(g);
                    false
                });
            }
        }
        if !connected && !sched.has_work() && scores.is_empty() {
            return;
        }
        // Scoring sweeps lane 0 over a full window, which on a metered
        // backend needs `ceil(seq / block_len)` KV blocks (lane 0's own
        // holdings are reclaimable — `nll` resets the lane first). While
        // generation holds the rest of the arena, defer the flush: every
        // decode step below can finish sequences and free blocks, so the
        // batch gets backpressure like admission does instead of a hard
        // `kv exhausted` error. The patience bound keeps a permanently
        // saturated arena from starving scoring forever.
        let scorable = !scores.is_empty()
            && (score_waited >= SCORE_PATIENCE
                || match be.kv_stats() {
                    Some(st) if sched.active() > 0 => {
                        let lane0 = st.lane_blocks.first().copied().unwrap_or(0);
                        st.free_blocks + lane0 >= blocks_for(be.seq(), st.block_len.max(1))
                    }
                    _ => true,
                });
        if scorable {
            score_waited = 0;
            let texts: Vec<Vec<u8>> = scores.iter().map(|r| r.text.clone()).collect();
            let results = score_texts(be, &texts);
            for (req, res) in scores.drain(..).zip(results) {
                let _ = req.reply.send(res);
            }
        } else if !scores.is_empty() {
            score_waited += 1;
        }
        if sched.has_work() {
            sched.step(be);
        }
    }
}

/// Serve until `max_conns` connections have been handled (forever if None).
///
/// PJRT handles are not `Send`, so the engine loop (which drives the
/// backend) runs on the *calling* thread; the accept loop and
/// per-connection readers run on spawned threads and communicate through
/// the batcher channel.
pub fn serve_on(
    listener: TcpListener,
    be: &mut dyn Backend,
    cfg: BatcherConfig,
    max_conns: Option<usize>,
) -> Result<()> {
    let (batcher, handle) = Batcher::new(cfg);
    let accept = std::thread::spawn(move || {
        let mut served = 0usize;
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    // fresh client id per connection: generation admission
                    // round-robins across clients, not raw request order
                    let h = handle.connection();
                    std::thread::spawn(move || handle_conn(s, h));
                    served += 1;
                    if let Some(max) = max_conns {
                        if served >= max {
                            break;
                        }
                    }
                }
                Err(_) => break,
            }
        }
        // `handle` drops here; the engine loop below exits once every
        // per-connection clone is gone too
    });
    run_engine(batcher, be);
    accept.join().ok();
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{NativeBackend, PackedModel};
    use crate::model::testing::micro_weights;

    fn micro_backend() -> NativeBackend {
        let w = micro_weights(33);
        NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 2, 1)
    }

    #[test]
    fn score_texts_rejects_empty_and_whitespace_input() {
        let mut be = micro_backend();
        let texts: Vec<Vec<u8>> = vec![
            b"ta kivo remo".to_vec(),
            Vec::new(),
            b"   \t ".to_vec(),
            b"so lute".to_vec(),
        ];
        let out = score_texts(&mut be, &texts);
        assert_eq!(out.len(), 4);
        assert!(out[0].as_ref().unwrap().is_finite());
        assert_eq!(out[1], Err("empty input".to_string()));
        assert_eq!(out[2], Err("empty input".to_string()));
        assert!(out[3].as_ref().unwrap().is_finite());
    }

    #[test]
    fn score_texts_skipping_empties_preserves_order_and_values() {
        // interleaved empties must not shift the scoreable texts' results
        let mut be = micro_backend();
        let a = b"ta kivo remo".to_vec();
        let b_ = b"so lute pamo".to_vec();
        let clean = score_texts(&mut be, &[a.clone(), b_.clone()]);
        let mixed = score_texts(&mut be, &[Vec::new(), a, Vec::new(), b_]);
        assert_eq!(mixed[1], clean[0]);
        assert_eq!(mixed[3], clean[1]);
    }
}
