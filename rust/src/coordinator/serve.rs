//! Line-protocol TCP scoring server over the quantized model.
//!
//! Protocol: one UTF-8 text per line in; `ppl <value>\n` out (byte-level
//! perplexity of the text under the served model), `err <msg>\n` on error.
//! Backend-generic: any [`engine::Backend`] can be served — the PJRT
//! runners or the native packed engine. The backend stays on the batcher
//! thread (xla handles are not Sync, and the native engine's KV scratch is
//! mutable state); connection handlers only exchange messages through the
//! batcher.

use super::batcher::{Batcher, BatcherConfig, BatcherHandle};
use crate::engine::Backend;
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

/// Score a batch of texts: mean NLL/byte -> perplexity per text.
pub fn score_texts(be: &mut dyn Backend, texts: &[Vec<u8>]) -> Vec<Result<f64, String>> {
    let (batch, seq) = (be.batch(), be.seq());
    let mut out = Vec::with_capacity(texts.len());
    for chunk in texts.chunks(batch) {
        let mut tokens = vec![b'\n' as i32; batch * seq];
        let mut lens = Vec::with_capacity(chunk.len());
        for (r, text) in chunk.iter().enumerate() {
            let take = text.len().min(seq);
            for (c, &b) in text[..take].iter().enumerate() {
                tokens[r * seq + c] = b as i32;
            }
            lens.push(take);
        }
        match be.nll(&tokens) {
            Ok(nll) => {
                let per_row = seq - 1;
                for (r, &len) in lens.iter().enumerate() {
                    let hi = len.saturating_sub(1).max(1).min(per_row);
                    let mean: f64 = nll[r * per_row..r * per_row + hi]
                        .iter()
                        .map(|&v| v as f64)
                        .sum::<f64>()
                        / hi as f64;
                    out.push(Ok(mean.exp()));
                }
            }
            Err(e) => {
                for _ in chunk {
                    out.push(Err(e.to_string()));
                }
            }
        }
    }
    out
}

fn handle_conn(stream: TcpStream, handle: BatcherHandle) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.is_empty() {
            continue;
        }
        let resp = match handle.score(line.as_bytes()) {
            Ok(ppl) => format!("ppl {ppl:.4}\n"),
            Err(e) => format!("err {e}\n"),
        };
        if writer.write_all(resp.as_bytes()).is_err() {
            break;
        }
    }
    let _ = peer;
}

/// Bind the listening socket (separately from serving, so callers can learn
/// the ephemeral port before the blocking serve loop starts).
pub fn bind(addr: &str) -> Result<(TcpListener, std::net::SocketAddr)> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    Ok((listener, local))
}

/// Serve until `max_conns` connections have been handled (forever if None).
///
/// PJRT handles are not `Send`, so the batcher loop (which drives the
/// backend) runs on the *calling* thread; the accept loop and
/// per-connection readers run on spawned threads and communicate through
/// the batcher channel.
pub fn serve_on(
    listener: TcpListener,
    be: &mut dyn Backend,
    cfg: BatcherConfig,
    max_conns: Option<usize>,
) -> Result<()> {
    let (batcher, handle) = Batcher::new(cfg);
    let accept = std::thread::spawn(move || {
        let mut served = 0usize;
        for stream in listener.incoming() {
            match stream {
                Ok(s) => {
                    let h = handle.clone();
                    std::thread::spawn(move || handle_conn(s, h));
                    served += 1;
                    if let Some(max) = max_conns {
                        if served >= max {
                            break;
                        }
                    }
                }
                Err(_) => break,
            }
        }
        // `handle` drops here; the batcher loop below exits once every
        // per-connection clone is gone too
    });
    batcher.run(|texts| score_texts(&mut *be, texts));
    accept.join().ok();
    Ok(())
}
