//! The serving core: the backend-owning engine loop ([`run_engine`]), the
//! front-end plumbing that feeds it ([`ClientConn`], [`FrontEnd`],
//! [`serve_fronts`]), and the line-oriented TCP protocol ([`LineConn`]).
//!
//! # TCP line protocol (full spec in `docs/API.md`)
//!
//! One UTF-8 line per request:
//!
//! * `ppl <text>` → `ppl <value>` (byte-level perplexity) or `err <msg>`.
//!   Empty / whitespace-only text is `err empty input`, never a
//!   perplexity over pad bytes.
//! * `gen <max-new> <temperature> <seed> <prompt…>` → a stream of
//!   `tok <byte>` lines (one per sampled byte, written as it is decoded),
//!   terminated by `done <n-generated>`, or `err <msg>`.
//! * `prio <interactive|batch> gen <…>` → as `gen`, admitted at the given
//!   [`Priority`] (plain `gen` is `interactive`).
//!
//! Verbs take precedence: a line is a verb iff it starts with `ppl `,
//! `gen`/`gen `, or `prio `; anything else is scored as legacy bare text
//! (the pre-verb protocol). A legacy text that itself begins with a verb
//! keyword must be sent as `ppl <text>` to be scored.
//!
//! # One engine loop, many front-ends
//!
//! Backend-generic: any [`engine::Backend`](crate::engine::Backend) can be
//! served. The backend stays on the [`run_engine`] thread (xla handles are
//! not Sync, and the native engine's KV lanes are mutable state);
//! connection handlers only exchange messages through the batcher channel.
//! A *front-end* is just a listener plus a [`ClientConn`] implementation
//! that translates its wire format into batcher work — [`LineConn`] for
//! this module's TCP protocol, [`HttpConn`](super::http::HttpConn) for
//! HTTP/SSE — so every transport shares one scheduler, one admission
//! policy, and one decode sweep ([`serve_fronts`] accepts any mix).
//! Generation is continuously batched: a [`GenScheduler`] admits queued
//! requests into free KV lanes between decode sweeps, so sequences join
//! and leave the running batch without draining it.
//!
//! KV memory is paged (`serve --kv-blocks`/`--block-len`): on a metered
//! backend admission additionally waits for enough free KV blocks, and a
//! sequence evicted mid-decode because the arena ran dry gets a single
//! `err kv exhausted` line — the sweep itself keeps running for everyone
//! else.
//!
//! Each connection (TCP or HTTP) gets its own client id
//! ([`BatcherHandle::connection`]) and generation admission runs the
//! scheduler's two-tier weighted rotation across clients, so one chatty
//! connection cannot starve the rest and batch traffic rides behind
//! interactive traffic without being starved. With `serve --spec-k N`,
//! greedy requests decode speculatively (the frequency cascade,
//! `engine::spec`) — byte-identical output, several verified tokens per
//! sweep — while sampling requests share the same lanes on the plain
//! path.

use super::batcher::{
    Batcher, BatcherConfig, BatcherHandle, ClientQueue, Request, StatsSnapshot, Work,
};
use super::metrics::ServeMetrics;
use super::scheduler::{GenEvent, GenScheduler, Priority};
use crate::engine::paged::blocks_for;
use crate::engine::Backend;
use anyhow::Result;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::Duration;

/// Decode steps a pending scoring batch waits for KV blocks before being
/// flushed anyway (each step can evict and free blocks; after this many,
/// the honest `kv exhausted` error beats further starvation).
const SCORE_PATIENCE: usize = 128;

/// How long an idle engine loop blocks per wait slice. Bounded (instead
/// of a plain blocking `recv`) so a drain request — the `drain` verb,
/// `POST /v1/drain`, or SIGTERM — wakes an idle engine promptly.
const IDLE_POLL: Duration = Duration::from_millis(50);

/// Process-wide drain latch, set by the SIGTERM handler. Distinct from
/// the per-batcher latch ([`BatcherHandle::drain`]) because a signal has
/// process semantics: every engine loop in the process observes it.
static GLOBAL_DRAIN: AtomicBool = AtomicBool::new(false);

/// Whether a process-wide drain (SIGTERM) has been requested.
pub fn global_drain_requested() -> bool {
    GLOBAL_DRAIN.load(Ordering::SeqCst)
}

/// Install a SIGTERM handler that requests a graceful drain of every
/// engine loop in this process: admission closes, queued requests get
/// `err draining`, active lanes finish, the prefix cache flushes, and
/// [`run_engine`] returns so the process can exit cleanly. Hand-rolled
/// `signal(2)` FFI — the only work in the handler is one atomic store,
/// which is async-signal-safe. No-op on non-Unix targets.
#[cfg(unix)]
pub fn install_sigterm_drain() {
    extern "C" fn on_term(_signum: i32) {
        GLOBAL_DRAIN.store(true, Ordering::SeqCst);
    }
    const SIGTERM: i32 = 15;
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    unsafe {
        signal(SIGTERM, on_term as extern "C" fn(i32) as usize);
    }
}

#[cfg(not(unix))]
pub fn install_sigterm_drain() {}

/// Score a batch of texts: mean NLL/byte → perplexity per text.
///
/// Empty and whitespace-only texts short-circuit to `Err("empty input")`
/// without occupying a batch row — their padded token rows would otherwise
/// report a "perplexity" computed over pad bytes.
pub fn score_texts(be: &mut dyn Backend, texts: &[Vec<u8>]) -> Vec<Result<f64, String>> {
    let (batch, seq) = (be.batch(), be.seq());
    let mut out: Vec<Option<Result<f64, String>>> = texts
        .iter()
        .map(|t| {
            t.iter()
                .all(|b| b.is_ascii_whitespace())
                .then(|| Err("empty input".to_string()))
        })
        .collect();
    let scoreable: Vec<usize> = (0..texts.len()).filter(|&i| out[i].is_none()).collect();
    for chunk in scoreable.chunks(batch) {
        let mut tokens = vec![b'\n' as i32; batch * seq];
        let mut lens = Vec::with_capacity(chunk.len());
        for (r, &ti) in chunk.iter().enumerate() {
            let text = &texts[ti];
            let take = text.len().min(seq);
            for (c, &b) in text[..take].iter().enumerate() {
                tokens[r * seq + c] = b as i32;
            }
            lens.push(take);
        }
        match be.nll(&tokens) {
            Ok(nll) => {
                let per_row = seq - 1;
                for (r, (&ti, &len)) in chunk.iter().zip(&lens).enumerate() {
                    let hi = len.saturating_sub(1).max(1).min(per_row);
                    let mean: f64 = nll[r * per_row..r * per_row + hi]
                        .iter()
                        .map(|&v| v as f64)
                        .sum::<f64>()
                        / hi as f64;
                    out[ti] = Some(Ok(mean.exp()));
                }
            }
            Err(e) => {
                for &ti in chunk {
                    out[ti] = Some(Err(e.to_string()));
                }
            }
        }
    }
    out.into_iter().map(|o| o.expect("every text resolved")).collect()
}

/// Stream a generation request's events back over the socket. Returns
/// `false` once the connection is unusable (the dropped receiver then
/// evicts the sequence from its KV lane at the engine's next step).
fn handle_gen(
    args: &str,
    priority: Priority,
    handle: &BatcherHandle,
    writer: &mut TcpStream,
) -> bool {
    let mut it = args.splitn(4, ' ');
    let parsed = (
        it.next().and_then(|s| s.parse::<usize>().ok()),
        it.next().and_then(|s| s.parse::<f32>().ok()),
        it.next().and_then(|s| s.parse::<u64>().ok()),
    );
    let (max_new, temperature, seed) = match parsed {
        (Some(m), Some(t), Some(s)) => (m, t, s),
        _ => {
            return writer
                .write_all(b"err usage: gen <max-new> <temperature> <seed> <prompt>\n")
                .is_ok()
        }
    };
    let prompt = it.next().unwrap_or("");
    let rx = match handle.generate(prompt.as_bytes(), max_new, temperature, seed, priority) {
        Ok(rx) => rx,
        Err(e) => return writer.write_all(format!("err {e}\n").as_bytes()).is_ok(),
    };
    for ev in rx {
        let ok = match ev {
            GenEvent::Token(b) => writer.write_all(format!("tok {b}\n").as_bytes()).is_ok(),
            GenEvent::Done { generated, .. } => {
                return writer.write_all(format!("done {generated}\n").as_bytes()).is_ok()
            }
            GenEvent::Error(e) => {
                return writer.write_all(format!("err {e}\n").as_bytes()).is_ok()
            }
        };
        if !ok {
            return false;
        }
    }
    // channel closed without a terminal event: server shutting down
    writer.write_all(b"err aborted\n").is_ok()
}

/// One accepted transport session. A front-end is a listener plus a
/// `ClientConn` implementation: the accept loop wraps each incoming
/// stream with [`ClientConn::open`] and drives [`ClientConn::run`] on its
/// own thread, with a [`BatcherHandle`] carrying that connection's fresh
/// client id. All sessions — whatever their wire format — feed the same
/// [`run_engine`] step loop through the handle, so admission fairness,
/// priorities, KV backpressure and speculative decoding behave
/// identically across transports. Implementations: [`LineConn`] (the TCP
/// line protocol) and [`HttpConn`](super::http::HttpConn) (HTTP/SSE).
pub trait ClientConn: Send + Sized + 'static {
    /// Wrap an accepted stream in this front-end's session type.
    fn open(stream: TcpStream) -> Self;
    /// Serve the session to completion (blocking; runs on its own thread).
    fn run(self, handle: BatcherHandle);
}

/// A bound listener paired with the [`ClientConn`] type its connections
/// speak, ready for [`serve_fronts`].
pub struct FrontEnd {
    listener: TcpListener,
    /// Stop accepting after this many connections (`None` = forever).
    max_conns: Option<usize>,
    spawn: fn(TcpStream, BatcherHandle),
}

impl FrontEnd {
    /// Serve `C`-sessions from `listener`, at most `max_conns` of them.
    pub fn new<C: ClientConn>(listener: TcpListener, max_conns: Option<usize>) -> FrontEnd {
        FrontEnd { listener, max_conns, spawn: |s, h| C::open(s).run(h) }
    }

    /// The TCP line-protocol front-end (`ppl`/`gen`/`prio` verbs).
    pub fn line(listener: TcpListener, max_conns: Option<usize>) -> FrontEnd {
        FrontEnd::new::<LineConn>(listener, max_conns)
    }
}

/// The line-oriented TCP session (`ppl`/`gen`/`prio` verbs plus legacy
/// bare-line scoring) — the [`ClientConn`] behind [`FrontEnd::line`] and
/// [`serve_on`]. Wire grammar in the module docs and `docs/API.md`.
pub struct LineConn {
    stream: TcpStream,
}

impl ClientConn for LineConn {
    fn open(stream: TcpStream) -> LineConn {
        LineConn { stream }
    }

    fn run(self, handle: BatcherHandle) {
        let _conn = handle.metrics().connection_guard(0); // FRONT_LABELS[0] = tcp
        let mut writer = match self.stream.try_clone() {
            Ok(w) => w,
            Err(_) => return,
        };
        let reader = BufReader::new(self.stream);
        for line in reader.lines() {
            let line = match line {
                Ok(l) => l,
                Err(_) => break,
            };
            if line.is_empty() {
                continue;
            }
            // `drain` (the whole line, no arguments): request a graceful
            // shutdown — admission closes, active lanes finish, then the
            // engine exits. Acknowledged so orchestration scripts can
            // tell the verb landed before the port goes away.
            if line == "drain" {
                handle.metrics().tcp_request("drain");
                handle.drain();
                if writer.write_all(b"ok draining\n").is_ok() {
                    continue;
                }
                break;
            }
            // `prio <level>` prefixes a gen verb with an admission tier;
            // anything else after it is a usage error (scoring has no
            // admission queue to prioritize)
            let (priority, verb) = match line.strip_prefix("prio ") {
                Some(rest) => {
                    let (level, tail) = rest.split_once(' ').unwrap_or((rest, ""));
                    match Priority::parse(level) {
                        Some(p) if tail == "gen" || tail.starts_with("gen ") => (p, tail),
                        _ => {
                            handle.metrics().tcp_request("bad");
                            let ok = writer
                                .write_all(b"err usage: prio <interactive|batch> gen <max-new> <temperature> <seed> <prompt>\n")
                                .is_ok();
                            if ok {
                                continue;
                            }
                            break;
                        }
                    }
                }
                None => (Priority::Interactive, line.as_str()),
            };
            let ok = if let Some(rest) = verb.strip_prefix("gen ") {
                handle.metrics().tcp_request("gen");
                handle_gen(rest, priority, &handle, &mut writer)
            } else if verb == "gen" {
                handle.metrics().tcp_request("gen");
                handle_gen("", priority, &handle, &mut writer)
            } else {
                // `ppl <text>`, or a legacy bare line scored as-is
                let text = match verb.strip_prefix("ppl ") {
                    Some(t) => {
                        handle.metrics().tcp_request("ppl");
                        t
                    }
                    None => {
                        handle.metrics().tcp_request("legacy");
                        verb
                    }
                };
                let resp = match handle.score(text.as_bytes()) {
                    Ok(ppl) => format!("ppl {ppl:.4}\n"),
                    Err(e) => format!("err {e}\n"),
                };
                writer.write_all(resp.as_bytes()).is_ok()
            };
            if !ok {
                break;
            }
        }
    }
}

/// Bind the listening socket (separately from serving, so callers can learn
/// the ephemeral port before the blocking serve loop starts).
pub fn bind(addr: &str) -> Result<(TcpListener, std::net::SocketAddr)> {
    let listener = TcpListener::bind(addr)?;
    let local = listener.local_addr()?;
    Ok((listener, local))
}

/// The backend-owning loop: admission-controlled continuous-batching
/// generation interleaved with dynamically batched scoring.
///
/// Policy per iteration: drain whatever requests are queued (admission
/// happens *between* decode sweeps — that is the continuous batching),
/// flush any pending scoring batch in one backend call, then advance every
/// active generation lane by one token. When the service is idle it blocks
/// on the channel; when only scoring traffic exists, a partial batch waits
/// up to `max_wait` for company (the generation step itself provides the
/// batching window otherwise). Returns when every handle has dropped and
/// all admitted work has drained.
///
/// Scoring runs through the backend's lane 0 and resets it; the scheduler
/// therefore admits generation into the highest free lane first, and a
/// sequence that does land in lane 0 transparently re-prefills on its
/// next step (the engine checks its cached prefix against the cache fill
/// level) — mixed traffic costs some recompute but never correctness.
/// On a KV-metered backend a pending scoring batch additionally waits
/// (bounded by `SCORE_PATIENCE` steps) until enough blocks are free for
/// lane 0's full-window sweep, so an undersized arena backpressures
/// scoring the same way it backpressures generation admission.
pub fn run_engine(batcher: Batcher, be: &mut dyn Backend) {
    let cfg = batcher.cfg;
    let mut sched = GenScheduler::with_spec(be.lanes(), cfg.max_new_cap, cfg.spec);
    // one metrics bundle across scheduler lifecycle events and front-end
    // request accounting — what `GET /v1/metrics` renders
    sched.set_metrics(batcher.metrics().clone());
    // one flight recorder across the scheduler's span stamps and the
    // HTTP front-end's `GET /v1/trace` (`serve --trace N`)
    sched.set_trace(batcher.trace().clone());
    // prompt prefix cache (`serve --prefix-cache N`): finished prompts
    // keep their leading KV blocks retained so later requests sharing a
    // prefix map them read-only instead of re-prefilling
    sched.set_prefix_cache(cfg.prefix_cache);
    let mut scores: Vec<Request> = Vec::new();
    let mut inbox: Vec<Work> = Vec::new();
    let mut connected = true;
    let mut score_waited = 0usize;
    let mut queue_failed = false;
    loop {
        // graceful drain (`drain` verb, `POST /v1/drain`, SIGTERM): close
        // admission, fail everything still queued, finish active lanes,
        // then fall out of the loop even while handles are alive
        let draining = batcher.is_draining() || global_drain_requested();
        if draining && !queue_failed {
            queue_failed = true;
            sched.fail_queued("draining");
        }
        if connected {
            if !sched.has_work() && scores.is_empty() && !draining {
                // idle: wait in bounded slices so a drain wakes the loop
                match batcher.recv_timeout(IDLE_POLL) {
                    Ok(w) => inbox.push(w),
                    Err(RecvTimeoutError::Timeout) => {
                        continue; // re-check the drain latch
                    }
                    Err(RecvTimeoutError::Disconnected) => connected = false,
                }
            }
            if connected && !batcher.drain_into(&mut inbox) {
                connected = false;
            }
            for w in inbox.drain(..) {
                match w {
                    Work::Score(r) => {
                        if draining {
                            let _ = r.reply.send(Err("draining".to_string()));
                        } else {
                            scores.push(r);
                        }
                    }
                    Work::Generate(g) => {
                        if draining {
                            // rejected before submit: neither `started`
                            // nor `finished` moves, so the drain
                            // invariant started == finished still holds
                            let _ = g.reply.send(GenEvent::Error("draining".to_string()));
                        } else {
                            sched.submit(g);
                        }
                    }
                    Work::Stats(tx) => {
                        let _ = tx.send(Ok(snapshot(&sched, &*be, draining)));
                    }
                }
            }
            // scoring-only service: let a partial batch fill up briefly
            // (generation traffic ends the wait — decoding is the batching
            // window once lanes are busy)
            if connected && !draining && !sched.has_work() && !scores.is_empty() {
                connected = batcher.top_up_scores(&mut scores, |w| match w {
                    Work::Generate(g) => {
                        sched.submit(g);
                        false
                    }
                    Work::Stats(tx) => {
                        let _ = tx.send(Ok(snapshot(&sched, &*be, draining)));
                        true
                    }
                    Work::Score(_) => unreachable!("scoring work is batched, never forwarded"),
                });
            }
        }
        if (!connected || draining) && !sched.has_work() && scores.is_empty() {
            break;
        }
        // Scoring sweeps lane 0 over a full window, which on a metered
        // backend needs `ceil(seq / block_len)` KV blocks (lane 0's own
        // holdings are reclaimable — `nll` resets the lane first). While
        // generation holds the rest of the arena, defer the flush: every
        // decode step below can finish sequences and free blocks, so the
        // batch gets backpressure like admission does instead of a hard
        // `kv exhausted` error. The patience bound keeps a permanently
        // saturated arena from starving scoring forever.
        let scorable = !scores.is_empty()
            && (score_waited >= SCORE_PATIENCE
                || match be.kv_stats() {
                    Some(st) if sched.active() > 0 => {
                        let lane0 = st.lane_blocks.first().copied().unwrap_or(0);
                        st.free_blocks + lane0 >= blocks_for(be.seq(), st.block_len.max(1))
                    }
                    _ => true,
                });
        if scorable {
            score_waited = 0;
            let texts: Vec<Vec<u8>> = scores.iter().map(|r| r.text.clone()).collect();
            let results = score_texts(be, &texts);
            for (req, res) in scores.drain(..).zip(results) {
                let _ = req.reply.send(res);
            }
        } else if !scores.is_empty() {
            score_waited += 1;
        }
        if sched.has_work() {
            sched.step(be);
        }
    }
    // shutdown: the prompt cache's retained blocks go back to the pool so
    // the arena drains to empty (the soak harness asserts free == total)
    sched.flush_prefix_cache(be);
}

/// The stats answer, built on the engine thread so scheduler queues and
/// backend counters are read coherently between sweeps.
fn snapshot(sched: &GenScheduler, be: &dyn Backend, draining: bool) -> StatsSnapshot {
    StatsSnapshot {
        lanes: sched.lanes(),
        active: sched.active(),
        queued: sched.queued(),
        clients: sched
            .queue_depths()
            .into_iter()
            .map(|(client, priority, depth)| ClientQueue { client, priority, depth })
            .collect(),
        kv: be.kv_stats(),
        spec: be.spec_stats(),
        draining,
    }
}

/// Accept connections from one front-end until its `max_conns` budget is
/// spent, spawning a session thread per connection. Each session gets a
/// handle with a fresh client id: generation admission rotates across
/// clients, not raw request order.
fn accept_loop(front: FrontEnd, handle: BatcherHandle, stop: Arc<AtomicBool>) {
    let mut served = 0usize;
    for stream in front.listener.incoming() {
        // checked after each accept returns: the engine's shutdown path
        // pokes the listener with a throwaway connection precisely so a
        // `max_conns: None` loop parked in `incoming()` gets here
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match stream {
            Ok(s) => {
                let h = handle.connection();
                let spawn = front.spawn;
                std::thread::spawn(move || spawn(s, h));
                served += 1;
                if let Some(max) = front.max_conns {
                    if served >= max {
                        break;
                    }
                }
            }
            Err(_) => break,
        }
    }
    // `handle` drops here; the engine loop exits once every
    // per-connection clone is gone too
}

/// Serve any mix of front-ends over one backend: every listener's
/// sessions feed the same [`run_engine`] step loop, so TCP and HTTP
/// traffic share lanes, admission fairness, and KV backpressure.
///
/// PJRT handles are not `Send`, so the engine loop (which drives the
/// backend) runs on the *calling* thread; accept loops and per-connection
/// sessions run on spawned threads and communicate through the batcher
/// channel. Returns when every front-end has exhausted its connection
/// budget and all their sessions have drained (never, for a `max_conns:
/// None` front-end). The returned [`ServeMetrics`] bundle carries the
/// run's final counters — what the CLI renders as its shutdown summary.
pub fn serve_fronts(
    fronts: Vec<FrontEnd>,
    be: &mut dyn Backend,
    cfg: BatcherConfig,
) -> Result<Arc<ServeMetrics>> {
    let (batcher, handle) = Batcher::new(cfg);
    let metrics = batcher.metrics().clone();
    let stop = Arc::new(AtomicBool::new(false));
    // recorded before the listeners move into their threads: a drain can
    // end the engine while `max_conns: None` accept loops are still
    // parked in `incoming()`, and the only portable way to unpark them
    // is a throwaway connection to their own address
    let wake_addrs: Vec<std::net::SocketAddr> =
        fronts.iter().filter_map(|f| f.listener.local_addr().ok()).collect();
    let accepts: Vec<std::thread::JoinHandle<()>> = fronts
        .into_iter()
        .map(|front| {
            let h = handle.clone();
            let s = stop.clone();
            std::thread::spawn(move || accept_loop(front, h, s))
        })
        .collect();
    drop(handle); // the engine loop's exit condition is the conn handles
    run_engine(batcher, be);
    stop.store(true, Ordering::SeqCst);
    for addr in wake_addrs {
        let _ = TcpStream::connect(addr);
    }
    for a in accepts {
        a.join().ok();
    }
    Ok(metrics)
}

/// Serve the TCP line protocol until `max_conns` connections have been
/// handled (forever if `None`) — [`serve_fronts`] with a single
/// [`FrontEnd::line`].
pub fn serve_on(
    listener: TcpListener,
    be: &mut dyn Backend,
    cfg: BatcherConfig,
    max_conns: Option<usize>,
) -> Result<Arc<ServeMetrics>> {
    serve_fronts(vec![FrontEnd::line(listener, max_conns)], be, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{NativeBackend, PackedModel};
    use crate::model::testing::micro_weights;

    fn micro_backend() -> NativeBackend {
        let w = micro_weights(33);
        NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 2, 1)
    }

    #[test]
    fn score_texts_rejects_empty_and_whitespace_input() {
        let mut be = micro_backend();
        let texts: Vec<Vec<u8>> = vec![
            b"ta kivo remo".to_vec(),
            Vec::new(),
            b"   \t ".to_vec(),
            b"so lute".to_vec(),
        ];
        let out = score_texts(&mut be, &texts);
        assert_eq!(out.len(), 4);
        assert!(out[0].as_ref().unwrap().is_finite());
        assert_eq!(out[1], Err("empty input".to_string()));
        assert_eq!(out[2], Err("empty input".to_string()));
        assert!(out[3].as_ref().unwrap().is_finite());
    }

    #[test]
    fn score_texts_skipping_empties_preserves_order_and_values() {
        // interleaved empties must not shift the scoreable texts' results
        let mut be = micro_backend();
        let a = b"ta kivo remo".to_vec();
        let b_ = b"so lute pamo".to_vec();
        let clean = score_texts(&mut be, &[a.clone(), b_.clone()]);
        let mixed = score_texts(&mut be, &[Vec::new(), a, Vec::new(), b_]);
        assert_eq!(mixed[1], clean[0]);
        assert_eq!(mixed[3], clean[1]);
    }
}
