//! First-class serving metrics (std-only, no crates): atomic counters,
//! gauges and fixed-bucket log-spaced histograms behind a
//! [`MetricsRegistry`] that renders the Prometheus text exposition
//! format, plus [`ServeMetrics`] — the typed bundle of every metric the
//! serving stack records, created once per engine and shared by `Arc`.
//!
//! # Lock discipline
//!
//! The hot path is lock-free: recording an event is one `fetch_add` on
//! an `AtomicU64` (two for a histogram's sum/count) through a
//! pre-registered handle — the per-token decode path never takes a
//! mutex. The registry's `Mutex` is touched only at *registration*
//! (startup, or the first time an HTTP status/verb combination appears)
//! and at *render* (a `GET /v1/metrics` scrape), both off the decode
//! path.
//!
//! # Exposition format
//!
//! [`MetricsRegistry::render`] emits the Prometheus text format
//! (`# HELP` / `# TYPE`, one sample per line; histograms as cumulative
//! `_bucket{le="..."}` series plus `_sum`/`_count`), with label values
//! escaped per the spec (`\\`, `\"`, `\n`). Histogram bucket bounds are
//! integers — the serving histograms record integer microseconds, so
//! sums stay exact in a `u64`.
//!
//! The metric catalog — names, labels, semantics, and the chaos-harness
//! invariants asserted over them — is documented in
//! `docs/OBSERVABILITY.md` at the repository root.

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Monotone event counter. Clones share the same underlying atomic.
#[derive(Clone)]
pub struct Counter(Arc<AtomicU64>);

impl Counter {
    pub fn new() -> Counter {
        Counter(Arc::new(AtomicU64::new(0)))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Counter {
    fn default() -> Counter {
        Counter::new()
    }
}

/// Point-in-time signed gauge. Clones share the same underlying atomic.
#[derive(Clone)]
pub struct Gauge(Arc<AtomicI64>);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(Arc::new(AtomicI64::new(0)))
    }

    #[inline]
    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    #[inline]
    pub fn add(&self, d: i64) {
        self.0.fetch_add(d, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

impl Default for Gauge {
    fn default() -> Gauge {
        Gauge::new()
    }
}

struct HistogramInner {
    /// Upper bounds (inclusive) of the finite buckets, strictly
    /// increasing; an implicit `+Inf` bucket follows.
    bounds: Vec<u64>,
    /// `bounds.len() + 1` per-bucket (non-cumulative) counts; the last
    /// entry is the `+Inf` overflow bucket.
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    count: AtomicU64,
}

/// Fixed-bucket histogram over `u64` values (the serving stack records
/// integer microseconds). Recording is lock-free: a binary search over
/// the immutable bounds plus three `fetch_add`s. Clones share state.
#[derive(Clone)]
pub struct Histogram(Arc<HistogramInner>);

impl Histogram {
    /// A histogram with the given finite bucket upper bounds (must be
    /// non-empty and strictly increasing; `+Inf` is implicit).
    pub fn with_bounds(bounds: Vec<u64>) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one finite bucket");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly increasing: {bounds:?}"
        );
        let buckets = (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect();
        Histogram(Arc::new(HistogramInner {
            bounds,
            buckets,
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }))
    }

    /// Log-spaced bounds: `start, start*factor, start*factor^2, ...`
    /// (`count` of them, saturating on overflow). The serving default
    /// `log_spaced(100, 4, 8)` spans 100 µs to ~6.5 s.
    pub fn log_spaced(start: u64, factor: u64, count: usize) -> Histogram {
        assert!(start > 0 && factor > 1 && count > 0);
        let mut bounds = Vec::with_capacity(count);
        let mut b = start;
        for _ in 0..count {
            if bounds.last().is_some_and(|&last| b <= last) {
                break; // saturated
            }
            bounds.push(b);
            b = b.saturating_mul(factor);
        }
        Histogram::with_bounds(bounds)
    }

    /// Index of the bucket `v` lands in: the first bound `>= v`, else
    /// the `+Inf` overflow bucket.
    #[inline]
    fn bucket_index(&self, v: u64) -> usize {
        self.0.bounds.partition_point(|&b| b < v)
    }

    /// Record one observation — lock-free, three `fetch_add`s.
    #[inline]
    pub fn observe(&self, v: u64) {
        let i = self.bucket_index(v);
        self.0.buckets[i].fetch_add(1, Ordering::Relaxed);
        self.0.sum.fetch_add(v, Ordering::Relaxed);
        self.0.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Record a `Duration` in integer microseconds.
    #[inline]
    pub fn observe_duration(&self, d: std::time::Duration) {
        self.observe(d.as_micros().min(u64::MAX as u128) as u64);
    }

    pub fn bounds(&self) -> &[u64] {
        &self.0.bounds
    }

    /// A consistent-enough snapshot: per-bucket (non-cumulative) counts,
    /// the value sum, and the observation count. Concurrent observers
    /// may skew `sum`/`count` by in-flight events; totals are exact once
    /// writers quiesce.
    pub fn snapshot(&self) -> (Vec<u64>, u64, u64) {
        let buckets = self.0.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        (buckets, self.0.sum.load(Ordering::Relaxed), self.0.count.load(Ordering::Relaxed))
    }

    pub fn count(&self) -> u64 {
        self.0.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.0.sum.load(Ordering::Relaxed)
    }

    /// Estimate the `q`-quantile (`q` clamped to `[0, 1]`) from the
    /// bucket counts by linear interpolation inside the containing
    /// bucket — the same estimator as Prometheus `histogram_quantile`,
    /// so a dashboard and the in-process SLO gate agree. `None` on an
    /// empty histogram.
    ///
    /// **Overflow-bucket semantics:** a rank that lands in the `+Inf`
    /// bucket reports the largest *finite* bound. The true value is
    /// unknowable above the last edge, so the estimate is a documented
    /// lower bound ("at least this"), never a fabricated larger number —
    /// an SLO asserted against it can only be *stricter* than reality.
    pub fn quantile(&self, q: f64) -> Option<f64> {
        let (buckets, _, count) = self.snapshot();
        if count == 0 {
            return None;
        }
        let rank = q.clamp(0.0, 1.0) * count as f64;
        let last_bound = *self.0.bounds.last().expect("bounds non-empty") as f64;
        let mut cum = 0u64;
        for (i, &c) in buckets.iter().enumerate() {
            let next = cum + c;
            if c > 0 && next as f64 >= rank {
                if i == self.0.bounds.len() {
                    return Some(last_bound); // +Inf bucket: clamp
                }
                let lo = if i == 0 { 0.0 } else { self.0.bounds[i - 1] as f64 };
                let hi = self.0.bounds[i] as f64;
                let frac = ((rank - cum as f64) / c as f64).clamp(0.0, 1.0);
                return Some(lo + (hi - lo) * frac);
            }
            cum = next;
        }
        // float rounding pushed the rank past every bucket: the last
        // edge is still the honest answer
        Some(last_bound)
    }
}

/// What a family's series hold; the registry keeps one kind per name.
enum Metric {
    Counter(Counter),
    Gauge(Gauge),
    Histogram(Histogram),
}

impl Metric {
    fn kind(&self) -> &'static str {
        match self {
            Metric::Counter(_) => "counter",
            Metric::Gauge(_) => "gauge",
            Metric::Histogram(_) => "histogram",
        }
    }
}

/// One labeled series inside a family.
struct Series {
    labels: Vec<(String, String)>,
    metric: Metric,
}

/// One metric family: a name, help text, and its labeled series in
/// registration order (rendering is deterministic).
struct Family {
    name: String,
    help: String,
    series: Vec<Series>,
}

/// Registry of metric families. Registration and rendering take the
/// internal mutex; the returned handles record without it.
pub struct MetricsRegistry {
    families: Mutex<Vec<Family>>,
}

/// Escape a label value per the Prometheus text format: backslash,
/// double-quote and newline.
pub fn escape_label_value(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn format_labels(labels: &[(String, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    format!("{{{}}}", inner.join(","))
}

/// Labels plus one extra pair (the histogram `le` bound).
fn format_labels_with(labels: &[(String, String)], key: &str, value: &str) -> String {
    let mut inner: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label_value(v)))
        .collect();
    inner.push(format!("{key}=\"{}\"", escape_label_value(value)));
    format!("{{{}}}", inner.join(","))
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry { families: Mutex::new(Vec::new()) }
    }

    /// Register (or fetch) the series `(name, labels)` with the metric
    /// built by `make`. Re-registration with the same name and labels
    /// returns a handle to the *existing* series — registration is
    /// idempotent, so dynamic label sets (HTTP status codes) can
    /// register on first sight. Panics if `name` already holds a
    /// different metric kind: that is a programming error that would
    /// corrupt the exposition.
    fn series(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Metric,
    ) -> Metric {
        let mut families = self.families.lock().unwrap();
        let fam = match families.iter_mut().find(|f| f.name == name) {
            Some(f) => f,
            None => {
                families.push(Family {
                    name: name.to_string(),
                    help: help.to_string(),
                    series: Vec::new(),
                });
                families.last_mut().unwrap()
            }
        };
        let owned: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        if let Some(s) = fam.series.iter().find(|s| s.labels == owned) {
            return match &s.metric {
                Metric::Counter(c) => Metric::Counter(c.clone()),
                Metric::Gauge(g) => Metric::Gauge(g.clone()),
                Metric::Histogram(h) => Metric::Histogram(h.clone()),
            };
        }
        let metric = make();
        if let Some(first) = fam.series.first() {
            assert_eq!(
                first.metric.kind(),
                metric.kind(),
                "metric {name} registered with two kinds"
            );
        }
        let handle = match &metric {
            Metric::Counter(c) => Metric::Counter(c.clone()),
            Metric::Gauge(g) => Metric::Gauge(g.clone()),
            Metric::Histogram(h) => Metric::Histogram(h.clone()),
        };
        fam.series.push(Series { labels: owned, metric });
        handle
    }

    /// Register (or fetch) a counter series.
    pub fn counter(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Counter {
        match self.series(name, help, labels, || Metric::Counter(Counter::new())) {
            Metric::Counter(c) => c,
            _ => panic!("metric {name} is not a counter"),
        }
    }

    /// Register (or fetch) a gauge series.
    pub fn gauge(&self, name: &str, help: &str, labels: &[(&str, &str)]) -> Gauge {
        match self.series(name, help, labels, || Metric::Gauge(Gauge::new())) {
            Metric::Gauge(g) => g,
            _ => panic!("metric {name} is not a gauge"),
        }
    }

    /// Register (or fetch) a histogram series with the given finite
    /// bucket bounds (ignored when the series already exists).
    pub fn histogram(
        &self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        bounds: Vec<u64>,
    ) -> Histogram {
        match self.series(name, help, labels, || Metric::Histogram(Histogram::with_bounds(bounds)))
        {
            Metric::Histogram(h) => h,
            _ => panic!("metric {name} is not a histogram"),
        }
    }

    /// Render every family in the Prometheus text exposition format.
    /// Families and series appear in registration order; histogram
    /// buckets are rendered cumulatively, ending with `le="+Inf"`.
    pub fn render(&self) -> String {
        let families = self.families.lock().unwrap();
        let mut out = String::new();
        for fam in families.iter() {
            let kind = match fam.series.first() {
                Some(s) => s.metric.kind(),
                None => continue,
            };
            out.push_str(&format!("# HELP {} {}\n", fam.name, fam.help));
            out.push_str(&format!("# TYPE {} {kind}\n", fam.name));
            for s in &fam.series {
                match &s.metric {
                    Metric::Counter(c) => {
                        out.push_str(&format!(
                            "{}{} {}\n",
                            fam.name,
                            format_labels(&s.labels),
                            c.get()
                        ));
                    }
                    Metric::Gauge(g) => {
                        out.push_str(&format!(
                            "{}{} {}\n",
                            fam.name,
                            format_labels(&s.labels),
                            g.get()
                        ));
                    }
                    Metric::Histogram(h) => {
                        let (buckets, sum, _) = h.snapshot();
                        let mut cum = 0u64;
                        for (i, &bound) in h.bounds().iter().enumerate() {
                            cum += buckets[i];
                            out.push_str(&format!(
                                "{}_bucket{} {cum}\n",
                                fam.name,
                                format_labels_with(&s.labels, "le", &bound.to_string()),
                            ));
                        }
                        cum += buckets[h.bounds().len()];
                        out.push_str(&format!(
                            "{}_bucket{} {cum}\n",
                            fam.name,
                            format_labels_with(&s.labels, "le", "+Inf"),
                        ));
                        out.push_str(&format!(
                            "{}_sum{} {sum}\n",
                            fam.name,
                            format_labels(&s.labels)
                        ));
                        // _count is the +Inf cumulative bucket by
                        // construction — rendered from the same loads so
                        // the exposition is internally consistent even
                        // mid-storm
                        out.push_str(&format!(
                            "{}_count{} {cum}\n",
                            fam.name,
                            format_labels(&s.labels)
                        ));
                    }
                }
            }
        }
        out
    }
}

impl Default for MetricsRegistry {
    fn default() -> MetricsRegistry {
        MetricsRegistry::new()
    }
}

/// Priority-tier label values, indexed by `Priority::tier()`
/// (0 = interactive, 1 = batch).
pub const TIER_LABELS: [&str; 2] = ["interactive", "batch"];

/// Terminal outcomes of an admitted generation request, indexed by
/// [`Outcome`]: `done` (budget exhausted normally), `error` (a terminal
/// `err` was sent — eviction, decode failure), `abandoned` (the client
/// disconnected mid-stream and the lane was reclaimed).
pub const OUTCOME_LABELS: [&str; 3] = ["done", "error", "abandoned"];

/// Index into [`OUTCOME_LABELS`] / `TierMetrics::finished`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    Done = 0,
    Error = 1,
    Abandoned = 2,
}

/// Eviction causes, indexed by [`EvictCause`].
pub const EVICT_LABELS: [&str; 3] = ["kv_exhausted", "client_gone", "decode_error"];

/// Index into [`EVICT_LABELS`] / `ServeMetrics::evictions`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictCause {
    KvExhausted = 0,
    ClientGone = 1,
    DecodeError = 2,
}

/// Per-priority-tier request metrics (one set per [`TIER_LABELS`] entry).
pub struct TierMetrics {
    /// Generation requests submitted at this tier.
    pub started: Counter,
    /// Terminal events by [`Outcome`] index.
    pub finished: [Counter; 3],
    /// Generated bytes streamed to clients.
    pub tokens: Counter,
    /// Submission → admission wait, µs.
    pub queue_wait_us: Histogram,
    /// Admission → first streamed token, µs.
    pub ttft_us: Histogram,
    /// Gap between consecutive streamed tokens, µs.
    pub inter_token_us: Histogram,
    /// Requests currently waiting for admission.
    pub queued: Gauge,
}

impl TierMetrics {
    fn new(reg: &MetricsRegistry, tier: &str) -> TierMetrics {
        let l = [("priority", tier)];
        TierMetrics {
            started: reg.counter(
                "hbllm_requests_started_total",
                "Generation requests submitted, by admission tier.",
                &l,
            ),
            finished: OUTCOME_LABELS.map(|o| {
                reg.counter(
                    "hbllm_requests_finished_total",
                    "Generation requests terminated, by tier and outcome.",
                    &[("priority", tier), ("outcome", o)],
                )
            }),
            tokens: reg.counter(
                "hbllm_tokens_total",
                "Generated bytes streamed to clients, by tier.",
                &l,
            ),
            queue_wait_us: reg.histogram(
                "hbllm_queue_wait_us",
                "Submission-to-admission wait in microseconds, by tier.",
                &l,
                default_latency_bounds(),
            ),
            ttft_us: reg.histogram(
                "hbllm_ttft_us",
                "Admission-to-first-token latency in microseconds, by tier.",
                &l,
                default_latency_bounds(),
            ),
            inter_token_us: reg.histogram(
                "hbllm_inter_token_us",
                "Inter-token gap in microseconds, by tier.",
                &l,
                default_latency_bounds(),
            ),
            queued: reg.gauge(
                "hbllm_queued_requests",
                "Requests waiting for admission, by tier.",
                &l,
            ),
        }
    }
}

/// The default log-spaced latency bucket bounds: 100 µs … ~6.5 s.
fn default_latency_bounds() -> Vec<u64> {
    let mut bounds = Vec::new();
    let mut b = 100u64;
    for _ in 0..8 {
        bounds.push(b);
        b = b.saturating_mul(4);
    }
    bounds
}

/// The serving stack's full metric bundle: every counter, gauge and
/// histogram the engine loop, scheduler and front-ends record, all
/// pre-registered so the decode path touches only atomics. One
/// `Arc<ServeMetrics>` is created per `Batcher` and shared by every
/// handle, connection session, and the engine loop.
pub struct ServeMetrics {
    pub registry: MetricsRegistry,
    started_at: Instant,
    /// Per-tier request metrics, indexed by `Priority::tier()`.
    pub tiers: [TierMetrics; 2],
    /// Evictions by [`EvictCause`] index.
    pub evictions: [Counter; 3],
    /// Admission stalled on KV-block backpressure, µs per stall.
    pub kv_stall_us: Histogram,
    /// Wall time of one decode sweep across all active lanes, µs.
    pub sweep_us: Histogram,
    pub spec_rounds: Counter,
    pub spec_drafted: Counter,
    pub spec_accepted: Counter,
    pub spec_rejected: Counter,
    /// Accepted draft tokens per speculative round (distribution).
    pub spec_round_accepted: Histogram,
    /// Lanes currently holding an active sequence.
    pub active_lanes: Gauge,
    pub kv_blocks_used: Gauge,
    pub kv_blocks_total: Gauge,
    /// High-water mark of concurrently allocated KV blocks.
    pub kv_blocks_used_hwm: Gauge,
    /// KV blocks currently mapped by more than one block table
    /// (prefix-sharing refcount > 1).
    pub shared_blocks: Gauge,
    /// Prompt-cache admissions that mapped a shared prefix.
    pub prefix_cache_hits: Counter,
    /// Prompt-cache admissions that found no usable prefix.
    pub prefix_cache_misses: Counter,
    /// Open client connections, indexed 0 = tcp, 1 = http.
    pub connections: [Gauge; 2],
    /// SSE generate streams that exited without a `done` event (client
    /// disconnect mid-stream, terminal error, or engine shutdown).
    /// `hbllm_http_requests_total` labels its status at header-write
    /// time — a stream dying after `200 OK` still counts as a 200 — so
    /// this counter is the only honest record of mid-stream failures.
    pub http_streams_aborted: Counter,
    /// Info-style gauge: always 1, with the selected packed-GEMV kernel
    /// (`pack::kernels::active()`) as its `kernel` label — so a
    /// deployment can tell from its metrics whether it is running the
    /// scalar, AVX2, or NEON path.
    pub kernel_info: Gauge,
}

/// Index into `ServeMetrics::connections`.
pub const FRONT_LABELS: [&str; 2] = ["tcp", "http"];

impl ServeMetrics {
    pub fn new() -> ServeMetrics {
        let reg = MetricsRegistry::new();
        let tiers = [TierMetrics::new(&reg, TIER_LABELS[0]), TierMetrics::new(&reg, TIER_LABELS[1])];
        let evictions = EVICT_LABELS.map(|c| {
            reg.counter(
                "hbllm_evictions_total",
                "Active sequences evicted from a decode lane, by cause.",
                &[("cause", c)],
            )
        });
        let kv_stall_us = reg.histogram(
            "hbllm_kv_stall_us",
            "Admission stalls on KV-block backpressure, microseconds per stall.",
            &[],
            default_latency_bounds(),
        );
        let sweep_us = reg.histogram(
            "hbllm_sweep_us",
            "Decode sweep wall time across all active lanes, microseconds.",
            &[],
            default_latency_bounds(),
        );
        let spec_rounds = reg.counter(
            "hbllm_spec_rounds_total",
            "Speculative verify rounds executed.",
            &[],
        );
        let spec_drafted = reg.counter(
            "hbllm_spec_drafted_total",
            "Draft tokens proposed by the low-band draft.",
            &[],
        );
        let spec_accepted = reg.counter(
            "hbllm_spec_accepted_total",
            "Draft tokens the full-model verifier accepted.",
            &[],
        );
        let spec_rejected = reg.counter(
            "hbllm_spec_rejected_total",
            "Draft tokens the full-model verifier rejected.",
            &[],
        );
        let spec_round_accepted = reg.histogram(
            "hbllm_spec_round_accepted",
            "Accepted draft tokens per speculative round.",
            &[],
            vec![0, 1, 2, 4, 8, 16],
        );
        let active_lanes =
            reg.gauge("hbllm_active_lanes", "Decode lanes holding an active sequence.", &[]);
        let kv_blocks_used =
            reg.gauge("hbllm_kv_blocks_used", "KV blocks currently allocated.", &[]);
        let kv_blocks_total =
            reg.gauge("hbllm_kv_blocks_total", "KV blocks in the shared arena.", &[]);
        let kv_blocks_used_hwm = reg.gauge(
            "hbllm_kv_blocks_used_hwm",
            "High-water mark of concurrently allocated KV blocks.",
            &[],
        );
        let shared_blocks = reg.gauge(
            "hbllm_shared_blocks",
            "KV blocks currently mapped by more than one block table.",
            &[],
        );
        let prefix_cache_hits = reg.counter(
            "hbllm_prefix_cache_hits_total",
            "Generation admissions that mapped a cached prompt prefix.",
            &[],
        );
        let prefix_cache_misses = reg.counter(
            "hbllm_prefix_cache_misses_total",
            "Generation admissions that found no cached prompt prefix.",
            &[],
        );
        let connections = FRONT_LABELS.map(|f| {
            reg.gauge(
                "hbllm_connections_active",
                "Open client connections, by front-end.",
                &[("front", f)],
            )
        });
        let http_streams_aborted = reg.counter(
            "hbllm_http_streams_aborted_total",
            "SSE generate streams that exited without a done event.",
            &[],
        );
        let kernel_info = reg.gauge(
            "hbllm_kernel_info",
            "Selected packed-GEMV kernel (value is always 1; the kernel label carries the name).",
            &[("kernel", crate::pack::kernels::active().name)],
        );
        kernel_info.set(1);
        ServeMetrics {
            registry: reg,
            started_at: Instant::now(),
            tiers,
            evictions,
            kv_stall_us,
            sweep_us,
            spec_rounds,
            spec_drafted,
            spec_accepted,
            spec_rejected,
            spec_round_accepted,
            active_lanes,
            kv_blocks_used,
            kv_blocks_total,
            kv_blocks_used_hwm,
            shared_blocks,
            prefix_cache_hits,
            prefix_cache_misses,
            connections,
            http_streams_aborted,
            kernel_info,
        }
    }

    /// Per-tier metrics for `Priority::tier()` index `t`.
    pub fn tier(&self, t: usize) -> &TierMetrics {
        &self.tiers[t.min(1)]
    }

    /// Record one terminal event for tier `t`.
    pub fn finish(&self, t: usize, outcome: Outcome) {
        self.tier(t).finished[outcome as usize].inc();
    }

    /// Record one eviction.
    pub fn evict(&self, cause: EvictCause) {
        self.evictions[cause as usize].inc();
    }

    /// Count one open connection on front-end `front` (index into
    /// [`FRONT_LABELS`]) for as long as the returned guard lives. RAII
    /// so every exit path of a connection loop — clean close, protocol
    /// error, panic unwind — decrements exactly once.
    pub fn connection_guard(&self, front: usize) -> GaugeGuard {
        let g = self.connections[front.min(1)].clone();
        g.add(1);
        GaugeGuard(g)
    }

    /// Account one HTTP request. Registers the (method, path, status)
    /// series on first sight — a mutex acquisition, acceptable off the
    /// decode path. Unknown paths must be collapsed by the caller (the
    /// front-end maps them to `"other"`) so scrape-cardinality stays
    /// bounded under path-scanning traffic.
    pub fn http_request(&self, method: &str, path: &str, status: u16) {
        self.registry
            .counter(
                "hbllm_http_requests_total",
                "HTTP requests served, by method, path and status.",
                &[("method", method), ("path", path), ("status", &status.to_string())],
            )
            .inc();
    }

    /// Account one TCP protocol line by verb (`ppl`, `gen`, `legacy`,
    /// `bad`).
    pub fn tcp_request(&self, verb: &str) {
        self.registry
            .counter(
                "hbllm_tcp_requests_total",
                "TCP protocol requests served, by verb.",
                &[("verb", verb)],
            )
            .inc();
    }

    /// Milliseconds since this metrics bundle (≈ the engine) started.
    pub fn uptime_ms(&self) -> u64 {
        self.started_at.elapsed().as_millis().min(u64::MAX as u128) as u64
    }

    /// Cumulative generation requests submitted, both tiers.
    pub fn requests_started(&self) -> u64 {
        self.tiers.iter().map(|t| t.started.get()).sum()
    }

    /// Cumulative terminal events, both tiers, all outcomes.
    pub fn requests_finished(&self) -> u64 {
        self.tiers.iter().flat_map(|t| t.finished.iter().map(Counter::get)).sum()
    }

    /// Cumulative generated bytes streamed, both tiers.
    pub fn tokens(&self) -> u64 {
        self.tiers.iter().map(|t| t.tokens.get()).sum()
    }

    /// Cumulative evictions, all causes.
    pub fn total_evictions(&self) -> u64 {
        self.evictions.iter().map(Counter::get).sum()
    }

    /// Render the full Prometheus exposition.
    pub fn render(&self) -> String {
        self.registry.render()
    }
}

impl Default for ServeMetrics {
    fn default() -> ServeMetrics {
        ServeMetrics::new()
    }
}

/// Holds one unit on a gauge: incremented at construction (see
/// [`ServeMetrics::connection_guard`]), decremented on drop.
pub struct GaugeGuard(Gauge);

impl Drop for GaugeGuard {
    fn drop(&mut self) {
        self.0.add(-1);
    }
}

/// The router tier's metric bundle (`coordinator::router`). Its own
/// registry, deliberately separate from any [`ServeMetrics`]: the router
/// is a different process from its workers, and its `GET /v1/metrics`
/// must describe routing decisions (placement, retries, worker liveness)
/// — worker-side engine metrics are scraped from the workers themselves.
pub struct RouterMetrics {
    pub registry: MetricsRegistry,
    /// Client requests the router accepted, indexed by [`FRONT_LABELS`]
    /// (0 = tcp, 1 = http). Counts generation and scoring requests alike
    /// — one increment per request placed, whatever its outcome.
    pub requests: [Counter; 2],
    /// Un-started requests transparently replayed on a healthy worker
    /// after a replica death (`docs/API.md` §Errors: replay only ever
    /// happens before the first output byte reaches the client).
    pub retries: Counter,
    /// Open client connections at the router, by front-end — the leak
    /// invariant the chaos harness asserts at drain.
    pub connections: [Gauge; 2],
}

impl RouterMetrics {
    pub fn new() -> RouterMetrics {
        let reg = MetricsRegistry::new();
        let requests = FRONT_LABELS.map(|f| {
            reg.counter(
                "hbllm_router_requests_total",
                "Client requests the router accepted, by front-end.",
                &[("front", f)],
            )
        });
        let retries = reg.counter(
            "hbllm_router_retries_total",
            "Un-started requests replayed on a healthy worker after a replica death.",
            &[],
        );
        let connections = FRONT_LABELS.map(|f| {
            reg.gauge(
                "hbllm_router_connections_active",
                "Open client connections at the router, by front-end.",
                &[("front", f)],
            )
        });
        RouterMetrics { registry: reg, requests, retries, connections }
    }

    /// Liveness gauge for one worker: 1 while the health loop considers
    /// it placeable (up and not draining), 0 otherwise. Registered on
    /// first sight; repeated calls return the same series — worker
    /// addresses come from the operator, not from clients, so the
    /// cardinality is bounded by fleet size.
    pub fn worker_up(&self, worker: &str) -> Gauge {
        self.registry.gauge(
            "hbllm_router_worker_up",
            "Worker liveness as the router's health loop sees it (1 = placeable).",
            &[("worker", worker)],
        )
    }

    /// Count one open router connection on front-end `front` (index into
    /// [`FRONT_LABELS`]) for as long as the returned guard lives.
    pub fn connection_guard(&self, front: usize) -> GaugeGuard {
        let g = self.connections[front.min(1)].clone();
        g.add(1);
        GaugeGuard(g)
    }

    /// Render the router's Prometheus exposition.
    pub fn render(&self) -> String {
        self.registry.render()
    }
}

impl Default for RouterMetrics {
    fn default() -> RouterMetrics {
        RouterMetrics::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn counter_and_gauge_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // clones share state
        let c2 = c.clone();
        c2.inc();
        assert_eq!(c.get(), 6);
        let g = Gauge::new();
        g.set(7);
        g.add(-3);
        assert_eq!(g.get(), 4);
    }

    #[test]
    fn log_spaced_bounds_grow_geometrically_and_saturate() {
        let h = Histogram::log_spaced(100, 4, 4);
        assert_eq!(h.bounds(), &[100, 400, 1600, 6400]);
        // near-overflow starts saturate instead of producing duplicates
        let h = Histogram::log_spaced(u64::MAX / 2, 4, 5);
        let b = h.bounds();
        assert!(b.windows(2).all(|w| w[0] < w[1]), "non-increasing: {b:?}");
    }

    #[test]
    fn histogram_bucket_boundaries_are_inclusive_upper() {
        let h = Histogram::with_bounds(vec![10, 100, 1000]);
        // a value equal to a bound lands in that bound's bucket
        for (v, want) in [(0, 0), (10, 0), (11, 1), (100, 1), (101, 2), (1000, 2), (1001, 3)] {
            assert_eq!(h.bucket_index(v), want, "value {v}");
        }
        h.observe(10);
        h.observe(11);
        h.observe(5000);
        let (buckets, sum, count) = h.snapshot();
        assert_eq!(buckets, vec![1, 1, 0, 1]);
        assert_eq!(sum, 10 + 11 + 5000);
        assert_eq!(count, 3);
    }

    #[test]
    fn histogram_merges_concurrent_observers_exactly() {
        let h = Histogram::with_bounds(vec![8, 64, 512]);
        let threads: Vec<_> = (0..4)
            .map(|t| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..1000u64 {
                        h.observe((i * 7 + t) % 600);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let (buckets, _, count) = h.snapshot();
        assert_eq!(count, 4000);
        assert_eq!(buckets.iter().sum::<u64>(), 4000, "observations lost in merge");
    }

    #[test]
    fn prop_observed_values_land_in_containing_bucket() {
        check(
            "histogram-bucket-containment",
            200,
            |g| {
                let n = g.size(1, 6);
                let mut bounds: Vec<u64> =
                    (0..n).map(|_| (g.rng.next_u64() % 100_000) + 1).collect();
                bounds.sort();
                bounds.dedup();
                let v = g.rng.next_u64() % 200_000;
                (bounds, v)
            },
            |(bounds, v)| {
                let h = Histogram::with_bounds(bounds.clone());
                h.observe(*v);
                let (buckets, sum, count) = h.snapshot();
                let i = buckets.iter().position(|&c| c == 1).ok_or("no bucket hit")?;
                if buckets.iter().sum::<u64>() != 1 || count != 1 || sum != *v {
                    return Err(format!("bad totals: {buckets:?} sum={sum} count={count}"));
                }
                // lower bound (exclusive) and upper bound (inclusive)
                // of the hit bucket must contain v
                let lo = if i == 0 { 0 } else { bounds[i - 1] };
                let hi = bounds.get(i).copied().unwrap_or(u64::MAX);
                if !(*v > lo || i == 0) || *v > hi {
                    return Err(format!("v={v} outside bucket {i} ({lo}, {hi}]"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn quantile_of_empty_histogram_is_none() {
        let h = Histogram::with_bounds(vec![10, 100]);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), None, "empty histogram answered q={q}");
        }
    }

    #[test]
    fn quantile_with_all_mass_in_overflow_clamps_to_last_finite_bound() {
        let h = Histogram::with_bounds(vec![10, 100]);
        h.observe(5_000);
        h.observe(9_000);
        // the true values are unknowable above the last edge; every
        // quantile reports the documented lower bound instead
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile(q), Some(100.0), "q={q}");
        }
    }

    #[test]
    fn quantile_interpolates_linearly_within_a_single_bucket() {
        let h = Histogram::with_bounds(vec![100, 200]);
        for _ in 0..4 {
            h.observe(150); // all mass in the (100, 200] bucket
        }
        assert_eq!(h.quantile(0.0), Some(100.0), "rank 0 sits on the lower edge");
        assert_eq!(h.quantile(0.5), Some(150.0), "midpoint of the bucket");
        assert_eq!(h.quantile(1.0), Some(200.0), "rank count sits on the upper edge");
        // out-of-range q clamps rather than extrapolating
        assert_eq!(h.quantile(-1.0), Some(100.0));
        assert_eq!(h.quantile(7.0), Some(200.0));
    }

    #[test]
    fn prop_quantiles_are_monotone_in_q_and_bounded_by_bucket_edges() {
        check(
            "histogram-quantile-monotone-bounded",
            200,
            |g| {
                let nb = g.size(1, 6);
                let mut bounds: Vec<u64> =
                    (0..nb).map(|_| (g.rng.next_u64() % 100_000) + 1).collect();
                bounds.sort();
                bounds.dedup();
                let nv = g.size(1, 32);
                let vals: Vec<u64> = (0..nv).map(|_| g.rng.next_u64() % 200_000).collect();
                (bounds, vals)
            },
            |(bounds, vals)| {
                let h = Histogram::with_bounds(bounds.clone());
                for &v in vals {
                    h.observe(v);
                }
                let last = *bounds.last().unwrap() as f64;
                let mut prev = 0.0f64;
                for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
                    let v = h.quantile(q).ok_or("non-empty histogram answered None")?;
                    if !(0.0..=last).contains(&v) {
                        return Err(format!("q={q}: {v} escapes the bucket edges [0, {last}]"));
                    }
                    if v < prev {
                        return Err(format!("not monotone at q={q}: {v} < {prev}"));
                    }
                    prev = v;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn label_values_escape_per_spec() {
        assert_eq!(escape_label_value(r#"a"b"#), r#"a\"b"#);
        assert_eq!(escape_label_value(r"a\b"), r"a\\b");
        assert_eq!(escape_label_value("a\nb"), r"a\nb");
        assert_eq!(escape_label_value("plain"), "plain");
    }

    #[test]
    fn render_matches_expected_exposition_exactly() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("hbllm_test_total", "A test counter.", &[("kind", "a\"b")]);
        c.add(3);
        let g = reg.gauge("hbllm_test_gauge", "A test gauge.", &[]);
        g.set(-2);
        let h = reg.histogram("hbllm_test_us", "A test histogram.", &[], vec![10, 100]);
        h.observe(5);
        h.observe(50);
        h.observe(50);
        h.observe(5000);
        let want = "\
# HELP hbllm_test_total A test counter.
# TYPE hbllm_test_total counter
hbllm_test_total{kind=\"a\\\"b\"} 3
# HELP hbllm_test_gauge A test gauge.
# TYPE hbllm_test_gauge gauge
hbllm_test_gauge -2
# HELP hbllm_test_us A test histogram.
# TYPE hbllm_test_us histogram
hbllm_test_us_bucket{le=\"10\"} 1
hbllm_test_us_bucket{le=\"100\"} 3
hbllm_test_us_bucket{le=\"+Inf\"} 4
hbllm_test_us_sum 5105
hbllm_test_us_count 4
";
        assert_eq!(reg.render(), want);
    }

    #[test]
    fn registration_is_idempotent_and_kind_checked() {
        let reg = MetricsRegistry::new();
        let a = reg.counter("hbllm_dup_total", "h", &[("l", "x")]);
        a.inc();
        // same name+labels returns the same series
        let b = reg.counter("hbllm_dup_total", "h", &[("l", "x")]);
        b.inc();
        assert_eq!(a.get(), 2);
        // same name, new labels is a new series in the same family
        let c = reg.counter("hbllm_dup_total", "h", &[("l", "y")]);
        c.inc();
        let text = reg.render();
        assert!(text.contains("hbllm_dup_total{l=\"x\"} 2"), "{text}");
        assert!(text.contains("hbllm_dup_total{l=\"y\"} 1"), "{text}");
        assert_eq!(text.matches("# TYPE hbllm_dup_total").count(), 1);
    }

    #[test]
    #[should_panic(expected = "two kinds")]
    fn kind_conflict_panics() {
        let reg = MetricsRegistry::new();
        let _ = reg.counter("hbllm_conflict", "h", &[("l", "x")]);
        let _ = reg.gauge("hbllm_conflict", "h", &[("l", "y")]);
    }

    #[test]
    fn serve_metrics_totals_aggregate_across_tiers() {
        let m = ServeMetrics::new();
        m.tier(0).started.inc();
        m.tier(0).started.inc();
        m.tier(1).started.inc();
        m.finish(0, Outcome::Done);
        m.finish(1, Outcome::Error);
        m.finish(1, Outcome::Abandoned);
        m.tier(0).tokens.add(10);
        m.tier(1).tokens.add(5);
        m.evict(EvictCause::KvExhausted);
        m.evict(EvictCause::ClientGone);
        assert_eq!(m.requests_started(), 3);
        assert_eq!(m.requests_finished(), 3);
        assert_eq!(m.tokens(), 15);
        assert_eq!(m.total_evictions(), 2);
        // the exposition carries every family the bundle registered
        let text = m.render();
        for needle in [
            "# TYPE hbllm_requests_started_total counter",
            "# TYPE hbllm_requests_finished_total counter",
            "# TYPE hbllm_tokens_total counter",
            "# TYPE hbllm_evictions_total counter",
            "# TYPE hbllm_queue_wait_us histogram",
            "# TYPE hbllm_ttft_us histogram",
            "# TYPE hbllm_inter_token_us histogram",
            "# TYPE hbllm_kv_stall_us histogram",
            "# TYPE hbllm_sweep_us histogram",
            "# TYPE hbllm_spec_rounds_total counter",
            "# TYPE hbllm_active_lanes gauge",
            "# TYPE hbllm_kv_blocks_used_hwm gauge",
            "# TYPE hbllm_shared_blocks gauge",
            "# TYPE hbllm_prefix_cache_hits_total counter",
            "# TYPE hbllm_prefix_cache_misses_total counter",
            "# TYPE hbllm_connections_active gauge",
            "hbllm_requests_finished_total{priority=\"batch\",outcome=\"error\"} 1",
            "hbllm_evictions_total{cause=\"kv_exhausted\"} 1",
        ] {
            assert!(text.contains(needle), "exposition lost {needle:?}:\n{text}");
        }
    }

    #[test]
    fn http_and_tcp_accounting_register_dynamic_series() {
        let m = ServeMetrics::new();
        m.http_request("GET", "/v1/stats", 200);
        m.http_request("GET", "/v1/stats", 200);
        m.http_request("POST", "/v1/generate", 400);
        m.tcp_request("ppl");
        m.tcp_request("gen");
        let text = m.render();
        assert!(
            text.contains(
                "hbllm_http_requests_total{method=\"GET\",path=\"/v1/stats\",status=\"200\"} 2"
            ),
            "{text}"
        );
        assert!(
            text.contains(
                "hbllm_http_requests_total{method=\"POST\",path=\"/v1/generate\",status=\"400\"} 1"
            ),
            "{text}"
        );
        assert!(text.contains("hbllm_tcp_requests_total{verb=\"ppl\"} 1"), "{text}");
        assert!(text.contains("hbllm_tcp_requests_total{verb=\"gen\"} 1"), "{text}");
    }

    #[test]
    fn kernel_info_exports_the_active_kernel_name() {
        let m = ServeMetrics::new();
        assert_eq!(m.kernel_info.get(), 1);
        let text = m.render();
        let needle = format!(
            "hbllm_kernel_info{{kernel=\"{}\"}} 1",
            crate::pack::kernels::active().name
        );
        assert!(text.contains(&needle), "exposition lost {needle:?}:\n{text}");
    }

    #[test]
    fn connection_guard_decrements_on_every_exit_path() {
        let m = ServeMetrics::new();
        {
            let _tcp = m.connection_guard(0);
            let _http = m.connection_guard(1);
            assert_eq!(m.connections[0].get(), 1);
            assert_eq!(m.connections[1].get(), 1);
        }
        assert_eq!(m.connections[0].get(), 0);
        assert_eq!(m.connections[1].get(), 0);
        // survives a panicking connection loop (unwind drops the guard)
        let g = m.connection_guard(1);
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
            let _held = g;
            panic!("connection loop died");
        }));
        assert_eq!(m.connections[1].get(), 0);
    }

    #[test]
    fn uptime_is_monotone_nonzero_eventually() {
        let m = ServeMetrics::new();
        let a = m.uptime_ms();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(m.uptime_ms() >= a);
    }

    #[test]
    fn router_metrics_register_and_render() {
        let m = RouterMetrics::new();
        m.requests[0].inc();
        m.requests[1].add(2);
        m.retries.inc();
        // worker_up registers per-address series idempotently
        m.worker_up("127.0.0.1:7001").set(1);
        m.worker_up("127.0.0.1:7002").set(0);
        assert_eq!(m.worker_up("127.0.0.1:7001").get(), 1, "re-lookup lost the series");
        {
            let _c = m.connection_guard(0);
            assert_eq!(m.connections[0].get(), 1);
        }
        assert_eq!(m.connections[0].get(), 0);
        let text = m.render();
        for needle in [
            "hbllm_router_requests_total{front=\"tcp\"} 1",
            "hbllm_router_requests_total{front=\"http\"} 2",
            "hbllm_router_retries_total 1",
            "hbllm_router_worker_up{worker=\"127.0.0.1:7001\"} 1",
            "hbllm_router_worker_up{worker=\"127.0.0.1:7002\"} 0",
            "hbllm_router_connections_active{front=\"tcp\"} 0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }
}
