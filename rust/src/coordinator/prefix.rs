//! Radix-trie prompt-prefix cache for prefix-sharing KV reuse.
//!
//! Chat traffic is dominated by repeated prompt prefixes (system prompts,
//! few-shot preambles). After a generation request finishes, the scheduler
//! can retain its prompt's KV blocks ([`Backend::kv_retain_prefix`]) and
//! park them here; a later admission whose prompt starts with a cached
//! prefix maps those same physical blocks read-only
//! ([`Backend::kv_adopt_prefix`]) and skips prefill for the matched
//! positions. Divergence is handled by the pool's copy-on-write path
//! ([`PagedKv::ensure_pos`]), so shared-prefix decode stays byte-identical
//! to an independent prefill (pinned by `tests/prefix_parity.rs`).
//!
//! # Ownership contract
//!
//! The cache never touches the block pool itself — it only *holds* block
//! ids whose refcounts the scheduler already bumped through the backend:
//!
//! * [`PrefixCache::insert`] takes ownership of a retained block list.
//!   Its return value is every block list the caller must now release
//!   (`kv_release_blocks`): LRU victims evicted to make room, or the
//!   offered list itself when the insert is rejected (duplicate key, or
//!   every resident entry pinned by a live mapping).
//! * [`PrefixCache::drain`] returns every remaining list the same way —
//!   the engine loop flushes the cache through it at shutdown so the
//!   arena drains to `free == total`.
//!
//! An entry mapped into a decode lane ([`PrefixCache::mark_hit`]) is
//! `live` until [`PrefixCache::release_lane`] runs for that lane; live
//! entries are never evicted, so a cached prefix cannot be dropped out
//! from under a sequence that shares its blocks (the blocks themselves
//! are also refcount-protected — this guard keeps the *cache accounting*
//! honest, e.g. hit-rate and eviction order).
//!
//! # Structure
//!
//! Keys live in a compressed radix trie over raw prompt bytes (arena of
//! nodes + free list, children keyed by first label byte), so
//! [`PrefixCache::lookup`] finds the longest cached prefix of a prompt in
//! one walk. Removal prunes emptied leaves but does not re-merge
//! pass-through interior nodes; their count is bounded by
//! `capacity × max cached prefix length`, which the small fixed
//! capacities used in serving keep negligible.
//!
//! [`Backend::kv_retain_prefix`]: crate::engine::Backend::kv_retain_prefix
//! [`Backend::kv_adopt_prefix`]: crate::engine::Backend::kv_adopt_prefix
//! [`PagedKv::ensure_pos`]: crate::engine::paged::PagedKv::ensure_pos

use std::collections::BTreeMap;

/// One node of the compressed radix trie.
struct Node {
    /// Bytes consumed stepping from the parent into this node (non-empty
    /// except at the root).
    label: Vec<u8>,
    /// Entry id if a cached prefix ends exactly here.
    entry: Option<usize>,
    /// Children keyed by the first byte of their label (at most one child
    /// per leading byte — the radix invariant).
    children: BTreeMap<u8, usize>,
}

/// Arena-allocated compressed radix trie mapping byte keys to entry ids.
struct Radix {
    /// Slot 0 is the root (empty label, never freed).
    nodes: Vec<Node>,
    free: Vec<usize>,
}

impl Radix {
    fn new() -> Radix {
        Radix {
            nodes: vec![Node { label: Vec::new(), entry: None, children: BTreeMap::new() }],
            free: Vec::new(),
        }
    }

    fn alloc(&mut self, node: Node) -> usize {
        match self.free.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        }
    }

    /// Attach `entry` at exactly `key` (non-empty, not already present —
    /// the cache checks `contains` first).
    fn insert(&mut self, key: &[u8], entry: usize) {
        let mut node = 0usize;
        let mut rest = key;
        loop {
            let Some(&first) = rest.first() else {
                debug_assert!(
                    self.nodes[node].entry.is_none(),
                    "duplicate radix insert"
                );
                self.nodes[node].entry = Some(entry);
                return;
            };
            let Some(&child) = self.nodes[node].children.get(&first) else {
                let leaf = self.alloc(Node {
                    label: rest.to_vec(),
                    entry: Some(entry),
                    children: BTreeMap::new(),
                });
                self.nodes[node].children.insert(first, leaf);
                return;
            };
            let common = rest
                .iter()
                .zip(self.nodes[child].label.iter())
                .take_while(|(a, b)| a == b)
                .count();
            if common == self.nodes[child].label.len() {
                // the whole child label is consumed; descend
                node = child;
                rest = &rest[common..];
                continue;
            }
            // split the child: it keeps the common head (>= 1 byte since
            // children are keyed by first byte), and its old content moves
            // to a new node under the diverging tail
            let tail = self.nodes[child].label.split_off(common);
            let moved = Node {
                label: tail,
                entry: self.nodes[child].entry.take(),
                children: std::mem::take(&mut self.nodes[child].children),
            };
            let moved_first = moved.label[0];
            let moved_id = self.alloc(moved);
            self.nodes[child].children.insert(moved_first, moved_id);
            node = child;
            rest = &rest[common..];
            // next iteration lands the remainder: empty -> entry on the
            // split node; non-empty -> a fresh leaf (its first byte
            // differs from `moved_first` by construction)
        }
    }

    /// Longest cached prefix of `key`: walks the trie while whole labels
    /// match, returning the deepest entry passed — `(entry id, its key
    /// length)` — or `None` when no cached key prefixes `key`.
    fn longest(&self, key: &[u8]) -> Option<(usize, usize)> {
        let mut node = 0usize;
        let mut consumed = 0usize;
        let mut best = None;
        loop {
            if let Some(e) = self.nodes[node].entry {
                best = Some((e, consumed));
            }
            let Some(&first) = key.get(consumed) else { return best };
            let Some(&child) = self.nodes[node].children.get(&first) else { return best };
            let label = &self.nodes[child].label;
            if key.len() - consumed < label.len()
                || key[consumed..consumed + label.len()] != **label
            {
                return best;
            }
            consumed += label.len();
            node = child;
        }
    }

    /// Detach the entry stored at exactly `key` (no-op when absent) and
    /// prune emptied leaves back up the path. Pass-through interior nodes
    /// are left in place (see the module docs for the size bound).
    fn remove(&mut self, key: &[u8]) {
        let mut path = vec![0usize];
        let mut consumed = 0usize;
        while consumed < key.len() {
            let node = *path.last().unwrap();
            let Some(&child) = self.nodes[node].children.get(&key[consumed]) else { return };
            let label_len = self.nodes[child].label.len();
            if key.len() - consumed < label_len
                || key[consumed..consumed + label_len] != self.nodes[child].label[..]
            {
                return;
            }
            consumed += label_len;
            path.push(child);
        }
        let last = *path.last().unwrap();
        self.nodes[last].entry = None;
        for i in (1..path.len()).rev() {
            let n = path[i];
            if self.nodes[n].entry.is_some() || !self.nodes[n].children.is_empty() {
                break;
            }
            let first = self.nodes[n].label[0];
            self.nodes[path[i - 1]].children.remove(&first);
            self.nodes[n].label = Vec::new();
            self.free.push(n);
        }
    }
}

/// One cached prompt prefix and the retained KV blocks backing it.
struct Entry {
    prefix: Vec<u8>,
    /// Block ids covering positions `0..prefix.len()`; the cache holds
    /// one refcount on each (bumped by `kv_retain_prefix` before insert).
    blocks: Vec<usize>,
    /// Logical LRU timestamp (cache clock, not wall time).
    last_used: u64,
    /// Decode lanes currently mapping this entry; > 0 pins it against
    /// eviction.
    live: usize,
}

/// LRU prompt-prefix cache over a radix trie — see the module docs for
/// the lifecycle and the block-ownership contract.
pub struct PrefixCache {
    capacity: usize,
    radix: Radix,
    entries: Vec<Option<Entry>>,
    free_ids: Vec<usize>,
    /// Logical clock bumped on every hit/insert/touch; orders LRU.
    clock: u64,
    hits: u64,
    misses: u64,
    /// lane -> entry id mapped into that lane (one at a time per lane).
    lanes: BTreeMap<usize, usize>,
}

impl PrefixCache {
    /// A cache holding at most `capacity` prefixes (0 disables inserts —
    /// every offer is handed straight back for release).
    pub fn new(capacity: usize) -> PrefixCache {
        PrefixCache {
            capacity,
            radix: Radix::new(),
            entries: Vec::new(),
            free_ids: Vec::new(),
            clock: 0,
            hits: 0,
            misses: 0,
            lanes: BTreeMap::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        self.entries.iter().filter(|e| e.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Admissions that mapped a cached prefix (counted by [`mark_hit`]).
    ///
    /// [`mark_hit`]: PrefixCache::mark_hit
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Admissions that found no usable prefix (counted by [`mark_miss`]).
    ///
    /// [`mark_miss`]: PrefixCache::mark_miss
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Whether exactly `prefix` is cached.
    pub fn contains(&self, prefix: &[u8]) -> bool {
        !prefix.is_empty()
            && self.radix.longest(prefix).is_some_and(|(_, m)| m == prefix.len())
    }

    /// Longest cached prefix usable for `prompt`, considering at most its
    /// first `limit` bytes (the scheduler passes `prompt.len() - 1` so an
    /// adoption always leaves at least one pending byte to decode).
    /// Returns `(entry id, matched positions)`. Pure — counting a hit or
    /// miss is the caller's explicit [`mark_hit`]/[`mark_miss`] call, so
    /// stalled admissions retrying every step don't inflate the counters.
    ///
    /// [`mark_hit`]: PrefixCache::mark_hit
    /// [`mark_miss`]: PrefixCache::mark_miss
    pub fn lookup(&self, prompt: &[u8], limit: usize) -> Option<(usize, usize)> {
        let limit = limit.min(prompt.len());
        let (id, matched) = self.radix.longest(&prompt[..limit])?;
        (matched > 0).then_some((id, matched))
    }

    /// The retained block list behind entry `id` (from [`lookup`]) — what
    /// the scheduler hands to `kv_adopt_prefix`.
    ///
    /// [`lookup`]: PrefixCache::lookup
    pub fn blocks(&self, id: usize) -> &[usize] {
        &self.entries[id].as_ref().expect("stale prefix-cache entry id").blocks
    }

    /// Record that `lane` adopted entry `id`: counts the hit, freshens the
    /// LRU stamp, and pins the entry against eviction until
    /// [`release_lane`](PrefixCache::release_lane).
    pub fn mark_hit(&mut self, id: usize, lane: usize) {
        self.release_lane(lane); // a lane maps at most one entry
        self.hits += 1;
        self.clock += 1;
        let e = self.entries[id].as_mut().expect("stale prefix-cache entry id");
        e.last_used = self.clock;
        e.live += 1;
        self.lanes.insert(lane, id);
    }

    /// Count one admission that adopted nothing.
    pub fn mark_miss(&mut self) {
        self.misses += 1;
    }

    /// Drop `lane`'s pin (no-op when the lane maps nothing). Call at
    /// every point a lane's sequence ends — finish, eviction, poison.
    pub fn release_lane(&mut self, lane: usize) {
        if let Some(id) = self.lanes.remove(&lane) {
            if let Some(e) = self.entries[id].as_mut() {
                e.live = e.live.saturating_sub(1);
            }
        }
    }

    /// Freshen the LRU stamp of an exactly-cached `prefix` (used instead
    /// of a duplicate insert when a finishing prompt is already cached).
    pub fn touch(&mut self, prefix: &[u8]) {
        if let Some((id, m)) = self.radix.longest(prefix) {
            if m == prefix.len() {
                self.clock += 1;
                if let Some(e) = self.entries[id].as_mut() {
                    e.last_used = self.clock;
                }
            }
        }
    }

    /// Offer a retained `(prefix, blocks)` pair. Returns every block list
    /// the caller must now release through the backend: LRU victims
    /// evicted to make room, or — when the offer is rejected (empty or
    /// duplicate key, zero capacity, or all residents pinned live) — the
    /// offered `blocks` themselves. The caller releases everything
    /// returned, unconditionally; an empty return means the insert landed
    /// and the cache kept the blocks.
    #[must_use = "returned block lists still hold refcounts and must be released"]
    pub fn insert(&mut self, prefix: Vec<u8>, blocks: Vec<usize>) -> Vec<Vec<usize>> {
        let mut out = Vec::new();
        if prefix.is_empty() || self.capacity == 0 || self.contains(&prefix) {
            out.push(blocks);
            return out;
        }
        while self.len() >= self.capacity {
            let victim = self
                .entries
                .iter()
                .enumerate()
                .filter_map(|(i, e)| e.as_ref().map(|e| (i, e)))
                .filter(|(_, e)| e.live == 0)
                .min_by_key(|(_, e)| e.last_used)
                .map(|(i, _)| i);
            match victim {
                Some(i) => out.push(self.evict(i)),
                None => {
                    // every resident entry is mapped into a live lane;
                    // rejecting keeps the never-evict-live invariant
                    out.push(blocks);
                    return out;
                }
            }
        }
        self.clock += 1;
        let entry = Entry { prefix, blocks, last_used: self.clock, live: 0 };
        let id = match self.free_ids.pop() {
            Some(i) => {
                self.entries[i] = Some(entry);
                i
            }
            None => {
                self.entries.push(Some(entry));
                self.entries.len() - 1
            }
        };
        self.radix.insert(&self.entries[id].as_ref().unwrap().prefix, id);
        out
    }

    fn evict(&mut self, id: usize) -> Vec<usize> {
        let e = self.entries[id].take().expect("evicting an empty cache slot");
        self.radix.remove(&e.prefix);
        self.free_ids.push(id);
        e.blocks
    }

    /// Empty the cache, returning every held block list for release (the
    /// engine loop flushes through this at shutdown so the arena drains
    /// to `free == total`). Live pins are discarded with the entries —
    /// the blocks a lane still maps stay protected by the lane's own
    /// refcounts, not the cache's.
    #[must_use = "returned block lists still hold refcounts and must be released"]
    pub fn drain(&mut self) -> Vec<Vec<usize>> {
        self.lanes.clear();
        let out = self.entries.iter_mut().filter_map(Option::take).map(|e| e.blocks).collect();
        self.entries.clear();
        self.free_ids.clear();
        self.radix = Radix::new();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg32;

    #[test]
    fn radix_longest_match_and_splits() {
        let mut r = Radix::new();
        r.insert(b"system: you are", 0);
        r.insert(b"system: you can", 1); // splits at "system: you "
        r.insert(b"sys", 2); // splits the shared head
        assert_eq!(r.longest(b"system: you are helpful"), Some((0, 15)));
        assert_eq!(r.longest(b"system: you can fly"), Some((1, 15)));
        // deepest entry wins, shallower entries are fallbacks
        assert_eq!(r.longest(b"system: you"), Some((2, 3)));
        assert_eq!(r.longest(b"sys"), Some((2, 3)));
        assert_eq!(r.longest(b"nothing"), None);
        r.remove(b"sys");
        assert_eq!(r.longest(b"system: you"), None);
        assert_eq!(r.longest(b"system: you are helpful"), Some((0, 15)));
    }

    #[test]
    fn lookup_clamps_to_limit_and_is_pure() {
        let mut c = PrefixCache::new(4);
        assert!(c.insert(b"hello world".to_vec(), vec![1, 2, 3]).is_empty());
        // the full prompt equals the cached key, but limit = len - 1
        // keeps one byte pending, so the match is refused
        assert_eq!(c.lookup(b"hello world", 10), None);
        assert_eq!(c.lookup(b"hello world, hi", 14), Some((0, 11)));
        assert_eq!(c.blocks(0), &[1, 2, 3]);
        // lookup counted nothing
        assert_eq!((c.hits(), c.misses()), (0, 0));
        c.mark_hit(0, 5);
        c.mark_miss();
        assert_eq!((c.hits(), c.misses()), (1, 1));
    }

    #[test]
    fn lru_evicts_least_recently_used_first() {
        let mut c = PrefixCache::new(2);
        assert!(c.insert(b"aaa".to_vec(), vec![10]).is_empty());
        assert!(c.insert(b"bbb".to_vec(), vec![20]).is_empty());
        // freshen "aaa" so "bbb" is the LRU victim
        c.touch(b"aaa");
        let evicted = c.insert(b"ccc".to_vec(), vec![30]);
        assert_eq!(evicted, vec![vec![20]]);
        assert!(c.contains(b"aaa") && c.contains(b"ccc") && !c.contains(b"bbb"));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn live_mapping_pins_entry_against_eviction() {
        let mut c = PrefixCache::new(1);
        assert!(c.insert(b"pinned".to_vec(), vec![7]).is_empty());
        let (id, m) = c.lookup(b"pinned prompt", 12).unwrap();
        assert_eq!(m, 6);
        c.mark_hit(id, 0);
        // the only resident is live: the offer comes straight back
        let rejected = c.insert(b"other".to_vec(), vec![9]);
        assert_eq!(rejected, vec![vec![9]]);
        assert!(c.contains(b"pinned"));
        // once the lane lets go, eviction works again
        c.release_lane(0);
        let evicted = c.insert(b"other".to_vec(), vec![9]);
        assert_eq!(evicted, vec![vec![7]]);
        assert!(c.contains(b"other") && !c.contains(b"pinned"));
    }

    #[test]
    fn duplicate_and_empty_inserts_are_rejected() {
        let mut c = PrefixCache::new(4);
        assert!(c.insert(b"dup".to_vec(), vec![1]).is_empty());
        assert_eq!(c.insert(b"dup".to_vec(), vec![2]), vec![vec![2]]);
        assert_eq!(c.insert(Vec::new(), vec![3]), vec![vec![3]]);
        assert_eq!(c.len(), 1);
        assert_eq!(c.blocks(0), &[1], "duplicate insert must not clobber");
        let mut off = PrefixCache::new(0);
        assert_eq!(off.insert(b"x".to_vec(), vec![4]), vec![vec![4]]);
    }

    #[test]
    fn drain_returns_every_held_block_list() {
        let mut c = PrefixCache::new(3);
        assert!(c.insert(b"a".to_vec(), vec![1, 2]).is_empty());
        assert!(c.insert(b"b".to_vec(), vec![3]).is_empty());
        c.mark_hit(c.lookup(b"ab", 1).unwrap().0, 0); // live pins don't block drain
        let mut lists = c.drain();
        lists.sort();
        assert_eq!(lists, vec![vec![1, 2], vec![3]]);
        assert!(c.is_empty());
        assert_eq!(c.lookup(b"ab", 1), None);
        // the cache is reusable after a drain
        assert!(c.insert(b"a".to_vec(), vec![5]).is_empty());
        assert_eq!(c.blocks(c.lookup(b"ab", 1).unwrap().0), &[5]);
    }

    #[test]
    fn release_of_unmapped_lane_is_a_noop() {
        let mut c = PrefixCache::new(2);
        c.release_lane(3);
        assert!(c.insert(b"k".to_vec(), vec![1]).is_empty());
        let (id, _) = c.lookup(b"kk", 1).unwrap();
        // re-hitting the same lane replaces, not stacks, the pin
        c.mark_hit(id, 0);
        c.mark_hit(id, 0);
        c.release_lane(0);
        // unpinned now: evictable
        assert_eq!(c.insert(b"l".to_vec(), vec![2]), Vec::<Vec<usize>>::new());
        assert_eq!(c.insert(b"m".to_vec(), vec![3]), vec![vec![1]]);
    }

    /// The radix trie agrees with a naive linear scan under random
    /// insert/remove interleavings.
    #[test]
    fn prop_radix_matches_linear_scan() {
        check(
            "radix-vs-linear-scan",
            200,
            |g| {
                let seed = g.rng.next_u64();
                let ops = g.size(1, 40);
                (seed, ops)
            },
            |&(seed, ops)| {
                let mut rng = Pcg32::seeded(seed);
                let mut key = |rng: &mut Pcg32| -> Vec<u8> {
                    let len = 1 + rng.below(6);
                    (0..len).map(|_| b'a' + rng.below(2) as u8).collect()
                };
                let mut radix = Radix::new();
                let mut naive: Vec<(Vec<u8>, usize)> = Vec::new();
                for op in 0..ops {
                    let k = key(&mut rng);
                    let present = naive.iter().any(|(nk, _)| *nk == k);
                    if rng.below(3) == 0 {
                        radix.remove(&k);
                        naive.retain(|(nk, _)| *nk != k);
                    } else if !present {
                        radix.insert(&k, op);
                        naive.push((k, op));
                    }
                    let q = key(&mut rng);
                    let want = naive
                        .iter()
                        .filter(|(nk, _)| q.starts_with(nk))
                        .max_by_key(|(nk, _)| nk.len())
                        .map(|(nk, id)| (*id, nk.len()));
                    let got = radix.longest(&q);
                    if got != want {
                        return Err(format!(
                            "query {q:?}: radix {got:?} != naive {want:?} (keys: {:?})",
                            naive.iter().map(|(k, _)| k.clone()).collect::<Vec<_>>()
                        ));
                    }
                }
                Ok(())
            },
        );
    }
}
