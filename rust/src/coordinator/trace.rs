//! Per-request tracing and SLO gates: the flight recorder behind
//! `serve --trace N` and `GET /v1/trace`.
//!
//! Every generation request carries an id minted at its front-end
//! ([`TraceRecorder::mint_id`], threaded through `GenRequest`); while the
//! request is resident the scheduler accumulates its span timeline —
//! enqueue, admission (with any KV-stall wait), prefix-cache adoption,
//! prefill, each decode/spec sweep, first token, finish — in a
//! [`TimelineBuilder`] owned by the engine thread. Only the *terminal*
//! event hands the completed [`Timeline`] to the [`TraceRecorder`]'s
//! bounded ring buffer, so tracing adds nothing to the per-token decode
//! path beyond an `Option` check, and `--trace 0` adds no locks or
//! allocations at all (the builder is never created).
//!
//! # Lock discipline
//!
//! The ring cursor is a lock-free `fetch_add`; each slot is guarded by
//! its own mutex, taken once per *completed request* (publication) and
//! at `GET /v1/trace` scrapes — never on the per-token path. The
//! recorder additionally pins the [`EXEMPLARS`] slowest-TTFT completed
//! traces, so a latency spike stays inspectable after the ring has
//! wrapped past it.
//!
//! # Export
//!
//! [`Timeline::to_json`] renders the `GET /v1/trace` JSON;
//! [`chrome_trace`] renders the same timelines as Chrome trace-event
//! JSON (`?format=chrome`), loadable directly in Perfetto or
//! `chrome://tracing` — one `ph: "X"` complete event per span, one `tid`
//! per request. See `docs/OBSERVABILITY.md` §Tracing.
//!
//! # SLOs
//!
//! [`SloSpec`] states the latency service levels as p99 bounds checked
//! through [`Histogram::quantile`]; the chaos harness and the CI
//! `soak-smoke` job assert them ([`SloSpec::check`]), with bounds scaled
//! by the `HBLLM_SLO_SCALE` environment variable ([`slo_scale`]) so slow
//! shared runners loosen the gates without disabling them. The
//! *structural* timeline invariants ([`Timeline::validate`]) are never
//! scaled.

use super::metrics::{Histogram, ServeMetrics};
use super::scheduler::INTERACTIVE_BURST;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Completed traces with the slowest TTFTs pinned outside the ring.
pub const EXEMPLARS: usize = 4;

/// The span catalog — one kind per request lifecycle stage (documented
/// in `docs/OBSERVABILITY.md` §Tracing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Submission → admission (the queue wait).
    Enqueue,
    /// The admission turn itself; `arg` is the KV-stall wait in µs the
    /// head of the rotation spent blocked on free blocks (0 = no stall).
    Admit,
    /// Instant: a cached prompt prefix was mapped read-only into the
    /// lane; `arg` is the matched byte count.
    PrefixAdopt,
    /// The sequence's first sweep — prompt prefill plus its first
    /// decoded byte run inside it.
    Prefill,
    /// One plain decode sweep the sequence took part in.
    Sweep,
    /// One speculative decode round; `arg` is the accepted draft count.
    SpecSweep,
    /// Instant: the first byte reached the client; `arg` is the TTFT µs.
    FirstToken,
    /// Instant terminal event; the timeline's `outcome` names it.
    Finish,
}

impl SpanKind {
    /// Wire name used by both JSON exports.
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Enqueue => "enqueue",
            SpanKind::Admit => "admit",
            SpanKind::PrefixAdopt => "prefix_adopt",
            SpanKind::Prefill => "prefill",
            SpanKind::Sweep => "sweep",
            SpanKind::SpecSweep => "spec_sweep",
            SpanKind::FirstToken => "first_token",
            SpanKind::Finish => "finish",
        }
    }
}

/// One completed span: a half-open `[start, start + dur)` interval in
/// microseconds since the recorder's epoch. Instant events have
/// `dur_us == 0`.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub kind: SpanKind,
    pub start_us: u64,
    pub dur_us: u64,
    /// Kind-specific payload — see each [`SpanKind`] variant.
    pub arg: u64,
    /// The backend's cumulative sweep counter
    /// (`Backend::sweeps_executed`) right after this span's sweep, so a
    /// scheduler-side span joins to engine-side counters; 0 for
    /// non-sweep spans.
    pub sweep: u64,
}

/// One request's completed span timeline.
#[derive(Clone, Debug)]
pub struct Timeline {
    pub id: u64,
    pub client: u64,
    /// Admission tier label (`interactive` / `batch`).
    pub priority: &'static str,
    /// Terminal outcome label (`done` / `error` / `abandoned`).
    pub outcome: &'static str,
    /// Admission → first streamed byte, when one was streamed.
    pub ttft_us: Option<u64>,
    pub spans: Vec<Span>,
}

impl Timeline {
    /// The `GET /v1/trace` JSON shape for one timeline.
    pub fn to_json(&self) -> Json {
        let spans: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(s.kind.name().to_string()));
                m.insert("start_us".to_string(), Json::Num(s.start_us as f64));
                m.insert("dur_us".to_string(), Json::Num(s.dur_us as f64));
                m.insert("arg".to_string(), Json::Num(s.arg as f64));
                m.insert("sweep".to_string(), Json::Num(s.sweep as f64));
                Json::Obj(m)
            })
            .collect();
        let mut m = BTreeMap::new();
        m.insert("id".to_string(), Json::Num(self.id as f64));
        m.insert("client".to_string(), Json::Num(self.client as f64));
        m.insert("priority".to_string(), Json::Str(self.priority.to_string()));
        m.insert("outcome".to_string(), Json::Str(self.outcome.to_string()));
        m.insert(
            "ttft_us".to_string(),
            self.ttft_us.map_or(Json::Null, |t| Json::Num(t as f64)),
        );
        m.insert("spans".to_string(), Json::Arr(spans));
        Json::Obj(m)
    }

    /// Structural invariants every well-formed timeline satisfies,
    /// asserted *unscaled* by the chaos harness on every run: spans
    /// present, `enqueue` first and `finish` last, start timestamps
    /// monotone non-decreasing, every span nested within the request
    /// lifetime, and a `first_token` span exactly when a TTFT was
    /// recorded. Returns human-readable violations (empty = valid).
    pub fn validate(&self) -> Vec<String> {
        let mut v = Vec::new();
        let Some(first) = self.spans.first() else {
            return vec![format!("req {}: timeline has no spans", self.id)];
        };
        let last = self.spans.last().expect("non-empty");
        if first.kind != SpanKind::Enqueue {
            v.push(format!("req {}: first span is {}, not enqueue", self.id, first.kind.name()));
        }
        if last.kind != SpanKind::Finish {
            v.push(format!("req {}: last span is {}, not finish", self.id, last.kind.name()));
        }
        let (lo, hi) = (first.start_us, last.start_us + last.dur_us);
        let mut prev = lo;
        for s in &self.spans {
            if s.start_us < prev {
                v.push(format!(
                    "req {}: span {} starts at {} after a span starting at {prev}",
                    self.id,
                    s.kind.name(),
                    s.start_us
                ));
            }
            prev = s.start_us;
            if s.start_us < lo || s.start_us + s.dur_us > hi {
                v.push(format!(
                    "req {}: span {} [{}, {}) escapes the request lifetime [{lo}, {hi})",
                    self.id,
                    s.kind.name(),
                    s.start_us,
                    s.start_us + s.dur_us
                ));
            }
        }
        let has_first = self.spans.iter().any(|s| s.kind == SpanKind::FirstToken);
        if has_first != self.ttft_us.is_some() {
            v.push(format!(
                "req {}: first_token span {} but ttft_us is {:?}",
                self.id,
                if has_first { "present" } else { "absent" },
                self.ttft_us
            ));
        }
        v
    }
}

/// Accumulates one request's spans while it is resident. Owned by the
/// engine thread (inside the scheduler's per-lane state): plain `Vec`
/// pushes, no sharing, no locks. Created only when tracing is enabled.
#[derive(Debug)]
pub struct TimelineBuilder {
    id: u64,
    client: u64,
    priority: &'static str,
    ttft_us: Option<u64>,
    spans: Vec<Span>,
}

impl TimelineBuilder {
    pub fn new(id: u64, client: u64, priority: &'static str) -> TimelineBuilder {
        TimelineBuilder { id, client, priority, ttft_us: None, spans: Vec::with_capacity(8) }
    }

    /// Record a completed span (`end_us >= start_us`).
    pub fn span(&mut self, kind: SpanKind, start_us: u64, end_us: u64, arg: u64, sweep: u64) {
        self.spans.push(Span {
            kind,
            start_us,
            dur_us: end_us.saturating_sub(start_us),
            arg,
            sweep,
        });
    }

    /// Record an instant event (zero duration).
    pub fn instant(&mut self, kind: SpanKind, at_us: u64, arg: u64) {
        self.span(kind, at_us, at_us, arg, 0);
    }

    /// Record the first streamed byte (stamps the TTFT).
    pub fn first_token(&mut self, at_us: u64, ttft_us: u64) {
        self.ttft_us = Some(ttft_us);
        self.instant(SpanKind::FirstToken, at_us, ttft_us);
    }

    /// Seal the timeline with its terminal event.
    pub fn finish(mut self, outcome: &'static str, at_us: u64) -> Timeline {
        self.instant(SpanKind::Finish, at_us, 0);
        Timeline {
            id: self.id,
            client: self.client,
            priority: self.priority,
            outcome,
            ttft_us: self.ttft_us,
            spans: self.spans,
        }
    }
}

/// The bounded flight recorder (`serve --trace N`): the last `capacity`
/// completed request timelines in a ring, plus the [`EXEMPLARS`]
/// slowest-TTFT completions pinned outside it. Capacity 0 disables
/// recording entirely ([`TraceRecorder::enabled`]) while ids keep being
/// minted — `req=<id>` log lines and SSE streams work untraced.
pub struct TraceRecorder {
    epoch: Instant,
    next_id: AtomicU64,
    /// Total timelines ever recorded; `cursor % capacity` is the next
    /// slot to overwrite. Lock-free.
    cursor: AtomicU64,
    slots: Vec<Mutex<Option<Arc<Timeline>>>>,
    exemplars: Mutex<Vec<Arc<Timeline>>>,
}

impl TraceRecorder {
    pub fn new(capacity: usize) -> TraceRecorder {
        TraceRecorder {
            epoch: Instant::now(),
            next_id: AtomicU64::new(0),
            cursor: AtomicU64::new(0),
            slots: (0..capacity).map(|_| Mutex::new(None)).collect(),
            exemplars: Mutex::new(Vec::new()),
        }
    }

    /// Whether completed timelines are recorded. When false, the
    /// scheduler never creates a [`TimelineBuilder`] — the decode path
    /// carries no tracing cost beyond an `Option` check.
    pub fn enabled(&self) -> bool {
        !self.slots.is_empty()
    }

    /// Mint the next request id (1-based; 0 marks an unminted request,
    /// e.g. scheduler unit tests that bypass the batcher).
    pub fn mint_id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Microseconds since the recorder's epoch — the time base every
    /// span timestamp shares.
    pub fn now_us(&self) -> u64 {
        self.us_at(Instant::now())
    }

    /// An `Instant` (e.g. an enqueue stamp taken before tracing looked
    /// at it) on the recorder's time base.
    pub fn us_at(&self, at: Instant) -> u64 {
        at.saturating_duration_since(self.epoch).as_micros().min(u64::MAX as u128) as u64
    }

    /// Publish a completed timeline: one slot overwrite in the ring
    /// (lock-free cursor, per-slot mutex) and, when its TTFT ranks among
    /// the slowest seen, a pin in the exemplar set. Never called on the
    /// per-token path — only at a request's terminal event.
    pub fn record(&self, t: Timeline) {
        if !self.enabled() {
            return;
        }
        let t = Arc::new(t);
        let i = (self.cursor.fetch_add(1, Ordering::Relaxed) as usize) % self.slots.len();
        *self.slots[i].lock().unwrap() = Some(t.clone());
        if let Some(ttft) = t.ttft_us {
            let mut ex = self.exemplars.lock().unwrap();
            let slowest_kept = ex.last().and_then(|e| e.ttft_us).unwrap_or(0);
            if ex.len() < EXEMPLARS || ttft > slowest_kept {
                ex.push(t);
                // slowest first; ties keep the earlier completion
                ex.sort_by_key(|e| std::cmp::Reverse(e.ttft_us.unwrap_or(0)));
                ex.truncate(EXEMPLARS);
            }
        }
    }

    /// The ring's resident timelines, oldest first.
    pub fn recent(&self) -> Vec<Arc<Timeline>> {
        if !self.enabled() {
            return Vec::new();
        }
        let n = self.cursor.load(Ordering::Relaxed);
        let cap = self.slots.len() as u64;
        let first = n.saturating_sub(cap);
        (first..n)
            .filter_map(|i| self.slots[(i % cap) as usize].lock().unwrap().clone())
            .collect()
    }

    /// The pinned slowest-TTFT completions, slowest first.
    pub fn exemplars(&self) -> Vec<Arc<Timeline>> {
        self.exemplars.lock().unwrap().clone()
    }

    /// The `GET /v1/trace` payload: recent timelines plus exemplars.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert(
            "recent".to_string(),
            Json::Arr(self.recent().iter().map(|t| t.to_json()).collect()),
        );
        m.insert(
            "exemplars".to_string(),
            Json::Arr(self.exemplars().iter().map(|t| t.to_json()).collect()),
        );
        Json::Obj(m)
    }
}

/// Render timelines as Chrome trace-event JSON (the `?format=chrome`
/// export): a flat array of `ph: "X"` complete events, `ts`/`dur` in
/// microseconds, one `tid` per request id — load the response body
/// as-is in Perfetto (ui.perfetto.dev) or `chrome://tracing`.
pub fn chrome_trace(timelines: &[Arc<Timeline>]) -> Json {
    let mut events = Vec::new();
    for t in timelines {
        for s in &t.spans {
            let mut args = BTreeMap::new();
            args.insert("arg".to_string(), Json::Num(s.arg as f64));
            args.insert("sweep".to_string(), Json::Num(s.sweep as f64));
            args.insert("priority".to_string(), Json::Str(t.priority.to_string()));
            args.insert("outcome".to_string(), Json::Str(t.outcome.to_string()));
            let mut e = BTreeMap::new();
            e.insert("name".to_string(), Json::Str(s.kind.name().to_string()));
            e.insert("ph".to_string(), Json::Str("X".to_string()));
            e.insert("ts".to_string(), Json::Num(s.start_us as f64));
            e.insert("dur".to_string(), Json::Num(s.dur_us as f64));
            e.insert("pid".to_string(), Json::Num(1.0));
            e.insert("tid".to_string(), Json::Num(t.id as f64));
            e.insert("args".to_string(), Json::Obj(args));
            events.push(Json::Obj(e));
        }
    }
    Json::Arr(events)
}

/// The latency SLO catalog, stated as p99 bounds in microseconds and
/// checked through [`Histogram::quantile`] (see `docs/OBSERVABILITY.md`
/// for the catalog table). The batch queue-wait bound is derived from
/// the interactive TTFT bound times `INTERACTIVE_BURST + 1`: the
/// scheduler guarantees a batch admission after at most that many
/// interactive turns, so batch waiting is bounded by the rotation
/// factor, not unbounded starvation.
#[derive(Clone, Copy, Debug)]
pub struct SloSpec {
    /// Interactive tier: p99 admission → first token, µs.
    pub interactive_p99_ttft_us: f64,
    /// Batch tier: p99 submission → admission wait, µs.
    pub batch_p99_queue_wait_us: f64,
    /// Interactive tier: p99 gap between streamed bytes, µs.
    pub interactive_p99_inter_token_us: f64,
}

impl SloSpec {
    /// An interactive-first catalog: the batch queue-wait bound follows
    /// from the TTFT bound and the admission rotation factor.
    pub fn interactive_first(p99_ttft_us: f64, p99_inter_token_us: f64) -> SloSpec {
        SloSpec {
            interactive_p99_ttft_us: p99_ttft_us,
            batch_p99_queue_wait_us: p99_ttft_us * (INTERACTIVE_BURST + 1) as f64,
            interactive_p99_inter_token_us: p99_inter_token_us,
        }
    }

    /// Every bound multiplied by `s` (runner-speed compensation).
    pub fn scaled(self, s: f64) -> SloSpec {
        SloSpec {
            interactive_p99_ttft_us: self.interactive_p99_ttft_us * s,
            batch_p99_queue_wait_us: self.batch_p99_queue_wait_us * s,
            interactive_p99_inter_token_us: self.interactive_p99_inter_token_us * s,
        }
    }

    /// Bounds scaled by the `HBLLM_SLO_SCALE` environment variable
    /// ([`slo_scale`]) — how CI loosens the gates on slow shared runners
    /// without disabling them.
    pub fn from_env(self) -> SloSpec {
        self.scaled(slo_scale())
    }

    /// Check every SLO against a metrics bundle's histograms. Returns
    /// human-readable violations; empty means all gates hold. A
    /// histogram with no observations passes vacuously (no traffic at a
    /// tier is not a latency regression).
    pub fn check(&self, m: &ServeMetrics) -> Vec<String> {
        let mut v = Vec::new();
        let mut gate = |name: &str, h: &Histogram, bound: f64| {
            if let Some(p99) = h.quantile(0.99) {
                if p99 > bound {
                    v.push(format!(
                        "{name}: p99 {p99:.0}µs exceeds the SLO bound {bound:.0}µs"
                    ));
                }
            }
        };
        gate("interactive ttft", &m.tier(0).ttft_us, self.interactive_p99_ttft_us);
        gate("batch queue_wait", &m.tier(1).queue_wait_us, self.batch_p99_queue_wait_us);
        gate(
            "interactive inter_token",
            &m.tier(0).inter_token_us,
            self.interactive_p99_inter_token_us,
        );
        v
    }
}

/// The `HBLLM_SLO_SCALE` multiplier: a positive float loosening (>1) or
/// tightening (<1) every [`SloSpec`] bound; unset, unparsable or
/// non-positive values mean 1.0.
pub fn slo_scale() -> f64 {
    std::env::var("HBLLM_SLO_SCALE")
        .ok()
        .and_then(|s| s.trim().parse::<f64>().ok())
        .filter(|s| *s > 0.0 && s.is_finite())
        .unwrap_or(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tl(id: u64, ttft: u64) -> Timeline {
        let mut b = TimelineBuilder::new(id, 0, "interactive");
        b.span(SpanKind::Enqueue, 0, 10, 0, 0);
        b.span(SpanKind::Admit, 10, 10, 0, 0);
        b.span(SpanKind::Prefill, 12, 20, 1, 1);
        b.first_token(20, ttft);
        b.finish("done", 30)
    }

    #[test]
    fn ids_are_monotone_and_start_at_one() {
        let r = TraceRecorder::new(0);
        assert_eq!(r.mint_id(), 1);
        assert_eq!(r.mint_id(), 2);
        assert!(!r.enabled(), "capacity 0 must disable recording");
        r.record(tl(1, 5)); // silently dropped
        assert!(r.recent().is_empty());
        assert!(r.exemplars().is_empty());
    }

    #[test]
    fn ring_keeps_the_last_capacity_timelines_in_order() {
        let r = TraceRecorder::new(3);
        for id in 1..=5 {
            r.record(tl(id, 100));
        }
        let ids: Vec<u64> = r.recent().iter().map(|t| t.id).collect();
        assert_eq!(ids, vec![3, 4, 5], "ring must hold the newest 3, oldest first");
    }

    #[test]
    fn exemplars_pin_the_slowest_ttfts() {
        let r = TraceRecorder::new(2);
        for (id, ttft) in [(1, 50), (2, 900), (3, 10), (4, 700), (5, 30), (6, 800), (7, 20)] {
            r.record(tl(id, ttft));
        }
        let ex: Vec<(u64, Option<u64>)> = r.exemplars().iter().map(|t| (t.id, t.ttft_us)).collect();
        // EXEMPLARS = 4 slowest, slowest first — the ring only holds the
        // last 2 but the 900µs spike from id 2 stays pinned
        assert_eq!(ex, vec![(2, Some(900)), (6, Some(800)), (4, Some(700)), (1, Some(50))]);
    }

    #[test]
    fn well_formed_timeline_validates_clean() {
        let t = tl(7, 8);
        assert!(t.validate().is_empty(), "{:?}", t.validate());
        assert_eq!(t.spans.first().unwrap().kind, SpanKind::Enqueue);
        assert_eq!(t.spans.last().unwrap().kind, SpanKind::Finish);
        assert_eq!(t.ttft_us, Some(8));
    }

    #[test]
    fn validate_catches_structural_violations() {
        // out-of-order start timestamps
        let mut b = TimelineBuilder::new(1, 0, "batch");
        b.span(SpanKind::Enqueue, 0, 10, 0, 0);
        b.span(SpanKind::Sweep, 5, 8, 0, 0); // goes backwards
        let t = b.finish("done", 30);
        assert!(t.validate().iter().any(|v| v.contains("after a span")), "{:?}", t.validate());
        // ttft recorded without a first_token span
        let t = Timeline {
            id: 2,
            client: 0,
            priority: "interactive",
            outcome: "done",
            ttft_us: Some(9),
            spans: vec![
                Span { kind: SpanKind::Enqueue, start_us: 0, dur_us: 1, arg: 0, sweep: 0 },
                Span { kind: SpanKind::Finish, start_us: 2, dur_us: 0, arg: 0, sweep: 0 },
            ],
        };
        assert!(t.validate().iter().any(|v| v.contains("first_token")), "{:?}", t.validate());
        // empty timeline
        let t = Timeline {
            id: 3,
            client: 0,
            priority: "interactive",
            outcome: "error",
            ttft_us: None,
            spans: Vec::new(),
        };
        assert_eq!(t.validate().len(), 1);
    }

    #[test]
    fn json_exports_parse_and_carry_the_span_catalog() {
        let r = TraceRecorder::new(4);
        r.record(tl(1, 5));
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        let recent = j.get("recent").and_then(Json::as_arr).unwrap();
        assert_eq!(recent.len(), 1);
        let spans = recent[0].get("spans").and_then(Json::as_arr).unwrap();
        let names: Vec<&str> =
            spans.iter().filter_map(|s| s.get("name").and_then(Json::as_str)).collect();
        assert_eq!(names, vec!["enqueue", "admit", "prefill", "first_token", "finish"]);
        assert_eq!(recent[0].at(&["ttft_us"]).and_then(Json::as_f64), Some(5.0));
        // chrome trace: flat array of complete events on the same base
        let c = Json::parse(&chrome_trace(&r.recent()).to_string()).unwrap();
        let events = c.as_arr().unwrap();
        assert_eq!(events.len(), 5);
        for e in events {
            assert_eq!(e.get("ph").and_then(Json::as_str), Some("X"));
            assert_eq!(e.get("tid").and_then(Json::as_f64), Some(1.0));
            assert!(e.get("ts").and_then(Json::as_f64).is_some());
            assert!(e.get("dur").and_then(Json::as_f64).is_some());
        }
    }

    #[test]
    fn slo_check_flags_only_violated_gates() {
        let m = ServeMetrics::new();
        for _ in 0..100 {
            m.tier(0).ttft_us.observe(150); // lands in (100, 400]
            m.tier(1).queue_wait_us.observe(150);
        }
        // generous bounds: everything passes (inter-token is empty and
        // passes vacuously)
        let ok = SloSpec::interactive_first(1_000_000.0, 1_000_000.0);
        assert!(ok.check(&m).is_empty(), "{:?}", ok.check(&m));
        // a 1µs TTFT bound must trip exactly the ttft gate, and the
        // derived batch bound (4µs) the queue-wait gate
        let tight = SloSpec::interactive_first(1.0, 1_000_000.0);
        let violations = tight.check(&m);
        assert_eq!(violations.len(), 2, "{violations:?}");
        assert!(violations[0].contains("interactive ttft"), "{violations:?}");
        assert!(violations[1].contains("batch queue_wait"), "{violations:?}");
    }

    #[test]
    fn slo_scaling_multiplies_every_bound() {
        let s = SloSpec::interactive_first(100.0, 10.0).scaled(3.0);
        assert_eq!(s.interactive_p99_ttft_us, 300.0);
        assert_eq!(s.batch_p99_queue_wait_us, 300.0 * (INTERACTIVE_BURST + 1) as f64);
        assert_eq!(s.interactive_p99_inter_token_us, 30.0);
        // unset env means identity scale
        assert!(slo_scale() > 0.0);
    }
}
