//! L3 coordination: quantization job scheduling across worker threads,
//! request batching, and the generation + scoring server.
//!
//! The paper's contribution is the quantization algorithm itself, so the
//! coordinator's job is (a) driving per-layer PTQ with deterministic
//! parallelism (Table 3's wall-clock), and (b) serving the quantized model
//! — batched perplexity scoring *and* admission-controlled
//! continuous-batching generation over the engine's KV lanes (the
//! deployment story in §3.6/§4.5).
//!
//! Serving is split into one backend-owning engine loop
//! ([`serve::run_engine`]) and pluggable transports
//! ([`serve::ClientConn`]): the line-oriented TCP protocol
//! ([`serve::LineConn`]) and the HTTP/SSE front-end ([`http::HttpConn`])
//! feed the same [`GenScheduler`] — one admission policy (two-tier
//! [`Priority`] rotation, per-client fairness, KV backpressure) whatever
//! the wire format. Every lifecycle event (admission, token, eviction,
//! HTTP/TCP request) is recorded into one shared [`ServeMetrics`] bundle
//! ([`metrics`]) exposed as a Prometheus text endpoint
//! (`GET /v1/metrics`) — see `docs/OBSERVABILITY.md`. The complete
//! serving API (verbs, endpoints, SSE grammar, errors, priorities) is
//! specified in `docs/API.md`; the request lifecycle is walked through
//! in `docs/ARCHITECTURE.md`.
//!
//! Multi-replica deployments put the [`router`] tier in front: a
//! separate process speaking the same client protocols, fanning requests
//! out to N `serve` worker processes with sticky prompt-prefix placement
//! and transparent replay on replica death (`docs/ARCHITECTURE.md`
//! §Router tier, pinned by `tests/router_failover.rs`).

pub mod batcher;
pub mod http;
pub mod metrics;
pub mod prefix;
pub mod progress;
pub mod router;
pub mod scheduler;
pub mod serve;
pub mod trace;

pub use batcher::{Batcher, BatcherConfig, BatcherHandle, ClientQueue, StatsSnapshot, Work};
pub use metrics::{MetricsRegistry, RouterMetrics, ServeMetrics};
pub use router::{prefix_hash, rendezvous_pick, run_router, RouterConfig};
pub use progress::Progress;
pub use scheduler::{
    quantize_model, GenEvent, GenRequest, GenScheduler, LayerResult, Priority, QuantJobConfig,
};
pub use trace::{SloSpec, Timeline, TraceRecorder};
