//! L3 coordination: quantization job scheduling across worker threads,
//! request batching, and the generation + scoring server.
//!
//! The paper's contribution is the quantization algorithm itself, so the
//! coordinator's job is (a) driving per-layer PTQ with deterministic
//! parallelism (Table 3's wall-clock), and (b) serving the quantized model
//! — batched perplexity scoring *and* admission-controlled
//! continuous-batching generation over the engine's KV lanes (the
//! deployment story in §3.6/§4.5). See `README.md` §Serving for the wire
//! protocol.

pub mod batcher;
pub mod progress;
pub mod scheduler;
pub mod serve;

pub use batcher::{Batcher, BatcherConfig, BatcherHandle, Work};
pub use progress::Progress;
pub use scheduler::{
    quantize_model, GenEvent, GenRequest, GenScheduler, LayerResult, QuantJobConfig,
};
