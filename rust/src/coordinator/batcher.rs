//! Dynamic request batcher: collect scoring requests up to `max_batch` or
//! `max_wait`, then flush to the scorer in one PJRT call. Generic over the
//! scoring function so it is testable without a PJRT runtime.

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(20) }
    }
}

pub struct Request {
    pub text: Vec<u8>,
    pub reply: Sender<Result<f64, String>>,
}

/// The batcher owns the receive side; the scorer closure owns the model
/// runtime (PJRT types are not Sync, so scoring stays on this thread).
pub struct Batcher {
    pub cfg: BatcherConfig,
    rx: Receiver<Request>,
}

#[derive(Clone)]
pub struct BatcherHandle {
    tx: Sender<Request>,
}

impl BatcherHandle {
    /// Blocking score call: mean NLL/byte for `text`.
    pub fn score(&self, text: &[u8]) -> Result<f64, String> {
        let (tx, rx) = channel();
        self.tx
            .send(Request { text: text.to_vec(), reply: tx })
            .map_err(|_| "batcher gone".to_string())?;
        rx.recv().map_err(|_| "batcher dropped request".to_string())?
    }
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> (Batcher, BatcherHandle) {
        let (tx, rx) = channel();
        (Batcher { cfg, rx }, BatcherHandle { tx })
    }

    /// Run the batch loop until all senders hang up. `score_batch` maps a
    /// slice of texts to one score per text.
    pub fn run(self, mut score_batch: impl FnMut(&[Vec<u8>]) -> Vec<Result<f64, String>>) {
        let mut pending: Vec<Request> = Vec::new();
        loop {
            // wait for the first request of a batch
            if pending.is_empty() {
                match self.rx.recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => return, // all senders dropped
                }
            }
            // top up until full or the wait budget expires
            let deadline = Instant::now() + self.cfg.max_wait;
            while pending.len() < self.cfg.max_batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match self.rx.recv_timeout(deadline - now) {
                    Ok(r) => pending.push(r),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
            let texts: Vec<Vec<u8>> = pending.iter().map(|r| r.text.clone()).collect();
            let scores = score_batch(&texts);
            debug_assert_eq!(scores.len(), texts.len());
            for (req, score) in pending.drain(..).zip(scores) {
                let _ = req.reply.send(score);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn batches_up_to_max() {
        let (batcher, handle) = Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(200),
        });
        let max_seen = Arc::new(AtomicUsize::new(0));
        let ms = max_seen.clone();
        let worker = std::thread::spawn(move || {
            batcher.run(move |texts| {
                ms.fetch_max(texts.len(), Ordering::Relaxed);
                texts.iter().map(|t| Ok(t.len() as f64)).collect()
            });
        });
        std::thread::scope(|s| {
            for i in 0..8 {
                let h = handle.clone();
                s.spawn(move || {
                    let text = vec![b'x'; i + 1];
                    assert_eq!(h.score(&text).unwrap(), (i + 1) as f64);
                });
            }
        });
        drop(handle);
        worker.join().unwrap();
        let seen = max_seen.load(Ordering::Relaxed);
        assert!(seen >= 2, "never batched: max batch seen {seen}");
        assert!(seen <= 4, "exceeded max_batch: {seen}");
    }

    #[test]
    fn flushes_partial_batch_on_timeout() {
        let (batcher, handle) = Batcher::new(BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(10),
        });
        let worker = std::thread::spawn(move || {
            batcher.run(|texts| texts.iter().map(|_| Ok(1.0)).collect());
        });
        let t0 = Instant::now();
        assert_eq!(handle.score(b"solo").unwrap(), 1.0);
        assert!(t0.elapsed() < Duration::from_secs(1), "timeout flush too slow");
        drop(handle);
        worker.join().unwrap();
    }

    #[test]
    fn propagates_errors() {
        let (batcher, handle) = Batcher::new(BatcherConfig::default());
        let worker = std::thread::spawn(move || {
            batcher.run(|texts| texts.iter().map(|_| Err("boom".to_string())).collect());
        });
        assert_eq!(handle.score(b"x"), Err("boom".to_string()));
        drop(handle);
        worker.join().unwrap();
    }
}
