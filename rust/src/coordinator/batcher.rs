//! Request plumbing between connection handlers and the thread that owns
//! the model backend.
//!
//! Three request kinds flow through one channel: **scoring** (collect up
//! to `max_batch` texts or wait `max_wait`, then flush in one backend
//! call), **generation** (handed to the continuous-batching
//! `GenScheduler`, which streams `GenEvent`s back per request and, on
//! KV-metered backends, holds requests in its queue until enough paged-KV
//! blocks are free — the channel itself never applies backpressure), and
//! **stats** (a [`StatsSnapshot`] of scheduler queues + backend KV/spec
//! counters, answered between sweeps — the `GET /v1/stats` payload). The
//! backend-owning side is generic: [`Batcher::run`] drives a scoring-only
//! closure (testable without any model runtime), while
//! `coordinator::serve::run_engine` interleaves scoring batches with
//! generation steps on the real backend.
//!
//! The channel is **front-end agnostic**: the line-oriented TCP protocol
//! and the HTTP/SSE front-end (`coordinator::http`) both talk to the one
//! engine loop through [`BatcherHandle`]s — see
//! [`ClientConn`](super::serve::ClientConn).

use super::metrics::ServeMetrics;
use super::scheduler::{GenEvent, GenRequest, Priority};
use super::trace::TraceRecorder;
use crate::engine::{KvStats, SpecConfig, SpecStats};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// Scoring batch size cap (one backend `nll` call per flush).
    pub max_batch: usize,
    /// How long a partial scoring batch waits for company before flushing.
    pub max_wait: Duration,
    /// Admission-control cap on any single generation request's `max_new`.
    pub max_new_cap: usize,
    /// Speculative decoding for greedy generation (`serve --spec-k`).
    /// Pass the *effective* config `Backend::set_spec` returned so the
    /// scheduler and backend agree; the default is disabled.
    pub spec: SpecConfig,
    /// Prompt prefix-cache capacity in entries (`serve --prefix-cache`):
    /// finished prompts keep their leading KV blocks resident so later
    /// requests sharing the prefix map them read-only instead of
    /// re-prefilling. `0` disables caching (the default); it only takes
    /// effect on KV-metered backends that support block sharing.
    pub prefix_cache: usize,
    /// Flight-recorder capacity in completed request timelines
    /// (`serve --trace N`): the last `N` finished requests keep their
    /// span timelines for `GET /v1/trace`. `0` disables recording (the
    /// default) — request ids are still minted, but the decode path
    /// never builds a timeline.
    pub trace: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_millis(20),
            max_new_cap: 256,
            spec: SpecConfig::disabled(),
            prefix_cache: 0,
            trace: 0,
        }
    }
}

/// A scoring request: mean NLL/byte → perplexity for one text.
pub struct Request {
    pub text: Vec<u8>,
    pub reply: Sender<Result<f64, String>>,
}

/// Point-in-time service snapshot, answered by the backend-owning loop so
/// scheduler queues and backend counters are read coherently between
/// sweeps. Serialized as JSON by the HTTP front-end's `GET /v1/stats`
/// (`docs/API.md`).
#[derive(Clone, Debug, Default)]
pub struct StatsSnapshot {
    /// KV decode lanes the backend hosts.
    pub lanes: usize,
    /// Sequences currently resident in lanes.
    pub active: usize,
    /// Generation requests waiting for admission (both priority tiers).
    pub queued: usize,
    /// Per-(client, priority) pending queue depths, interactive tier
    /// first, clients ascending.
    pub clients: Vec<ClientQueue>,
    /// Paged-KV occupancy (`None` on unmetered backends).
    pub kv: Option<KvStats>,
    /// Speculative-decoding counters (`None` without a draft path).
    pub spec: Option<SpecStats>,
    /// The server is draining: admission is closed, active lanes are
    /// finishing, and the process exits once they do. A router's health
    /// check reads this to stop placing work here before the port goes
    /// away (`docs/ARCHITECTURE.md` §Router tier).
    pub draining: bool,
}

/// One client's pending generation queue in a [`StatsSnapshot`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ClientQueue {
    pub client: u64,
    pub priority: Priority,
    pub depth: usize,
}

/// One unit of work for the backend-owning thread.
pub enum Work {
    Score(Request),
    Generate(GenRequest),
    /// Answer with a [`StatsSnapshot`] at the next loop turn — or an
    /// error when the answering loop has no engine behind it (the
    /// scoring-only [`Batcher::run`] loop), which the HTTP front-end
    /// surfaces as a 503 rather than a fabricated all-zero snapshot.
    Stats(Sender<Result<StatsSnapshot, String>>),
}

/// The batcher owns the receive side; the scorer closure / engine loop
/// owns the model runtime (PJRT types are not Sync, so backend execution
/// stays on one thread).
pub struct Batcher {
    pub cfg: BatcherConfig,
    rx: Receiver<Work>,
    /// The serving metrics bundle shared with every [`BatcherHandle`];
    /// the engine loop records lifecycle events into it and the HTTP
    /// front-end renders it at `GET /v1/metrics`.
    metrics: Arc<ServeMetrics>,
    /// The trace flight recorder shared with every handle; request ids
    /// are minted from it and the engine loop publishes completed span
    /// timelines into it (`GET /v1/trace`).
    trace: Arc<TraceRecorder>,
    /// Graceful-drain latch shared with every handle: once set (the
    /// `drain` TCP verb, `POST /v1/drain`, or SIGTERM), the engine loop
    /// fails queued requests, rejects new admissions, finishes active
    /// lanes, flushes the prefix cache, and exits.
    draining: Arc<AtomicBool>,
}

/// Cloning a handle keeps its client identity (`clone` = same caller);
/// [`BatcherHandle::connection`] mints a handle with a fresh client id —
/// the serve accept loop calls it per TCP connection so the generation
/// scheduler can round-robin admission across clients.
#[derive(Clone)]
pub struct BatcherHandle {
    tx: Sender<Work>,
    /// Client identity attached to generation requests from this handle.
    client: u64,
    next_client: Arc<AtomicU64>,
    metrics: Arc<ServeMetrics>,
    trace: Arc<TraceRecorder>,
    draining: Arc<AtomicBool>,
}

impl BatcherHandle {
    /// A handle carrying a fresh client id (same underlying channel).
    pub fn connection(&self) -> BatcherHandle {
        BatcherHandle {
            tx: self.tx.clone(),
            client: self.next_client.fetch_add(1, Ordering::Relaxed),
            next_client: self.next_client.clone(),
            metrics: self.metrics.clone(),
            trace: self.trace.clone(),
            draining: self.draining.clone(),
        }
    }

    /// Begin a graceful drain: admission closes, active lanes finish,
    /// the prefix cache is flushed, and the engine loop exits. Idempotent
    /// — the latch only ever goes one way.
    pub fn drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    /// Whether a drain has been requested on this batcher.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// The serving metrics bundle every handle to this batcher shares.
    /// Front-ends record request/connection accounting into it; the HTTP
    /// front-end renders it as Prometheus text at `GET /v1/metrics`.
    pub fn metrics(&self) -> &Arc<ServeMetrics> {
        &self.metrics
    }

    /// The client id this handle stamps on generation requests.
    pub fn client(&self) -> u64 {
        self.client
    }

    /// The trace flight recorder every handle to this batcher shares —
    /// the HTTP front-end serves it at `GET /v1/trace`.
    pub fn trace(&self) -> &Arc<TraceRecorder> {
        &self.trace
    }

    /// Blocking score call: perplexity (exp mean NLL/byte) for `text`.
    pub fn score(&self, text: &[u8]) -> Result<f64, String> {
        let rx = self.score_async(text)?;
        rx.recv().map_err(|_| "batcher dropped request".to_string())?
    }

    /// Submit a scoring request without waiting; the result arrives on the
    /// returned receiver. A caller with several texts (the HTTP
    /// `/v1/score` endpoint) submits them as one burst so the engine can
    /// flush them in a single batched backend call.
    pub fn score_async(&self, text: &[u8]) -> Result<Receiver<Result<f64, String>>, String> {
        let (tx, rx) = channel();
        self.tx
            .send(Work::Score(Request { text: text.to_vec(), reply: tx }))
            .map_err(|_| "batcher gone".to_string())?;
        Ok(rx)
    }

    /// Submit a generation request at the given admission [`Priority`];
    /// events stream back on the returned receiver ([`GenEvent::Token`]*
    /// then [`GenEvent::Done`], or [`GenEvent::Error`]). Dropping the
    /// receiver mid-stream evicts the sequence from its lane at the next
    /// step.
    pub fn generate(
        &self,
        prompt: &[u8],
        max_new: usize,
        temperature: f32,
        seed: u64,
        priority: Priority,
    ) -> Result<Receiver<GenEvent>, String> {
        let (tx, rx) = channel();
        self.tx
            .send(Work::Generate(GenRequest {
                id: self.trace.mint_id(),
                prompt: prompt.to_vec(),
                max_new,
                temperature,
                seed,
                client: self.client,
                priority,
                reply: tx,
            }))
            .map_err(|_| "batcher gone".to_string())?;
        Ok(rx)
    }

    /// Blocking service-stats snapshot (scheduler queue depths + backend
    /// KV/spec counters), answered by the engine loop between sweeps.
    /// `Err` when no engine loop is answering (scoring-only server, or
    /// the loop is gone) — surfaced as HTTP 503, never a zero snapshot.
    pub fn stats(&self) -> Result<StatsSnapshot, String> {
        let (tx, rx) = channel();
        self.tx.send(Work::Stats(tx)).map_err(|_| "batcher gone".to_string())?;
        rx.recv().map_err(|_| "batcher dropped request".to_string())?
    }
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> (Batcher, BatcherHandle) {
        let (tx, rx) = channel();
        let metrics = Arc::new(ServeMetrics::new());
        let trace = Arc::new(TraceRecorder::new(cfg.trace));
        let draining = Arc::new(AtomicBool::new(false));
        let handle = BatcherHandle {
            tx,
            client: 0,
            next_client: Arc::new(AtomicU64::new(1)),
            metrics: metrics.clone(),
            trace: trace.clone(),
            draining: draining.clone(),
        };
        (Batcher { cfg, rx, metrics, trace, draining }, handle)
    }

    /// Whether a graceful drain has been requested through any handle
    /// (see [`BatcherHandle::drain`]).
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// The serving metrics bundle shared with every handle (see
    /// [`BatcherHandle::metrics`]).
    pub fn metrics(&self) -> &Arc<ServeMetrics> {
        &self.metrics
    }

    /// The trace flight recorder shared with every handle (see
    /// [`BatcherHandle::trace`]).
    pub fn trace(&self) -> &Arc<TraceRecorder> {
        &self.trace
    }

    /// Blocking receive; `None` once every handle has dropped.
    pub fn recv(&self) -> Option<Work> {
        self.rx.recv().ok()
    }

    /// Bounded-wait receive (scoring batch top-up).
    pub fn recv_timeout(&self, d: Duration) -> Result<Work, RecvTimeoutError> {
        self.rx.recv_timeout(d)
    }

    /// Non-blocking drain of everything queued; returns `false` once every
    /// handle has dropped.
    pub fn drain_into(&self, into: &mut Vec<Work>) -> bool {
        loop {
            match self.rx.try_recv() {
                Ok(w) => into.push(w),
                Err(TryRecvError::Empty) => return true,
                Err(TryRecvError::Disconnected) => return false,
            }
        }
    }

    /// The one copy of the scoring batch policy: collect requests into
    /// `pending` until it holds `max_batch` texts or the `max_wait`
    /// deadline expires. Non-scoring work (generation, stats) is handed
    /// to `on_work`; if it returns `false` the top-up stops early (the
    /// engine loop uses this to start decoding as soon as generation
    /// traffic arrives). Returns `false` once every handle has dropped.
    pub fn top_up_scores(
        &self,
        pending: &mut Vec<Request>,
        mut on_work: impl FnMut(Work) -> bool,
    ) -> bool {
        let deadline = Instant::now() + self.cfg.max_wait;
        while pending.len() < self.cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.recv_timeout(deadline - now) {
                Ok(Work::Score(r)) => pending.push(r),
                Ok(other) => {
                    if !on_work(other) {
                        break;
                    }
                }
                Err(RecvTimeoutError::Timeout) => break,
                Err(RecvTimeoutError::Disconnected) => return false,
            }
        }
        true
    }

    /// Run a scoring-only batch loop until all senders hang up.
    /// `score_batch` maps a slice of texts to one score per text;
    /// generation requests are answered with an error, and stats requests
    /// with an `Err` — there is no scheduler or backend here, so
    /// fabricating an all-zero snapshot would just mislead monitoring
    /// (use `serve::run_engine` for a generation-capable loop).
    pub fn run(self, mut score_batch: impl FnMut(&[Vec<u8>]) -> Vec<Result<f64, String>>) {
        let answer_other = |w: Work| match w {
            Work::Generate(g) => {
                let _ = g
                    .reply
                    .send(GenEvent::Error("generation not supported by this server".into()));
            }
            Work::Stats(tx) => {
                let _ = tx.send(Err("generation engine not running (scoring-only loop)".into()));
            }
            Work::Score(_) => unreachable!("scoring work is batched, never forwarded"),
        };
        let mut pending: Vec<Request> = Vec::new();
        loop {
            // wait for the first request of a batch
            if pending.is_empty() {
                match self.recv() {
                    Some(Work::Score(r)) => pending.push(r),
                    Some(other) => {
                        answer_other(other);
                        continue;
                    }
                    None => return, // all senders dropped
                }
            }
            // top up until full or the wait budget expires; on disconnect
            // the flush below still answers what was collected, then the
            // next recv() observes the hangup
            self.top_up_scores(&mut pending, |w| {
                answer_other(w);
                true
            });
            let texts: Vec<Vec<u8>> = pending.iter().map(|r| r.text.clone()).collect();
            let scores = score_batch(&texts);
            debug_assert_eq!(scores.len(), texts.len());
            for (req, score) in pending.drain(..).zip(scores) {
                let _ = req.reply.send(score);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn batches_up_to_max() {
        let (batcher, handle) = Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(200),
            ..Default::default()
        });
        let max_seen = Arc::new(AtomicUsize::new(0));
        let ms = max_seen.clone();
        let worker = std::thread::spawn(move || {
            batcher.run(move |texts| {
                ms.fetch_max(texts.len(), Ordering::Relaxed);
                texts.iter().map(|t| Ok(t.len() as f64)).collect()
            });
        });
        std::thread::scope(|s| {
            for i in 0..8 {
                let h = handle.clone();
                s.spawn(move || {
                    let text = vec![b'x'; i + 1];
                    assert_eq!(h.score(&text).unwrap(), (i + 1) as f64);
                });
            }
        });
        drop(handle);
        worker.join().unwrap();
        let seen = max_seen.load(Ordering::Relaxed);
        assert!(seen >= 2, "never batched: max batch seen {seen}");
        assert!(seen <= 4, "exceeded max_batch: {seen}");
    }

    #[test]
    fn flushes_partial_batch_on_timeout() {
        let (batcher, handle) = Batcher::new(BatcherConfig {
            max_batch: 64,
            max_wait: Duration::from_millis(10),
            ..Default::default()
        });
        let worker = std::thread::spawn(move || {
            batcher.run(|texts| texts.iter().map(|_| Ok(1.0)).collect());
        });
        let t0 = Instant::now();
        assert_eq!(handle.score(b"solo").unwrap(), 1.0);
        assert!(t0.elapsed() < Duration::from_secs(1), "timeout flush too slow");
        drop(handle);
        worker.join().unwrap();
    }

    #[test]
    fn propagates_errors() {
        let (batcher, handle) = Batcher::new(BatcherConfig::default());
        let worker = std::thread::spawn(move || {
            batcher.run(|texts| texts.iter().map(|_| Err("boom".to_string())).collect());
        });
        assert_eq!(handle.score(b"x"), Err("boom".to_string()));
        drop(handle);
        worker.join().unwrap();
    }

    #[test]
    fn connection_handles_get_distinct_client_ids() {
        let (_batcher, handle) = Batcher::new(BatcherConfig::default());
        let a = handle.connection();
        let b = handle.connection();
        assert_ne!(a.client(), b.client(), "connections share a client id");
        assert_eq!(a.clone().client(), a.client(), "clone must keep identity");
        assert_ne!(handle.connection().client(), b.client());
    }

    #[test]
    fn scoring_only_loop_rejects_generation() {
        let (batcher, handle) = Batcher::new(BatcherConfig::default());
        let worker = std::thread::spawn(move || {
            batcher.run(|texts| texts.iter().map(|_| Ok(1.0)).collect());
        });
        let rx = handle.generate(b"hi", 4, 0.0, 0, Priority::Interactive).unwrap();
        match rx.recv().unwrap() {
            GenEvent::Error(msg) => assert!(msg.contains("not supported"), "{msg}"),
            other => panic!("expected Error, got {other:?}"),
        }
        drop(handle);
        worker.join().unwrap();
    }

    #[test]
    fn scoring_only_loop_answers_stats_with_error() {
        let (batcher, handle) = Batcher::new(BatcherConfig::default());
        let worker = std::thread::spawn(move || {
            batcher.run(|texts| texts.iter().map(|_| Ok(1.0)).collect());
        });
        // no engine loop behind this server: stats must say so, not hand
        // back a fabricated all-zero snapshot
        let err = handle.stats().unwrap_err();
        assert!(err.contains("not running"), "{err}");
        drop(handle);
        worker.join().unwrap();
    }

    #[test]
    fn drain_latch_is_shared_and_one_way() {
        let (batcher, handle) = Batcher::new(BatcherConfig::default());
        assert!(!batcher.is_draining());
        let conn = handle.connection();
        assert!(!conn.is_draining());
        // any handle can trip the latch; every view agrees afterwards
        conn.drain();
        assert!(batcher.is_draining());
        assert!(handle.is_draining());
        assert!(handle.connection().is_draining());
        // idempotent: draining again changes nothing
        handle.drain();
        assert!(batcher.is_draining());
    }

    #[test]
    fn handles_share_one_metrics_bundle() {
        let (batcher, handle) = Batcher::new(BatcherConfig::default());
        let conn = handle.connection();
        conn.metrics().tier(0).tokens.add(3);
        assert_eq!(batcher.metrics().tokens(), 3, "metrics not shared");
        assert_eq!(handle.clone().metrics().tokens(), 3);
    }
}
