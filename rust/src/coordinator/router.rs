//! The router tier: a front-end process that speaks this repo's existing
//! client protocols — the TCP line protocol and HTTP/SSE, unchanged — and
//! fans the requests out to N independent `serve` worker processes over
//! localhost TCP ([`run_router`]; CLI: `router --workers a:p,b:p` or
//! `serve --router`).
//!
//! Clients cannot tell a router from a single worker: the byte streams
//! are pinned identical by `tests/router_failover.rs`. What the router
//! adds is placement and failover across replicas:
//!
//! * **Load-aware placement.** A health loop polls every worker's
//!   `GET /v1/stats` on a short interval ([`RouterConfig::health_interval`])
//!   and records queue depth + active lanes as that worker's load, plus
//!   its `draining` flag. A worker whose poll fails its deadline is down;
//!   a draining worker stops receiving placements but keeps its active
//!   streams (satellite: graceful drain).
//! * **Sticky prefix routing.** Generation requests hash the first
//!   [`RouterConfig::sticky_prefix`] bytes of the prompt ([`prefix_hash`])
//!   and pick a worker by highest-random-weight hashing over the healthy
//!   set ([`rendezvous_pick`]). Requests sharing a prompt prefix land on
//!   the same replica, so its prompt prefix cache (`serve
//!   --prefix-cache`) keeps hitting — unless that worker's load exceeds
//!   the least-loaded worker by more than [`RouterConfig::load_slack`],
//!   in which case placement falls back to least-loaded (cache affinity
//!   is a hint, not a hotspot).
//! * **Retry on replica death.** The failure semantics extend
//!   `docs/API.md` §Errors without changing it: a request that has not
//!   yet produced output replays transparently on a surviving worker
//!   (the client never notices; `hbllm_router_retries_total` counts it);
//!   a stream that dies after its first byte surfaces the documented
//!   retryable `aborted` error, exactly as a restarting single server
//!   would. Scoring is idempotent and always replayable. With no healthy
//!   workers left, requests fail fast with `no healthy workers`.
//!
//! The router keeps its own metrics registry
//! ([`RouterMetrics`](super::metrics::RouterMetrics), `GET /v1/metrics`)
//! and serves an aggregate `GET /v1/stats` over the fleet. Workers can be
//! added at runtime (`POST /v1/workers {"add": "host:port"}`) — the
//! chaos harness uses this to bring in a replacement after a kill.
//! Fleet topology, the placement policy, and the full failure matrix are
//! documented in `docs/ARCHITECTURE.md` §Router tier.

use super::http::{
    drain_unread, error_json, obj, read_request, read_response_head, respond, respond_json,
    HttpRequest, Incoming,
};
use super::metrics::RouterMetrics;
use super::scheduler::Priority;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Tuning knobs for [`run_router`]. `Default` is sized for localhost
/// fleets (the only deployment this repo ships): tight health deadlines,
/// a sticky window matching a typical shared system-prompt prefix.
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// How often the health loop polls each worker's `GET /v1/stats`.
    pub health_interval: Duration,
    /// TCP connect deadline per worker dial — a dead replica must fail
    /// placement fast, not hang it.
    pub connect_timeout: Duration,
    /// Read deadline for bounded round-trips (health polls, scoring).
    /// Generation streams deliberately carry no read deadline: replica
    /// death shows up as EOF/reset, while a slow decode is not an error.
    pub read_timeout: Duration,
    /// How many leading prompt bytes feed [`prefix_hash`] — requests
    /// agreeing on this window stick to the same worker.
    pub sticky_prefix: usize,
    /// Load headroom the sticky worker is allowed over the least-loaded
    /// worker before placement abandons affinity for balance.
    pub load_slack: u64,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig {
            health_interval: Duration::from_millis(50),
            connect_timeout: Duration::from_millis(250),
            read_timeout: Duration::from_secs(2),
            sticky_prefix: 32,
            load_slack: 8,
        }
    }
}

/// FNV-1a over the first `sticky_prefix` bytes of the prompt — the
/// sticky-routing key. Pure and stable so tests can predict placement:
/// two prompts sharing the window hash identically, whatever their tails.
pub fn prefix_hash(prompt: &[u8], sticky_prefix: usize) -> u64 {
    fnv1a(&prompt[..prompt.len().min(sticky_prefix)])
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Highest-random-weight (rendezvous) choice: mix the request hash with
/// each address and pick the maximum. Deterministic, and minimally
/// disruptive — removing one address only moves the keys that mapped to
/// it, which is exactly the failover property the sticky prompt cache
/// wants. Returns an index into `addrs` (`None` when empty).
pub fn rendezvous_pick<S: AsRef<str>>(hash: u64, addrs: &[S]) -> Option<usize> {
    addrs
        .iter()
        .enumerate()
        .max_by_key(|(_, a)| mix(hash, fnv1a(a.as_ref().as_bytes())))
        .map(|(i, _)| i)
}

/// SplitMix64-style avalanche of the (request, worker) pair.
fn mix(a: u64, b: u64) -> u64 {
    let mut x = a ^ b.rotate_left(32);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

/// One worker as the router sees it. Liveness and load are atomics so
/// session threads read them lock-free; the health loop (and the
/// forward-failure path) are the writers.
struct WorkerState {
    addr: String,
    up: AtomicBool,
    draining: AtomicBool,
    /// Queue depth + active lanes from the last health poll, bumped
    /// optimistically on every placement so a burst between polls still
    /// spreads out.
    load: AtomicU64,
    up_gauge: super::metrics::Gauge,
}

impl WorkerState {
    /// Eligible for new placements: answered its last poll and not
    /// draining. Active streams on a draining worker are unaffected —
    /// only *placement* stops.
    fn placeable(&self) -> bool {
        self.up.load(Ordering::SeqCst) && !self.draining.load(Ordering::SeqCst)
    }

    fn set_health(&self, up: bool, draining: bool, load: u64) {
        self.up.store(up, Ordering::SeqCst);
        self.draining.store(draining, Ordering::SeqCst);
        self.load.store(load, Ordering::SeqCst);
        self.up_gauge.set((up && !draining) as i64);
    }
}

/// What one forwarded generation attempt did.
enum Attempt {
    /// A terminal frame (`done` or a worker `error`) was delivered.
    Finished,
    /// The client side stopped accepting writes.
    ClientGone,
    /// The worker connection died; `streamed` says whether any output
    /// byte had already reached the client (true = not replayable).
    WorkerDied { streamed: bool },
    /// The worker answered non-200 before streaming (400 usage, 503
    /// draining/engine-gone); body is its JSON error.
    Rejected { status: u16, body: String },
}

/// What a whole relayed generation (attempts + replays) came to.
enum Relay {
    Finished,
    ClientGone,
    /// Died after first output; `next_id` is the SSE id the terminal
    /// `aborted` frame must carry to stay monotone.
    Aborted { next_id: u64 },
    NoWorkers,
    Rejected { status: u16, body: String },
}

/// Shared router state: the worker pool, config, and metrics. Session
/// threads hold an `Arc<Router>`.
struct Router {
    cfg: RouterConfig,
    workers: Mutex<Vec<Arc<WorkerState>>>,
    metrics: Arc<RouterMetrics>,
}

impl Router {
    fn new(cfg: RouterConfig, metrics: Arc<RouterMetrics>) -> Router {
        Router { cfg, workers: Mutex::new(Vec::new()), metrics }
    }

    /// Register a worker address (idempotent). New workers start down
    /// until a poll sees them — callers wanting immediate placement run
    /// [`Router::poll_all`] right after.
    fn add_worker(&self, addr: &str) -> bool {
        let mut pool = self.workers.lock().unwrap();
        if pool.iter().any(|w| w.addr == addr) {
            return false;
        }
        pool.push(Arc::new(WorkerState {
            addr: addr.to_string(),
            up: AtomicBool::new(false),
            draining: AtomicBool::new(false),
            load: AtomicU64::new(0),
            up_gauge: self.metrics.worker_up(addr),
        }));
        true
    }

    fn snapshot(&self) -> Vec<Arc<WorkerState>> {
        self.workers.lock().unwrap().clone()
    }

    /// One health sweep over the fleet: load + draining from each
    /// worker's `GET /v1/stats`, down on any transport/deadline failure
    /// (a 503 — engine gone — is down too: it cannot take placements).
    fn poll_all(&self) {
        for w in self.snapshot() {
            match fetch_worker_stats(&w.addr, &self.cfg) {
                Ok((load, draining)) => w.set_health(true, draining, load),
                Err(_) => {
                    w.up.store(false, Ordering::SeqCst);
                    w.up_gauge.set(0);
                }
            }
        }
    }

    /// A forward failed against `w`: stop placing there immediately (the
    /// health loop re-admits it if it comes back).
    fn mark_down(&self, w: &WorkerState) {
        w.up.store(false, Ordering::SeqCst);
        w.up_gauge.set(0);
    }

    /// Pick a worker. `hash`: sticky rendezvous placement, overridden to
    /// least-loaded only when the sticky worker is `load_slack` busier
    /// than the least-loaded one. `None` (scoring): plain least-loaded.
    /// The winner's load is bumped so a same-instant burst spreads.
    fn place(&self, hash: Option<u64>) -> Option<Arc<WorkerState>> {
        let healthy: Vec<Arc<WorkerState>> =
            self.snapshot().into_iter().filter(|w| w.placeable()).collect();
        if healthy.is_empty() {
            return None;
        }
        let load = |w: &WorkerState| w.load.load(Ordering::SeqCst);
        let least = (0..healthy.len()).min_by_key(|&i| load(&healthy[i])).unwrap();
        let pick = match hash {
            Some(h) => {
                let addrs: Vec<&str> = healthy.iter().map(|w| w.addr.as_str()).collect();
                let sticky = rendezvous_pick(h, &addrs).unwrap();
                if load(&healthy[sticky]) > load(&healthy[least]) + self.cfg.load_slack {
                    least
                } else {
                    sticky
                }
            }
            None => least,
        };
        let w = healthy[pick].clone();
        w.load.fetch_add(1, Ordering::SeqCst);
        Some(w)
    }

    /// Forward one scoring POST, replaying across workers on transport
    /// failure (scoring is idempotent — `docs/API.md` §Errors). Returns
    /// the first worker response, or `None` with no healthy workers.
    fn forward_score(&self, body: &[u8]) -> Option<(u16, String)> {
        while let Some(w) = self.place(None) {
            match post_worker(&w.addr, "/v1/score", body, &self.cfg) {
                Ok(resp) => return Some(resp),
                Err(_) => {
                    self.mark_down(&w);
                    self.metrics.retries.inc();
                }
            }
        }
        None
    }

    /// Relay one generation end to end: place, stream, and replay dead
    /// attempts while nothing has reached the client. `sink(id, event,
    /// data)` writes one frame in the caller's wire format and reports
    /// whether the client is still there; `id` stays monotone from 0
    /// across replays, so the client-visible stream is indistinguishable
    /// from a single worker's.
    fn relay_generation<F: FnMut(u64, &str, &str) -> bool>(
        &self,
        body: &str,
        hash: u64,
        sink: &mut F,
    ) -> Relay {
        let mut next_id = 0u64;
        loop {
            let Some(w) = self.place(Some(hash)) else {
                return Relay::NoWorkers;
            };
            match try_stream(&w.addr, body, &self.cfg, &mut next_id, sink) {
                Attempt::Finished => return Relay::Finished,
                Attempt::ClientGone => return Relay::ClientGone,
                Attempt::WorkerDied { streamed: true } => {
                    self.mark_down(&w);
                    return Relay::Aborted { next_id };
                }
                Attempt::WorkerDied { streamed: false } => {
                    // nothing reached the client: replay elsewhere,
                    // invisibly (the tentpole's retry semantics)
                    self.mark_down(&w);
                    self.metrics.retries.inc();
                }
                Attempt::Rejected { status: 503, .. } => {
                    // admission refused (draining / engine gone) — the
                    // request never started, so it replays like a death;
                    // the health loop sorts out draining vs down
                    self.mark_down(&w);
                    self.metrics.retries.inc();
                }
                Attempt::Rejected { status, body } => {
                    // deterministic client error (bad usage): every
                    // worker would say the same — forward, don't retry
                    return Relay::Rejected { status, body };
                }
            }
        }
    }
}

/// Dial a worker with the connect deadline (hostnames fall back to the
/// blocking resolver path — worker addresses are normally numeric).
fn connect_worker(addr: &str, cfg: &RouterConfig) -> std::io::Result<TcpStream> {
    match addr.parse::<SocketAddr>() {
        Ok(sa) => TcpStream::connect_timeout(&sa, cfg.connect_timeout),
        Err(_) => TcpStream::connect(addr),
    }
}

/// `GET /v1/stats` from one worker → (queued + active as load, draining).
fn fetch_worker_stats(addr: &str, cfg: &RouterConfig) -> Result<(u64, bool)> {
    let mut s = connect_worker(addr, cfg)?;
    s.set_read_timeout(Some(cfg.read_timeout))?;
    s.write_all(
        format!("GET /v1/stats HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n").as_bytes(),
    )?;
    let mut reader = BufReader::new(s);
    let status = read_response_head(&mut reader)?;
    let mut body = String::new();
    reader.read_to_string(&mut body)?;
    if status != 200 {
        bail!("worker {addr} stats answered {status}");
    }
    let j = Json::parse(&body).map_err(|e| anyhow!("worker {addr} stats: {e}"))?;
    let num =
        |k: &str| j.get(k).and_then(Json::as_f64).map(|v| v.max(0.0) as u64).unwrap_or(0);
    let draining = j.get("draining") == Some(&Json::Bool(true));
    Ok((num("queued") + num("active"), draining))
}

/// POST a JSON body to one worker and read the whole response.
fn post_worker(addr: &str, path: &str, body: &[u8], cfg: &RouterConfig) -> Result<(u16, String)> {
    let mut s = connect_worker(addr, cfg)?;
    s.set_read_timeout(Some(cfg.read_timeout))?;
    let head = format!(
        "POST {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    s.write_all(head.as_bytes())?;
    s.write_all(body)?;
    let mut reader = BufReader::new(s);
    let status = read_response_head(&mut reader)?;
    let mut resp = String::new();
    reader.read_to_string(&mut resp)?;
    Ok((status, resp))
}

/// One streaming attempt against one worker. `next_id` only advances on
/// frames actually handed to `sink`, so ids stay contiguous across a
/// replay. A worker-side `aborted` with nothing streamed is folded into
/// `WorkerDied` — the worker's engine died under the request, which is
/// exactly the replayable case.
fn try_stream<F: FnMut(u64, &str, &str) -> bool>(
    addr: &str,
    body: &str,
    cfg: &RouterConfig,
    next_id: &mut u64,
    sink: &mut F,
) -> Attempt {
    let mut s = match connect_worker(addr, cfg) {
        Ok(s) => s,
        Err(_) => return Attempt::WorkerDied { streamed: false },
    };
    let head = format!(
        "POST /v1/generate HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    if s.write_all(head.as_bytes()).is_err() || s.write_all(body.as_bytes()).is_err() {
        return Attempt::WorkerDied { streamed: false };
    }
    let mut reader = BufReader::new(s);
    let status = match read_response_head(&mut reader) {
        Ok(st) => st,
        Err(_) => return Attempt::WorkerDied { streamed: false },
    };
    if status != 200 {
        let mut b = String::new();
        if reader.read_to_string(&mut b).is_err() {
            return Attempt::WorkerDied { streamed: false };
        }
        return Attempt::Rejected { status, body: b };
    }
    let mut streamed = false;
    let mut event = String::new();
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return Attempt::WorkerDied { streamed },
            Ok(_) => {}
        }
        let t = line.trim_end();
        if let Some(e) = t.strip_prefix("event: ") {
            event = e.to_string();
        } else if let Some(d) = t.strip_prefix("data: ") {
            match event.as_str() {
                "tok" => {
                    let id = *next_id;
                    *next_id += 1;
                    if !sink(id, "tok", d) {
                        return Attempt::ClientGone;
                    }
                    streamed = true;
                }
                "done" => {
                    let id = *next_id;
                    *next_id += 1;
                    return if sink(id, "done", d) {
                        Attempt::Finished
                    } else {
                        Attempt::ClientGone
                    };
                }
                "error" if d == "aborted" && !streamed => {
                    return Attempt::WorkerDied { streamed: false };
                }
                "error" => {
                    // a real engine answer (`kv exhausted`, `draining`):
                    // forwarded verbatim, never retried — replaying a
                    // request its worker rejected would double-charge
                    // the documented error semantics
                    let id = *next_id;
                    *next_id += 1;
                    return if sink(id, "error", d) {
                        Attempt::Finished
                    } else {
                        Attempt::ClientGone
                    };
                }
                _ => return Attempt::WorkerDied { streamed },
            }
        }
        // blank lines delimit frames
    }
}

/// Build a worker `/v1/generate` body from TCP `gen` verb arguments
/// (seed as a decimal string so the full u64 range round-trips).
fn gen_body(prompt: &str, max_new: usize, temperature: f32, seed: u64, prio: Priority) -> String {
    obj(vec![
        ("prompt", Json::Str(prompt.to_string())),
        ("max_new", Json::Num(max_new as f64)),
        ("temperature", Json::Num(temperature as f64)),
        ("seed", Json::Str(seed.to_string())),
        ("priority", Json::Str(prio.as_str().to_string())),
    ])
    .to_string()
}

/// Pull the `error` field out of a worker's JSON error body (falling
/// back to the raw text) so the TCP front can say `err <msg>`.
fn error_text(body: &str) -> String {
    Json::parse(body)
        .ok()
        .and_then(|j| j.get("error").and_then(Json::as_str).map(String::from))
        .unwrap_or_else(|| body.trim().to_string())
}

/// Forward one TCP `gen` request. Returns `false` once the client
/// connection is unusable — mirrors `serve::handle_gen` byte for byte on
/// every path it shares.
fn forward_gen_tcp(
    router: &Router,
    args: &str,
    priority: Priority,
    writer: &mut TcpStream,
) -> bool {
    let mut it = args.splitn(4, ' ');
    let parsed = (
        it.next().and_then(|s| s.parse::<usize>().ok()),
        it.next().and_then(|s| s.parse::<f32>().ok()),
        it.next().and_then(|s| s.parse::<u64>().ok()),
    );
    let (max_new, temperature, seed) = match parsed {
        (Some(m), Some(t), Some(s)) => (m, t, s),
        _ => {
            return writer
                .write_all(b"err usage: gen <max-new> <temperature> <seed> <prompt>\n")
                .is_ok()
        }
    };
    let prompt = it.next().unwrap_or("");
    router.metrics.requests[0].inc();
    let body = gen_body(prompt, max_new, temperature, seed, priority);
    let hash = prefix_hash(prompt.as_bytes(), router.cfg.sticky_prefix);
    let mut sink = |_id: u64, event: &str, data: &str| -> bool {
        let line = match event {
            "tok" => format!("tok {data}\n"),
            "done" => format!("done {data}\n"),
            _ => format!("err {data}\n"),
        };
        writer.write_all(line.as_bytes()).is_ok()
    };
    match router.relay_generation(&body, hash, &mut sink) {
        Relay::Finished => true,
        Relay::ClientGone => false,
        Relay::Aborted { .. } => writer.write_all(b"err aborted\n").is_ok(),
        Relay::NoWorkers => writer.write_all(b"err no healthy workers\n").is_ok(),
        Relay::Rejected { body, .. } => {
            writer.write_all(format!("err {}\n", error_text(&body)).as_bytes()).is_ok()
        }
    }
}

/// One TCP line-protocol session at the router. Verb grammar and byte
/// streams match [`serve::LineConn`](super::serve::LineConn) exactly —
/// `tests/router_failover.rs` pins the equivalence — except `drain`,
/// which is a per-worker verb and is answered with an error here.
fn run_tcp_session(router: &Router, stream: TcpStream) {
    let _conn = router.metrics.connection_guard(0);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = match line {
            Ok(l) => l,
            Err(_) => break,
        };
        if line.is_empty() {
            continue;
        }
        if line == "drain" {
            // draining is a worker lifecycle action, not a routed one:
            // the operator drains replicas individually (POST /v1/drain)
            // and the health loop stops placing there
            if writer.write_all(b"err drain is not routed; drain workers directly\n").is_ok() {
                continue;
            }
            break;
        }
        let (priority, verb) = match line.strip_prefix("prio ") {
            Some(rest) => {
                let (level, tail) = rest.split_once(' ').unwrap_or((rest, ""));
                match Priority::parse(level) {
                    Some(p) if tail == "gen" || tail.starts_with("gen ") => (p, tail),
                    _ => {
                        let ok = writer
                            .write_all(b"err usage: prio <interactive|batch> gen <max-new> <temperature> <seed> <prompt>\n")
                            .is_ok();
                        if ok {
                            continue;
                        }
                        break;
                    }
                }
            }
            None => (Priority::Interactive, line.as_str()),
        };
        let ok = if let Some(rest) = verb.strip_prefix("gen ") {
            forward_gen_tcp(router, rest, priority, &mut writer)
        } else if verb == "gen" {
            forward_gen_tcp(router, "", priority, &mut writer)
        } else {
            // `ppl <text>` or a legacy bare line: one idempotent scoring
            // round-trip through a worker's /v1/score
            let text = verb.strip_prefix("ppl ").unwrap_or(verb);
            router.metrics.requests[0].inc();
            let body =
                obj(vec![("texts", Json::Arr(vec![Json::Str(text.to_string())]))]).to_string();
            let resp = match router.forward_score(body.as_bytes()) {
                None => "err no healthy workers\n".to_string(),
                Some((200, resp)) => {
                    let first = Json::parse(&resp)
                        .ok()
                        .and_then(|j| j.get("results")?.as_arr()?.first().cloned());
                    match first {
                        Some(r) => match r.get("ppl").and_then(Json::as_f64) {
                            // the worker's TCP front formats the same f64
                            // with {:.4}; Json round-trips it exactly, so
                            // these bytes match a direct connection
                            Some(ppl) => format!("ppl {ppl:.4}\n"),
                            None => format!(
                                "err {}\n",
                                r.get("error").and_then(Json::as_str).unwrap_or("score failed")
                            ),
                        },
                        None => "err score failed\n".to_string(),
                    }
                }
                Some((_, resp)) => format!("err {}\n", error_text(&resp)),
            };
            writer.write_all(resp.as_bytes()).is_ok()
        };
        if !ok {
            break;
        }
    }
}

/// Map a relayed status code back onto a reason phrase for the
/// response's start line (the worker's phrase is not kept).
fn reason_for(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        503 => "Service Unavailable",
        _ => "Error",
    }
}

/// Aggregate fleet stats for the router's `GET /v1/stats`: one row per
/// worker (placement's exact view) plus the healthy count.
fn fleet_stats_json(router: &Router) -> Json {
    let workers = router.snapshot();
    let healthy = workers.iter().filter(|w| w.placeable()).count();
    let rows = workers
        .iter()
        .map(|w| {
            obj(vec![
                ("worker", Json::Str(w.addr.clone())),
                ("up", Json::Bool(w.up.load(Ordering::SeqCst))),
                ("draining", Json::Bool(w.draining.load(Ordering::SeqCst))),
                ("load", Json::Num(w.load.load(Ordering::SeqCst) as f64)),
            ])
        })
        .collect();
    obj(vec![
        ("healthy", Json::Num(healthy as f64)),
        ("workers", Json::Arr(rows)),
        ("requests", obj(vec![
            ("tcp", Json::Num(router.metrics.requests[0].get() as f64)),
            ("http", Json::Num(router.metrics.requests[1].get() as f64)),
        ])),
        ("retries", Json::Num(router.metrics.retries.get() as f64)),
    ])
}

/// `POST /v1/generate` at the router: hash the prompt for stickiness,
/// forward the raw client body (workers validate; their 400s relay
/// verbatim), and re-emit the worker's SSE frames under the router's own
/// monotone `id:` counter.
fn handle_http_generate(router: &Router, req: &HttpRequest, writer: &mut TcpStream) {
    router.metrics.requests[1].inc();
    // prompt for stickiness only — an unparseable body still forwards
    // (hashed whole) so the worker's error response stays authoritative
    let prompt_hash = std::str::from_utf8(&req.body)
        .ok()
        .and_then(|s| Json::parse(s).ok())
        .and_then(|j| {
            j.get("prompt").and_then(Json::as_str).map(|p| {
                prefix_hash(p.as_bytes(), router.cfg.sticky_prefix)
            })
        })
        .unwrap_or_else(|| prefix_hash(&req.body, router.cfg.sticky_prefix));
    let body = String::from_utf8_lossy(&req.body).into_owned();
    let sse_head = "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-store\r\nConnection: close\r\n\r\n";
    let mut head_written = false;
    let mut sink = |id: u64, event: &str, data: &str| -> bool {
        if !head_written {
            if writer.write_all(sse_head.as_bytes()).is_err() {
                return false;
            }
            head_written = true;
        }
        let frame = format!("id: {id}\nevent: {event}\ndata: {data}\n\n");
        writer.write_all(frame.as_bytes()).is_ok() && writer.flush().is_ok()
    };
    match router.relay_generation(&body, prompt_hash, &mut sink) {
        Relay::Finished | Relay::ClientGone => {}
        Relay::Aborted { next_id } => {
            // same terminal frame a dying single server writes
            if head_written {
                let _ = writer
                    .write_all(format!("id: {next_id}\nevent: error\ndata: aborted\n\n").as_bytes());
            }
        }
        Relay::NoWorkers => {
            if !head_written {
                respond_json(
                    writer,
                    503,
                    "Service Unavailable",
                    &error_json("no healthy workers"),
                    true,
                );
            } else {
                let _ = writer.write_all(b"id: 0\nevent: error\ndata: no healthy workers\n\n");
            }
        }
        Relay::Rejected { status, body } => {
            if !head_written {
                respond(writer, status, reason_for(status), "application/json", body.as_bytes(), true);
            } else {
                let _ = writer.write_all(
                    format!("id: 0\nevent: error\ndata: {}\n\n", error_text(&body)).as_bytes(),
                );
            }
        }
    }
}

/// One HTTP session at the router: same endpoints as a worker where they
/// make sense (`/v1/generate`, `/v1/score`, `/v1/stats`, `/v1/metrics`),
/// plus the fleet-management pair (`GET`/`POST /v1/workers`).
fn run_http_session(router: &Router, stream: TcpStream) {
    let _conn = router.metrics.connection_guard(1);
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    loop {
        let req = match read_request(&mut reader) {
            Ok(Incoming::Req(r)) => r,
            Ok(Incoming::Eof) | Err(_) => return,
            Ok(Incoming::Oversized) => {
                respond_json(
                    &mut writer,
                    413,
                    "Payload Too Large",
                    &error_json("request head or body too large"),
                    true,
                );
                drain_unread(&mut reader);
                return;
            }
            Ok(Incoming::Malformed(msg)) => {
                respond_json(&mut writer, 400, "Bad Request", &error_json(msg), true);
                drain_unread(&mut reader);
                return;
            }
        };
        let close = req.wants_close();
        let keep = match (req.method.as_str(), req.path.as_str()) {
            ("POST", "/v1/generate") => {
                handle_http_generate(router, &req, &mut writer);
                false // SSE stream is delimited by connection close
            }
            ("POST", "/v1/score") => {
                router.metrics.requests[1].inc();
                match router.forward_score(&req.body) {
                    Some((status, body)) => respond(
                        &mut writer,
                        status,
                        reason_for(status),
                        "application/json",
                        body.as_bytes(),
                        close,
                    ),
                    None => respond_json(
                        &mut writer,
                        503,
                        "Service Unavailable",
                        &error_json("no healthy workers"),
                        close,
                    ),
                }
            }
            ("GET", "/v1/stats") => {
                respond_json(&mut writer, 200, "OK", &fleet_stats_json(router), close)
            }
            ("GET", "/v1/metrics") => {
                let text = router.metrics.render();
                respond(
                    &mut writer,
                    200,
                    "OK",
                    "text/plain; version=0.0.4; charset=utf-8",
                    text.as_bytes(),
                    close,
                )
            }
            ("GET", "/v1/workers") => {
                respond_json(&mut writer, 200, "OK", &fleet_stats_json(router), close)
            }
            ("POST", "/v1/workers") => {
                let add = std::str::from_utf8(&req.body)
                    .ok()
                    .and_then(|s| Json::parse(s).ok())
                    .and_then(|j| j.get("add").and_then(Json::as_str).map(String::from));
                match add {
                    Some(addr) => {
                        router.add_worker(&addr);
                        // poll immediately so the new replica is
                        // placeable before the next health tick — the
                        // chaos harness adds a replacement and expects
                        // traffic to land on it right away
                        router.poll_all();
                        respond_json(&mut writer, 200, "OK", &fleet_stats_json(router), close)
                    }
                    None => respond_json(
                        &mut writer,
                        400,
                        "Bad Request",
                        &error_json("usage: {\"add\": \"host:port\"}"),
                        close,
                    ),
                }
            }
            (_, "/v1/generate") | (_, "/v1/score") | (_, "/v1/stats") | (_, "/v1/metrics")
            | (_, "/v1/workers") => respond_json(
                &mut writer,
                405,
                "Method Not Allowed",
                &error_json("wrong method for this endpoint (see docs/API.md)"),
                close,
            ),
            _ => respond_json(
                &mut writer,
                404,
                "Not Found",
                &error_json("no such endpoint (see docs/API.md)"),
                close,
            ),
        };
        if !keep || close {
            return;
        }
    }
}

/// Accept sessions from one router listener until its budget is spent
/// (forever for `None`), then join every session so callers observe a
/// quiesced connection gauge.
fn accept_router(
    listener: TcpListener,
    max_conns: Option<usize>,
    router: Arc<Router>,
    tcp_front: bool,
) {
    let mut sessions = Vec::new();
    let mut served = 0usize;
    for stream in listener.incoming() {
        match stream {
            Ok(s) => {
                let r = router.clone();
                sessions.push(std::thread::spawn(move || {
                    if tcp_front {
                        run_tcp_session(&r, s)
                    } else {
                        run_http_session(&r, s)
                    }
                }));
                served += 1;
                if let Some(max) = max_conns {
                    if served >= max {
                        break;
                    }
                }
            }
            Err(_) => break,
        }
    }
    for s in sessions {
        s.join().ok();
    }
}

/// Run the router tier: front-end listeners (TCP line protocol and/or
/// HTTP, each with an optional connection budget) over a fleet of worker
/// addresses. Blocks until every budgeted front is exhausted and its
/// sessions have drained (forever with `None` budgets — the CLI path).
/// Workers are polled once before accepting so the first request can
/// place; after that the health loop owns liveness. Returns the router's
/// metrics bundle for the caller's shutdown summary.
pub fn run_router(
    tcp: Option<(TcpListener, Option<usize>)>,
    http: Option<(TcpListener, Option<usize>)>,
    workers: Vec<String>,
    cfg: RouterConfig,
) -> Result<Arc<RouterMetrics>> {
    let metrics = Arc::new(RouterMetrics::new());
    let router = Arc::new(Router::new(cfg, metrics.clone()));
    for w in &workers {
        router.add_worker(w);
    }
    router.poll_all();
    let stop = Arc::new(AtomicBool::new(false));
    let health = {
        let r = router.clone();
        let s = stop.clone();
        std::thread::spawn(move || {
            while !s.load(Ordering::SeqCst) {
                std::thread::sleep(r.cfg.health_interval);
                r.poll_all();
            }
        })
    };
    let mut accepts = Vec::new();
    if let Some((listener, max)) = tcp {
        let r = router.clone();
        accepts.push(std::thread::spawn(move || accept_router(listener, max, r, true)));
    }
    if let Some((listener, max)) = http {
        let r = router.clone();
        accepts.push(std::thread::spawn(move || accept_router(listener, max, r, false)));
    }
    for a in accepts {
        a.join().ok();
    }
    stop.store(true, Ordering::SeqCst);
    health.join().ok();
    Ok(metrics)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prefix_hash_depends_only_on_the_window() {
        let window = 8;
        let a = prefix_hash(b"system: abc TAIL ONE", window);
        let b = prefix_hash(b"system: abc TAIL TWO", window);
        assert_eq!(a, b, "same first {window} bytes must hash identically");
        assert_ne!(
            prefix_hash(b"system: x", window),
            prefix_hash(b"system: y", window),
            "differing windows should (overwhelmingly) differ"
        );
        // shorter than the window: the whole prompt is the key
        assert_eq!(prefix_hash(b"hi", window), prefix_hash(b"hi", 64));
    }

    #[test]
    fn rendezvous_is_deterministic_and_minimally_disruptive() {
        let addrs = ["127.0.0.1:7001", "127.0.0.1:7002", "127.0.0.1:7003"];
        assert_eq!(rendezvous_pick(42, &[] as &[&str]), None);
        for h in 0..200u64 {
            let pick = rendezvous_pick(h, &addrs).unwrap();
            assert!(pick < addrs.len());
            assert_eq!(rendezvous_pick(h, &addrs), Some(pick), "must be stable");
            // HRW property: removing an address the key did NOT map to
            // must not move the key (this is what keeps prompt-cache
            // affinity intact when an unrelated replica dies)
            for dead in 0..addrs.len() {
                if dead == pick {
                    continue;
                }
                let survivors: Vec<&str> =
                    addrs.iter().enumerate().filter(|&(i, _)| i != dead).map(|(_, a)| *a).collect();
                let re = rendezvous_pick(h, &survivors).unwrap();
                assert_eq!(survivors[re], addrs[pick], "unrelated removal moved the key");
            }
        }
    }

    #[test]
    fn rendezvous_spreads_keys_across_workers() {
        let addrs = ["127.0.0.1:7001", "127.0.0.1:7002", "127.0.0.1:7003"];
        let mut counts = [0usize; 3];
        for h in 0..600u64 {
            counts[rendezvous_pick(mix(h, 0x9e37), &addrs).unwrap()] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            assert!(c > 60, "worker {i} got only {c}/600 keys — not a spread");
        }
    }

    #[test]
    fn placement_is_sticky_until_the_load_slack_is_exceeded() {
        let cfg = RouterConfig { load_slack: 2, ..RouterConfig::default() };
        let router = Router::new(cfg, Arc::new(RouterMetrics::new()));
        for a in ["127.0.0.1:7001", "127.0.0.1:7002"] {
            router.add_worker(a);
        }
        // mark both up by hand (no real workers in a unit test)
        for w in router.snapshot() {
            w.set_health(true, false, 0);
        }
        let hash = prefix_hash(b"shared prefix", 32);
        let sticky = router.place(Some(hash)).unwrap().addr.clone();
        for _ in 0..2 {
            assert_eq!(router.place(Some(hash)).unwrap().addr, sticky, "affinity lost");
        }
        // place() bumped the sticky worker to load 3 while the other
        // sits at 0 — past the slack, so the next placement balances
        let spilled = router.place(Some(hash)).unwrap().addr.clone();
        assert_ne!(spilled, sticky, "load_slack exceeded but placement did not spill");
        // a draining worker is not placeable, however sticky
        for w in router.snapshot() {
            let stick_here = w.addr == sticky;
            w.set_health(true, stick_here, 0);
        }
        assert_ne!(router.place(Some(hash)).unwrap().addr, sticky);
        // nothing placeable -> None (the `no healthy workers` path)
        for w in router.snapshot() {
            w.set_health(false, false, 0);
        }
        assert!(router.place(Some(hash)).is_none());
        assert!(router.place(None).is_none());
    }

    #[test]
    fn gen_body_round_trips_the_full_seed_range() {
        let body = gen_body("p", 4, 0.5, u64::MAX, Priority::Batch);
        let j = Json::parse(&body).unwrap();
        assert_eq!(j.get("seed").and_then(Json::as_str), Some(u64::MAX.to_string().as_str()));
        assert_eq!(j.get("priority").and_then(Json::as_str), Some("batch"));
        assert_eq!(j.get("max_new"), Some(&Json::Num(4.0)));
    }

    #[test]
    fn error_text_unwraps_json_or_falls_back() {
        assert_eq!(error_text("{\"error\":\"draining\"}"), "draining");
        assert_eq!(error_text("not json at all\n"), "not json at all");
    }
}
