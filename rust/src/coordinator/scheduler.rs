//! The coordinator's two schedulers.
//!
//! **Quantization** ([`quantize_model`]): a deterministic work-stealing
//! pool over the model's linear layers. Invariants (property-tested):
//! every layer quantized exactly once, output independent of worker count,
//! original weights untouched on failure.
//!
//! **Generation** ([`GenScheduler`]): the admission-control state machine
//! behind the continuous-batching serve loop. Requests (prompt +
//! max-tokens + temperature + seed) queue until a KV lane frees up — and,
//! on KV-metered backends, until enough paged-KV blocks are free to cover
//! the request's worst case; every [`GenScheduler::step`] admits waiting
//! requests into free lanes, runs one [`Backend::decode_batch`] sweep
//! over all active lanes, samples and streams one token per sequence, and
//! evicts sequences that exhausted their token budget or lost their
//! client — so lanes turn over without ever draining the whole batch
//! (continuous batching, not static batches). A freshly admitted lane
//! prefills its prompt inside the same sweep established lanes decode in.
//! Block exhaustion mid-sweep evicts the lowest-progress sequence with
//! `kv exhausted` instead of failing the batch, so an undersized arena
//! degrades to backpressure, never an OOM or a wedged sweep.

use super::progress::Progress;
use crate::calib::CtxMap;
use crate::data::ByteTokenizer;
use crate::engine::paged::blocks_for;
use crate::engine::{sample_logits, Backend, KvExhausted, SpecConfig};
use crate::model::Weights;
use crate::quant::{BitsBreakdown, Quantizer};
use crate::tensor::Matrix;
use crate::util::rng::Pcg32;
use anyhow::{anyhow, Result};
use std::cmp::Reverse;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Mutex;
use std::time::Instant;

#[derive(Clone)]
pub struct QuantJobConfig {
    pub workers: usize,
    pub quiet: bool,
}

impl Default for QuantJobConfig {
    fn default() -> Self {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        QuantJobConfig { workers: workers.min(8), quiet: false }
    }
}

#[derive(Clone, Debug)]
pub struct LayerResult {
    pub name: String,
    pub mse: f64,
    pub wbits: f64,
    pub bits: f64,
    pub seconds: f64,
    pub rows: usize,
    pub cols: usize,
}

/// Quantize every linear layer of `weights` in place with `method`, using
/// the Hessians in `calib`. Returns per-layer metrics (sorted by name).
///
/// Matrices are stored [in, out] (x @ W); the quantizer contract is paper
/// orientation [out, in], so each layer transposes in and back out.
pub fn quantize_model(
    weights: &mut Weights,
    ctxs: &CtxMap,
    method: &dyn Quantizer,
    cfg: &QuantJobConfig,
) -> Result<Vec<LayerResult>> {
    let names = weights.config.linear_names();
    let progress = Progress::new(&method.name(), names.len(), cfg.quiet);
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<(String, Matrix, LayerResult)>> = Mutex::new(Vec::new());
    let failure: Mutex<Option<String>> = Mutex::new(None);

    std::thread::scope(|s| {
        for _ in 0..cfg.workers.max(1) {
            s.spawn(|| loop {
                let idx = next.fetch_add(1, Ordering::Relaxed);
                if idx >= names.len() {
                    return;
                }
                let name = &names[idx];
                let run = || -> Result<(Matrix, LayerResult)> {
                    let w_model = weights.get(name).as_mat(); // [in, out]
                    let w_paper = w_model.transpose(); // [out, in]
                    let ctx = ctxs.for_linear(name)?;
                    let t0 = Instant::now();
                    let out = method.quantize(&w_paper, &ctx);
                    let seconds = t0.elapsed().as_secs_f64();
                    let bits: BitsBreakdown = out.bits;
                    let res = LayerResult {
                        name: name.clone(),
                        mse: out.mse,
                        wbits: bits.per_weight(w_paper.rows, w_paper.cols),
                        bits: bits.total(),
                        seconds,
                        rows: w_paper.rows,
                        cols: w_paper.cols,
                    };
                    Ok((out.w_hat.transpose(), res))
                };
                match run() {
                    Ok((w_hat_model, res)) => {
                        progress.tick(name);
                        results.lock().unwrap().push((name.clone(), w_hat_model, res));
                    }
                    Err(e) => {
                        *failure.lock().unwrap() = Some(format!("{name}: {e}"));
                        return;
                    }
                }
            });
        }
    });

    if let Some(msg) = failure.into_inner().unwrap() {
        // leave `weights` untouched on failure
        return Err(anyhow!("quantization failed: {msg}"));
    }
    let mut results = results.into_inner().unwrap();
    results.sort_by(|a, b| a.0.cmp(&b.0));
    let mut metrics = Vec::with_capacity(results.len());
    for (name, w_hat, res) in results {
        weights.set_matrix(&name, w_hat);
        metrics.push(res);
    }
    Ok(metrics)
}

/// Streamed generation events, one receiver per request.
#[derive(Clone, Debug, PartialEq)]
pub enum GenEvent {
    /// One sampled byte (streamed as soon as it is decoded).
    Token(u8),
    /// Sequence finished: the full text (prompt + generated bytes) and the
    /// number of generated bytes.
    Done { text: Vec<u8>, generated: usize },
    /// Decoding failed or the server is shutting down.
    Error(String),
}

/// A generation request as admitted by the scheduler.
pub struct GenRequest {
    pub prompt: Vec<u8>,
    /// Token budget; capped at the scheduler's `max_new_cap` on admission.
    pub max_new: usize,
    /// `<= 0` is greedy argmax; otherwise softmax sampling.
    pub temperature: f32,
    /// Sampling RNG seed (ignored for greedy decoding).
    pub seed: u64,
    /// Originating client connection. Admission round-robins across
    /// clients (per-client FIFO), so one chatty connection cannot starve
    /// the others; requests sharing a client id keep strict FIFO order.
    pub client: u64,
    pub reply: Sender<GenEvent>,
}

/// One sequence resident in a KV lane.
struct ActiveSeq {
    text: Vec<u8>,
    generated: usize,
    remaining: usize,
    temperature: f32,
    rng: Pcg32,
    reply: Sender<GenEvent>,
    /// KV blocks promised to this sequence at admission (0 when the
    /// backend's KV memory is unmetered). The lane allocates lazily, so
    /// admission subtracts the *unallocated* remainder of every active
    /// sequence's reservation from the free count.
    reserved: usize,
}

/// Admission-controlled continuous batching over a backend's KV lanes.
///
/// The scheduler owns no model state — lanes live in the backend
/// ([`Backend::lanes`]); it owns the queues, the per-sequence sampling
/// state, and the admit/step/evict policy. Drive it with repeated
/// [`GenScheduler::step`] calls while [`GenScheduler::has_work`].
///
/// With a [`SpecConfig`] ([`GenScheduler::with_spec`]), greedy sequences
/// decode speculatively through [`Backend::decode_batch_spec`] — several
/// verified bytes per step — while sampling sequences share the same
/// lanes on the plain path (mixed speculative/plain batches).
pub struct GenScheduler {
    /// `slots[i]` is the sequence resident in backend lane `i`.
    slots: Vec<Option<ActiveSeq>>,
    /// Per-client FIFO queues; admission serves clients from `rr` in
    /// rotation so a chatty client cannot starve the rest.
    queues: BTreeMap<u64, VecDeque<GenRequest>>,
    /// Round-robin rotation of client ids with pending requests.
    rr: VecDeque<u64>,
    max_new_cap: usize,
    spec: SpecConfig,
}

impl GenScheduler {
    /// `lanes` should be [`Backend::lanes`] of the backend that will be
    /// stepped; `max_new_cap` bounds any single request's token budget
    /// (admission control — one request cannot monopolize a lane forever).
    pub fn new(lanes: usize, max_new_cap: usize) -> GenScheduler {
        GenScheduler::with_spec(lanes, max_new_cap, SpecConfig::disabled())
    }

    /// As [`GenScheduler::new`], with speculative decoding for greedy
    /// sequences. Pass the *effective* config [`Backend::set_spec`]
    /// returned so scheduler and backend agree.
    pub fn with_spec(lanes: usize, max_new_cap: usize, spec: SpecConfig) -> GenScheduler {
        GenScheduler {
            slots: (0..lanes.max(1)).map(|_| None).collect(),
            queues: BTreeMap::new(),
            rr: VecDeque::new(),
            max_new_cap: max_new_cap.max(1),
            spec,
        }
    }

    pub fn lanes(&self) -> usize {
        self.slots.len()
    }

    /// Sequences currently resident in lanes.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Requests waiting for a free lane (all clients).
    pub fn queued(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    pub fn has_work(&self) -> bool {
        self.active() > 0 || !self.rr.is_empty()
    }

    /// Enqueue a request. A zero-token request completes immediately.
    pub fn submit(&mut self, req: GenRequest) {
        if req.max_new == 0 {
            let _ = req.reply.send(GenEvent::Done { text: req.prompt, generated: 0 });
            return;
        }
        let client = req.client;
        self.queues.entry(client).or_default().push_back(req);
        if !self.rr.contains(&client) {
            self.rr.push_back(client);
        }
    }

    /// The next request in client rotation (front of the head client's
    /// FIFO), without dequeuing it.
    fn peek_next(&self) -> Option<&GenRequest> {
        let client = self.rr.front()?;
        self.queues.get(client).and_then(|q| q.front())
    }

    /// Dequeue the request [`Self::peek_next`] pointed at, rotating its
    /// client to the back of the round-robin (or out of it when drained).
    fn pop_next(&mut self) -> Option<GenRequest> {
        let client = *self.rr.front()?;
        let queue = self.queues.get_mut(&client)?;
        let req = queue.pop_front();
        if queue.is_empty() {
            self.queues.remove(&client);
            self.rr.pop_front();
        } else {
            self.rr.rotate_left(1);
        }
        req
    }

    /// Move queued requests into free lanes, highest index first: scoring
    /// (`Backend::nll`) runs through lane 0 and resets it, so keeping
    /// generation out of lane 0 until no other lane is free avoids a
    /// full-window re-prefill per token under mixed traffic (the engine's
    /// prefix guard makes the clobber safe either way).
    ///
    /// Admission order is round-robin across client connections
    /// (per-client FIFO): with several clients queued, each free lane
    /// goes to the next client in rotation, so one connection submitting
    /// many requests cannot starve the others. A single client degrades
    /// to the old strict global FIFO.
    ///
    /// On KV-metered backends ([`Backend::kv_stats`]), admission is also
    /// gated on block memory: a request reserves enough blocks for its
    /// worst case (prompt + capped token budget, clipped to the window),
    /// and the head of the rotation stalls — the rotation does not skip
    /// it, so there is still no starvation — until evictions free that
    /// many unpromised blocks. A request too big to ever fit reserves the
    /// whole arena and is admitted alone; if it outgrows the arena
    /// mid-decode the exhaustion path below evicts it with `kv exhausted`
    /// rather than wedging the sweep.
    fn admit(&mut self, be: &mut dyn Backend) {
        let stats = be.kv_stats();
        let mut avail = match &stats {
            Some(st) => {
                // blocks promised to resident sequences but not yet drawn
                let outstanding: usize = self
                    .slots
                    .iter()
                    .enumerate()
                    .filter_map(|(i, s)| {
                        s.as_ref().map(|seq| {
                            let held = st.lane_blocks.get(i).copied().unwrap_or(0);
                            seq.reserved.saturating_sub(held)
                        })
                    })
                    .sum();
                st.free_blocks.saturating_sub(outstanding)
            }
            None => usize::MAX,
        };
        let seq_cap = be.seq();
        for lane in (0..self.slots.len()).rev() {
            if self.slots[lane].is_some() {
                continue;
            }
            let Some(front) = self.peek_next() else { return };
            let mut reserved = 0usize;
            if let Some(st) = &stats {
                let prompt_len = front.prompt.len().max(1); // pad-seeded
                let worst = prompt_len
                    .saturating_add(front.max_new.min(self.max_new_cap))
                    .min(seq_cap);
                let bl = st.block_len.max(1);
                reserved = blocks_for(worst, bl).clamp(1, st.total_blocks.max(1));
                if reserved > avail {
                    return; // backpressure: wait for an eviction
                }
                avail -= reserved;
            }
            let req = self.pop_next().expect("peek_next() was Some");
            be.reset_lane(lane);
            let mut text = req.prompt;
            if text.is_empty() {
                // seed with the pad byte so the first step has a position
                text.push(ByteTokenizer::PAD);
            }
            self.slots[lane] = Some(ActiveSeq {
                text,
                generated: 0,
                remaining: req.max_new.min(self.max_new_cap),
                temperature: req.temperature,
                rng: Pcg32::seeded(req.seed),
                reply: req.reply,
                reserved,
            });
        }
    }

    /// Drive one backend sweep for a group of active lanes with the
    /// eviction policy shared by the plain and speculative paths: a sweep
    /// refused for KV memory (typed [`KvExhausted`]) evicts the group's
    /// lowest-progress sequence — its client gets `Error("kv exhausted")`
    /// — and retries with the survivors; any other failure poisons every
    /// lane in the group (the backend's state is not trustworthy after
    /// it). Returns the surviving lanes and their per-lane results.
    fn sweep_group<T>(
        &mut self,
        be: &mut dyn Backend,
        mut idxs: Vec<usize>,
        run: impl Fn(&mut dyn Backend, &[(usize, &[u8])]) -> Result<Vec<T>>,
    ) -> (Vec<usize>, Vec<T>) {
        loop {
            if idxs.is_empty() {
                return (idxs, Vec::new());
            }
            let res = {
                let reqs: Vec<(usize, &[u8])> = idxs
                    .iter()
                    .map(|&i| (i, self.slots[i].as_ref().unwrap().text.as_slice()))
                    .collect();
                run(be, &reqs)
            };
            match res {
                Ok(out) => return (idxs, out),
                Err(e) if e.downcast_ref::<KvExhausted>().is_some() => {
                    // memory backpressure, not a broken backend: free
                    // blocks by evicting the lowest-progress sequence
                    // (least work lost; ties evict the highest lane, the
                    // most recent admission) and retry with the rest
                    let victim = idxs
                        .iter()
                        .copied()
                        .min_by_key(|&i| {
                            (self.slots[i].as_ref().unwrap().generated, Reverse(i))
                        })
                        .expect("exhausted sweep with no active lanes");
                    if let Some(seq) = self.slots[victim].take() {
                        let _ = seq.reply.send(GenEvent::Error("kv exhausted".into()));
                    }
                    be.reset_lane(victim);
                    idxs.retain(|&i| i != victim);
                }
                Err(e) => {
                    // a decode failure poisons every lane in the group:
                    // report and drain so the serve loop does not spin
                    let msg = e.to_string();
                    for &i in &idxs {
                        if let Some(seq) = self.slots[i].take() {
                            let _ = seq.reply.send(GenEvent::Error(msg.clone()));
                        }
                        be.reset_lane(i);
                    }
                    return (Vec::new(), Vec::new());
                }
            }
        }
    }

    /// Stream `bytes` to lane `i`'s client (clamped to its remaining
    /// budget), then evict on budget exhaustion or a dead client. Returns
    /// bytes actually produced.
    fn commit_bytes(&mut self, be: &mut dyn Backend, i: usize, bytes: &[u8]) -> usize {
        let slot = &mut self.slots[i];
        let seq = slot.as_mut().unwrap();
        let mut produced = 0usize;
        let mut alive = true;
        for &b in bytes {
            if seq.remaining == 0 {
                break; // speculative overshoot past the budget: dropped
            }
            seq.text.push(b);
            seq.generated += 1;
            seq.remaining -= 1;
            produced += 1;
            alive = seq.reply.send(GenEvent::Token(b)).is_ok();
            if !alive {
                break;
            }
        }
        let exhausted = seq.remaining == 0;
        if exhausted || !alive {
            let seq = slot.take().unwrap();
            if exhausted {
                let _ = seq
                    .reply
                    .send(GenEvent::Done { text: seq.text, generated: seq.generated });
            }
            be.reset_lane(i); // free the KV lane for the next admission
        }
        produced
    }

    /// One continuous-batching step: admit, decode every active lane —
    /// greedy lanes speculatively via [`Backend::decode_batch_spec`] when
    /// a [`SpecConfig`] is enabled (1 to `k + 1` verified bytes each),
    /// sampling lanes via a plain [`Backend::decode_batch`] sweep — then
    /// stream the new bytes and evict exhausted or abandoned sequences
    /// (freeing their lanes for the next step's admissions). Returns
    /// bytes produced across all lanes.
    pub fn step(&mut self, be: &mut dyn Backend) -> usize {
        self.admit(be);
        let idxs: Vec<usize> = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.is_some())
            .map(|(i, _)| i)
            .collect();
        if idxs.is_empty() {
            return 0;
        }
        let use_spec = self.spec.enabled && self.spec.k > 0;
        let (spec_idxs, plain_idxs): (Vec<usize>, Vec<usize>) = idxs
            .into_iter()
            .partition(|&i| use_spec && self.slots[i].as_ref().unwrap().temperature <= 0.0);
        let mut produced = 0usize;
        if !spec_idxs.is_empty() {
            // clamp the draft width to the group's tightest remaining
            // budget: admission reserved KV blocks for prompt + max_new
            // only, so a round must never grow a lane's KV past that
            // worst case (and drafts beyond the budget would be verified
            // just to be dropped). A lane with `remaining == 1` pulls the
            // group to k = 0 for one plain round — it is evicted at the
            // end of it.
            let min_remaining = spec_idxs
                .iter()
                .map(|&i| self.slots[i].as_ref().unwrap().remaining)
                .min()
                .unwrap_or(1);
            let k = self.spec.k.min(min_remaining.saturating_sub(1));
            let (alive, rounds) = self.sweep_group(
                be,
                spec_idxs,
                |be: &mut dyn Backend, reqs: &[(usize, &[u8])]| be.decode_batch_spec(reqs, k),
            );
            for (&i, round) in alive.iter().zip(rounds) {
                produced += self.commit_bytes(be, i, &round.bytes);
            }
        }
        if !plain_idxs.is_empty() {
            let (alive, rows) = self.sweep_group(
                be,
                plain_idxs,
                |be: &mut dyn Backend, reqs: &[(usize, &[u8])]| be.decode_batch(reqs),
            );
            for (&i, row) in alive.iter().zip(rows) {
                let next = {
                    let seq = self.slots[i].as_mut().unwrap();
                    sample_logits(&row, seq.temperature, &mut seq.rng) as u8
                };
                produced += self.commit_bytes(be, i, &[next]);
            }
        }
        produced
    }
}

/// Aggregate W-bits across layers (weighted by element count).
pub fn aggregate_wbits(results: &[LayerResult]) -> f64 {
    let total_elems: f64 = results.iter().map(|r| (r.rows * r.cols) as f64).sum();
    let total_bits: f64 = results.iter().map(|r| r.bits).sum();
    if total_elems == 0.0 {
        0.0
    } else {
        total_bits / total_elems
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib;
    use crate::model::testing::micro_weights;
    use crate::quant::by_name;

    fn calibrated() -> (crate::model::Weights, CtxMap) {
        let w = micro_weights(11);
        let win: Vec<u8> = (0..12u8).map(|i| i.wrapping_mul(37)).collect();
        let win2: Vec<u8> = (0..12u8).map(|i| i.wrapping_mul(11).wrapping_add(3)).collect();
        let c = calib::collect(&w, &[&win, &win2]).contexts().unwrap();
        (w, c)
    }

    #[test]
    fn quantizes_every_layer_once() {
        let (mut w, c) = calibrated();
        let q = by_name("rtn").unwrap();
        let res = quantize_model(&mut w, &c, q.as_ref(), &QuantJobConfig { workers: 3, quiet: true })
            .unwrap();
        assert_eq!(res.len(), w.config.linear_names().len());
        let mut names: Vec<&str> = res.iter().map(|r| r.name.as_str()).collect();
        names.dedup();
        assert_eq!(names.len(), res.len(), "duplicate layer results");
    }

    #[test]
    fn worker_count_does_not_change_output() {
        let q = by_name("hbllm-row").unwrap();
        let mut outs = Vec::new();
        for workers in [1usize, 4] {
            let (mut w, c) = calibrated();
            quantize_model(&mut w, &c, q.as_ref(), &QuantJobConfig { workers, quiet: true })
                .unwrap();
            outs.push(w.get("l0.wq").as_mat().clone());
        }
        assert_eq!(outs[0].data, outs[1].data, "nondeterministic across worker counts");
    }

    #[test]
    fn weights_actually_change() {
        let (mut w, c) = calibrated();
        let before = w.get("l1.w2").as_mat().clone();
        let q = by_name("billm").unwrap();
        quantize_model(&mut w, &c, q.as_ref(), &QuantJobConfig { workers: 2, quiet: true }).unwrap();
        let after = w.get("l1.w2").as_mat();
        assert!(before.mse(after) > 0.0, "weights unchanged");
        // non-linear tensors untouched
        assert_eq!(w.get("tok_emb").as_mat().data.len(), 256 * 16);
    }

    #[test]
    fn aggregate_wbits_weighted() {
        let res = vec![
            LayerResult { name: "a".into(), mse: 0.0, wbits: 1.0, bits: 100.0, seconds: 0.0, rows: 10, cols: 10 },
            LayerResult { name: "b".into(), mse: 0.0, wbits: 2.0, bits: 600.0, seconds: 0.0, rows: 10, cols: 30 },
        ];
        let agg = aggregate_wbits(&res);
        assert!((agg - 700.0 / 400.0).abs() < 1e-12);
    }
}

#[cfg(test)]
mod gen_tests {
    use super::*;
    use std::sync::mpsc::{channel, Receiver};

    /// Deterministic stateless backend: the next token is always
    /// `last_byte + 1`. Exercises the trait's default single-lane
    /// `decode_batch` fallback alongside the scheduler.
    struct MockBackend {
        lanes: usize,
        resets: usize,
    }

    impl Backend for MockBackend {
        fn name(&self) -> String {
            "mock".into()
        }
        fn batch(&self) -> usize {
            1
        }
        fn seq(&self) -> usize {
            32
        }
        fn vocab(&self) -> usize {
            256
        }
        fn nll(&mut self, _tokens: &[i32]) -> Result<Vec<f32>> {
            anyhow::bail!("mock backend scores nothing")
        }
        fn logits(&mut self, _tokens: &[i32]) -> Result<Vec<f32>> {
            anyhow::bail!("mock backend scores nothing")
        }
        fn decode_step(&mut self, text: &[u8]) -> Result<Vec<f32>> {
            let last = *text.last().unwrap_or(&0);
            let mut row = vec![0.0f32; 256];
            row[last.wrapping_add(1) as usize] = 1.0;
            Ok(row)
        }
        fn reset(&mut self) {
            self.resets += 1;
        }
        fn lanes(&self) -> usize {
            self.lanes
        }
    }

    fn submit(sched: &mut GenScheduler, prompt: &[u8], max_new: usize) -> Receiver<GenEvent> {
        submit_for(sched, 0, prompt, max_new)
    }

    fn submit_for(
        sched: &mut GenScheduler,
        client: u64,
        prompt: &[u8],
        max_new: usize,
    ) -> Receiver<GenEvent> {
        let (tx, rx) = channel();
        sched.submit(GenRequest {
            prompt: prompt.to_vec(),
            max_new,
            temperature: 0.0,
            seed: 0,
            client,
            reply: tx,
        });
        rx
    }

    #[test]
    fn continuous_batching_admits_and_evicts() {
        let mut be = MockBackend { lanes: 2, resets: 0 };
        let mut sched = GenScheduler::new(2, 64);
        let rxs: Vec<Receiver<GenEvent>> =
            (0..3u8).map(|i| submit(&mut sched, &[b'a' + i], 3)).collect();
        assert_eq!(sched.queued(), 3);
        assert_eq!(sched.active(), 0);

        // step 1: two requests admitted, the third waits for an eviction
        assert_eq!(sched.step(&mut be), 2);
        assert_eq!((sched.active(), sched.queued()), (2, 1));

        let mut steps = 1;
        while sched.has_work() {
            assert!(sched.active() <= 2, "over-admitted past the lane count");
            sched.step(&mut be);
            steps += 1;
            assert!(steps < 100, "scheduler failed to drain");
        }
        // 2 lanes × 3 tokens, then the queued request runs 3 more steps
        assert_eq!(steps, 6);
        // the backend saw one lane reset per admission and per eviction
        assert_eq!(be.resets, 6);

        for (i, rx) in rxs.iter().enumerate() {
            let events: Vec<GenEvent> = rx.try_iter().collect();
            assert_eq!(events.len(), 4, "3 tokens + done");
            let b0 = b'a' + i as u8;
            assert_eq!(events[0], GenEvent::Token(b0 + 1));
            match &events[3] {
                GenEvent::Done { text, generated } => {
                    assert_eq!(*generated, 3);
                    assert_eq!(text[..], [b0, b0 + 1, b0 + 2, b0 + 3]);
                }
                other => panic!("expected Done, got {other:?}"),
            }
        }
    }

    /// Two clients, one lane: client A floods the queue before B's single
    /// request arrives; round-robin admission must serve B's request
    /// second, not after all of A's (the starvation the per-client
    /// rotation exists to prevent). Within a client, FIFO order holds.
    #[test]
    fn round_robin_admission_prevents_client_starvation() {
        let mut be = MockBackend { lanes: 1, resets: 0 };
        let mut sched = GenScheduler::new(1, 8);
        let a1 = submit_for(&mut sched, 1, b"a", 2);
        let a2 = submit_for(&mut sched, 1, b"b", 2);
        let a3 = submit_for(&mut sched, 1, b"c", 2);
        let b1 = submit_for(&mut sched, 2, b"x", 2);
        assert_eq!(sched.queued(), 4);

        // completion order is the admission order (one lane, FIFO drain):
        // track when each receiver sees Done relative to the others
        let mut order: Vec<&'static str> = Vec::new();
        let mut check = |done: &mut Vec<&'static str>| {
            for (name, rx) in [("a1", &a1), ("a2", &a2), ("a3", &a3), ("b1", &b1)] {
                if done.contains(&name) {
                    continue;
                }
                if rx.try_iter().any(|e| matches!(e, GenEvent::Done { .. })) {
                    done.push(name);
                }
            }
        };
        let mut steps = 0;
        while sched.has_work() {
            sched.step(&mut be);
            check(&mut order);
            steps += 1;
            assert!(steps < 100, "scheduler failed to drain");
        }
        assert_eq!(
            order,
            vec!["a1", "b1", "a2", "a3"],
            "rotation did not interleave clients"
        );
    }

    #[test]
    fn single_client_keeps_strict_fifo() {
        let mut be = MockBackend { lanes: 1, resets: 0 };
        let mut sched = GenScheduler::new(1, 8);
        let r1 = submit(&mut sched, b"a", 1);
        let r2 = submit(&mut sched, b"b", 1);
        sched.step(&mut be);
        assert!(r1.try_iter().any(|e| matches!(e, GenEvent::Done { .. })));
        assert!(!r2.try_iter().any(|e| matches!(e, GenEvent::Done { .. })));
        while sched.has_work() {
            sched.step(&mut be);
        }
        assert!(r2.try_iter().any(|e| matches!(e, GenEvent::Done { .. })));
    }

    /// Speculative scheduling over the native backend: greedy requests
    /// decode through `decode_batch_spec` (several bytes per step —
    /// observable as fewer steps than tokens), outputs match the plain
    /// scheduler byte for byte, and acceptance stats accumulate.
    #[test]
    fn spec_scheduler_matches_plain_and_commits_multibyte_steps() {
        use crate::engine::{NativeBackend, PackedModel, SpecConfig};
        use crate::model::testing::micro_weights;
        let w = micro_weights(43);
        let n_new = 8;
        let run = |spec: SpecConfig| {
            let mut be =
                NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
            be.set_lanes(2);
            let eff = be.set_spec(spec);
            let mut sched = GenScheduler::with_spec(2, 64, eff);
            let rx_a = submit(&mut sched, b"ta ki", n_new);
            let rx_b = submit(&mut sched, b"vo", n_new);
            let mut steps = 0usize;
            while sched.has_work() {
                sched.step(&mut be);
                steps += 1;
                assert!(steps < 100, "failed to drain");
            }
            let text = |rx: Receiver<GenEvent>| {
                let mut toks = Vec::new();
                for ev in rx.try_iter() {
                    match ev {
                        GenEvent::Token(b) => toks.push(b),
                        GenEvent::Done { generated, .. } => assert_eq!(generated, n_new),
                        GenEvent::Error(e) => panic!("unexpected error {e}"),
                    }
                }
                toks
            };
            (text(rx_a), text(rx_b), steps, be.spec_stats().unwrap())
        };
        let (pa, pb, plain_steps, _) = run(SpecConfig::disabled());
        let (sa, sb, spec_steps, stats) = run(SpecConfig::with_k(3));
        assert_eq!(sa, pa, "speculative lane A diverged from plain");
        assert_eq!(sb, pb, "speculative lane B diverged from plain");
        assert!(
            spec_steps <= plain_steps,
            "speculation took more steps ({spec_steps} > {plain_steps})"
        );
        assert!(stats.rounds > 0 && stats.drafted > 0, "no speculation happened: {stats:?}");
    }

    /// Speculation must respect admission's KV reservation: with 1-token
    /// blocks and an arena sized exactly to one request's worst case
    /// (prompt 2 + max_new 2 = 4 blocks), an unclamped k = 4 verify sweep
    /// would need 6 blocks and evict a request admission had guaranteed —
    /// the per-round clamp to the remaining budget keeps it inside.
    #[test]
    fn spec_rounds_respect_admission_reservations() {
        use crate::engine::{NativeBackend, PackedModel, SpecConfig};
        use crate::model::testing::micro_weights;
        let w = micro_weights(44);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(1);
        be.set_kv_blocks(Some(4), Some(1));
        let eff = be.set_spec(SpecConfig::with_k(4));
        let mut sched = GenScheduler::with_spec(1, 2, eff);
        let rx = submit(&mut sched, b"ab", 2);
        let mut steps = 0;
        while sched.has_work() {
            sched.step(&mut be);
            steps += 1;
            assert!(steps < 20, "spec round wedged the scheduler");
        }
        let events: Vec<GenEvent> = rx.try_iter().collect();
        assert!(
            matches!(events.last(), Some(GenEvent::Done { generated: 2, .. })),
            "request inside its reservation was evicted: {events:?}"
        );
    }

    #[test]
    fn abandoned_request_is_evicted() {
        let mut be = MockBackend { lanes: 2, resets: 0 };
        let mut sched = GenScheduler::new(2, 64);
        let keep = submit(&mut sched, b"x", 4);
        let gone = submit(&mut sched, b"y", 4);
        drop(gone);
        sched.step(&mut be);
        assert_eq!(sched.active(), 1, "dead client's lane not reclaimed");
        while sched.has_work() {
            sched.step(&mut be);
        }
        let events: Vec<GenEvent> = keep.try_iter().collect();
        assert_eq!(events.len(), 5, "surviving request unaffected");
    }

    #[test]
    fn max_new_is_capped_on_admission() {
        let mut be = MockBackend { lanes: 1, resets: 0 };
        let mut sched = GenScheduler::new(1, 4);
        let rx = submit(&mut sched, b"q", 1000);
        while sched.has_work() {
            sched.step(&mut be);
        }
        let done = rx.try_iter().last().unwrap();
        match done {
            GenEvent::Done { generated, .. } => assert_eq!(generated, 4),
            other => panic!("expected Done, got {other:?}"),
        }
    }

    #[test]
    fn zero_token_and_empty_prompt_requests() {
        let mut be = MockBackend { lanes: 1, resets: 0 };
        let mut sched = GenScheduler::new(1, 8);
        // zero tokens: immediate Done, never queued
        let rx0 = submit(&mut sched, b"abc", 0);
        assert!(!sched.has_work());
        assert_eq!(
            rx0.try_iter().next(),
            Some(GenEvent::Done { text: b"abc".to_vec(), generated: 0 })
        );
        // empty prompt: pad-seeded, still produces tokens
        let rx = submit(&mut sched, b"", 2);
        while sched.has_work() {
            sched.step(&mut be);
        }
        let events: Vec<GenEvent> = rx.try_iter().collect();
        match events.last().unwrap() {
            GenEvent::Done { text, generated } => {
                assert_eq!(*generated, 2);
                assert_eq!(text.len(), 3, "pad seed + 2 tokens");
            }
            other => panic!("expected Done, got {other:?}"),
        }
    }

    #[test]
    fn admission_stalls_on_block_exhaustion_and_resumes_after_done() {
        use crate::engine::{NativeBackend, PackedModel};
        use crate::model::testing::micro_weights;
        // 2 lanes, but only 3 blocks of 4 tokens: each request below
        // reserves 2 blocks (4-byte prompt + 4 new tokens), so just one
        // fits at a time — the second must wait for the first's eviction
        let w = micro_weights(40);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(2);
        be.set_kv_blocks(Some(3), Some(4));
        let mut sched = GenScheduler::new(2, 64);
        let rx_a = submit(&mut sched, b"abcd", 4);
        let rx_b = submit(&mut sched, b"wxyz", 4);

        assert_eq!(sched.step(&mut be), 1, "only one lane admitted");
        assert_eq!((sched.active(), sched.queued()), (1, 1), "admission did not stall");
        for _ in 0..3 {
            sched.step(&mut be);
            assert!(sched.active() <= 1, "stalled request admitted early");
        }
        // first request done (4 tokens) -> its blocks freed -> b admits
        let done_a = rx_a.try_iter().last().unwrap();
        assert!(matches!(done_a, GenEvent::Done { generated: 4, .. }), "{done_a:?}");
        let mut steps = 0;
        while sched.has_work() {
            sched.step(&mut be);
            steps += 1;
            assert!(steps < 50, "stalled request never resumed");
        }
        let done_b = rx_b.try_iter().last().unwrap();
        assert!(matches!(done_b, GenEvent::Done { generated: 4, .. }), "{done_b:?}");
    }

    #[test]
    fn memory_eviction_reports_kv_exhausted_without_wedging() {
        use crate::engine::{NativeBackend, PackedModel};
        use crate::model::testing::micro_weights;
        // one 4-token block total: a request needing two blocks is
        // admitted alone (reservation clamps to the arena) and must be
        // evicted mid-decode with "kv exhausted", not wedge the loop
        let w = micro_weights(41);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(2);
        be.set_kv_blocks(Some(1), Some(4));
        let mut sched = GenScheduler::new(2, 64);
        let rx = submit(&mut sched, b"abcd", 6);
        let mut steps = 0;
        while sched.has_work() {
            sched.step(&mut be);
            steps += 1;
            assert!(steps < 50, "exhausted sequence wedged the scheduler");
        }
        let events: Vec<GenEvent> = rx.try_iter().collect();
        assert!(
            matches!(events.last(), Some(GenEvent::Error(msg)) if msg.as_str() == "kv exhausted"),
            "expected kv exhausted eviction, got {events:?}"
        );
        // the arena is fully released: a request that fits completes
        let rx2 = submit(&mut sched, b"ab", 2);
        while sched.has_work() {
            sched.step(&mut be);
        }
        let done = rx2.try_iter().last().unwrap();
        assert!(matches!(done, GenEvent::Done { generated: 2, .. }), "{done:?}");
    }

    #[test]
    fn decode_failure_reports_and_drains() {
        struct FailBackend;
        impl Backend for FailBackend {
            fn name(&self) -> String {
                "fail".into()
            }
            fn batch(&self) -> usize {
                1
            }
            fn seq(&self) -> usize {
                8
            }
            fn vocab(&self) -> usize {
                256
            }
            fn nll(&mut self, _: &[i32]) -> Result<Vec<f32>> {
                anyhow::bail!("no")
            }
            fn logits(&mut self, _: &[i32]) -> Result<Vec<f32>> {
                anyhow::bail!("no")
            }
            fn decode_step(&mut self, _: &[u8]) -> Result<Vec<f32>> {
                anyhow::bail!("device lost")
            }
            fn reset(&mut self) {}
        }
        let mut be = FailBackend;
        let mut sched = GenScheduler::new(1, 8);
        let rx = submit(&mut sched, b"x", 4);
        sched.step(&mut be);
        assert!(!sched.has_work(), "failed lanes must drain");
        match rx.try_iter().next().unwrap() {
            GenEvent::Error(msg) => assert!(msg.contains("device lost")),
            other => panic!("expected Error, got {other:?}"),
        }
    }
}
