//! Per-layer quantization job scheduler: a deterministic work-stealing pool
//! over the model's linear layers.
//!
//! Invariants (property-tested): every layer quantized exactly once, output
//! independent of worker count, original weights untouched on failure.

use super::progress::Progress;
use crate::calib::CtxMap;
use crate::model::Weights;
use crate::quant::{BitsBreakdown, Quantizer};
use crate::tensor::Matrix;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Clone)]
pub struct QuantJobConfig {
    pub workers: usize,
    pub quiet: bool,
}

impl Default for QuantJobConfig {
    fn default() -> Self {
        let workers = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
        QuantJobConfig { workers: workers.min(8), quiet: false }
    }
}

#[derive(Clone, Debug)]
pub struct LayerResult {
    pub name: String,
    pub mse: f64,
    pub wbits: f64,
    pub bits: f64,
    pub seconds: f64,
    pub rows: usize,
    pub cols: usize,
}

/// Quantize every linear layer of `weights` in place with `method`, using
/// the Hessians in `calib`. Returns per-layer metrics (sorted by name).
///
/// Matrices are stored [in, out] (x @ W); the quantizer contract is paper
/// orientation [out, in], so each layer transposes in and back out.
pub fn quantize_model(
    weights: &mut Weights,
    ctxs: &CtxMap,
    method: &dyn Quantizer,
    cfg: &QuantJobConfig,
) -> Result<Vec<LayerResult>> {
    let names = weights.config.linear_names();
    let progress = Progress::new(&method.name(), names.len(), cfg.quiet);
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<(String, Matrix, LayerResult)>> = Mutex::new(Vec::new());
    let failure: Mutex<Option<String>> = Mutex::new(None);

    std::thread::scope(|s| {
        for _ in 0..cfg.workers.max(1) {
            s.spawn(|| loop {
                let idx = next.fetch_add(1, Ordering::Relaxed);
                if idx >= names.len() {
                    return;
                }
                let name = &names[idx];
                let run = || -> Result<(Matrix, LayerResult)> {
                    let w_model = weights.get(name).as_mat(); // [in, out]
                    let w_paper = w_model.transpose(); // [out, in]
                    let ctx = ctxs.for_linear(name)?;
                    let t0 = Instant::now();
                    let out = method.quantize(&w_paper, &ctx);
                    let seconds = t0.elapsed().as_secs_f64();
                    let bits: BitsBreakdown = out.bits;
                    let res = LayerResult {
                        name: name.clone(),
                        mse: out.mse,
                        wbits: bits.per_weight(w_paper.rows, w_paper.cols),
                        bits: bits.total(),
                        seconds,
                        rows: w_paper.rows,
                        cols: w_paper.cols,
                    };
                    Ok((out.w_hat.transpose(), res))
                };
                match run() {
                    Ok((w_hat_model, res)) => {
                        progress.tick(name);
                        results.lock().unwrap().push((name.clone(), w_hat_model, res));
                    }
                    Err(e) => {
                        *failure.lock().unwrap() = Some(format!("{name}: {e}"));
                        return;
                    }
                }
            });
        }
    });

    if let Some(msg) = failure.into_inner().unwrap() {
        // leave `weights` untouched on failure
        return Err(anyhow!("quantization failed: {msg}"));
    }
    let mut results = results.into_inner().unwrap();
    results.sort_by(|a, b| a.0.cmp(&b.0));
    let mut metrics = Vec::with_capacity(results.len());
    for (name, w_hat, res) in results {
        weights.set_matrix(&name, w_hat);
        metrics.push(res);
    }
    Ok(metrics)
}

/// Aggregate W-bits across layers (weighted by element count).
pub fn aggregate_wbits(results: &[LayerResult]) -> f64 {
    let total_elems: f64 = results.iter().map(|r| (r.rows * r.cols) as f64).sum();
    let total_bits: f64 = results.iter().map(|r| r.bits).sum();
    if total_elems == 0.0 {
        0.0
    } else {
        total_bits / total_elems
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calib;
    use crate::model::testing::micro_weights;
    use crate::quant::by_name;

    fn calibrated() -> (crate::model::Weights, CtxMap) {
        let w = micro_weights(11);
        let win: Vec<u8> = (0..12u8).map(|i| i.wrapping_mul(37)).collect();
        let win2: Vec<u8> = (0..12u8).map(|i| i.wrapping_mul(11).wrapping_add(3)).collect();
        let c = calib::collect(&w, &[&win, &win2]).contexts().unwrap();
        (w, c)
    }

    #[test]
    fn quantizes_every_layer_once() {
        let (mut w, c) = calibrated();
        let q = by_name("rtn").unwrap();
        let res = quantize_model(&mut w, &c, q.as_ref(), &QuantJobConfig { workers: 3, quiet: true })
            .unwrap();
        assert_eq!(res.len(), w.config.linear_names().len());
        let mut names: Vec<&str> = res.iter().map(|r| r.name.as_str()).collect();
        names.dedup();
        assert_eq!(names.len(), res.len(), "duplicate layer results");
    }

    #[test]
    fn worker_count_does_not_change_output() {
        let q = by_name("hbllm-row").unwrap();
        let mut outs = Vec::new();
        for workers in [1usize, 4] {
            let (mut w, c) = calibrated();
            quantize_model(&mut w, &c, q.as_ref(), &QuantJobConfig { workers, quiet: true })
                .unwrap();
            outs.push(w.get("l0.wq").as_mat().clone());
        }
        assert_eq!(outs[0].data, outs[1].data, "nondeterministic across worker counts");
    }

    #[test]
    fn weights_actually_change() {
        let (mut w, c) = calibrated();
        let before = w.get("l1.w2").as_mat().clone();
        let q = by_name("billm").unwrap();
        quantize_model(&mut w, &c, q.as_ref(), &QuantJobConfig { workers: 2, quiet: true }).unwrap();
        let after = w.get("l1.w2").as_mat();
        assert!(before.mse(after) > 0.0, "weights unchanged");
        // non-linear tensors untouched
        assert_eq!(w.get("tok_emb").as_mat().data.len(), 256 * 16);
    }

    #[test]
    fn aggregate_wbits_weighted() {
        let res = vec![
            LayerResult { name: "a".into(), mse: 0.0, wbits: 1.0, bits: 100.0, seconds: 0.0, rows: 10, cols: 10 },
            LayerResult { name: "b".into(), mse: 0.0, wbits: 2.0, bits: 600.0, seconds: 0.0, rows: 10, cols: 30 },
        ];
        let agg = aggregate_wbits(&res);
        assert!((agg - 700.0 / 400.0).abs() < 1e-12);
    }
}
