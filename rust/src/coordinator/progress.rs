//! Thread-safe progress/metrics collector for long-running jobs.
//!
//! Console output goes through the leveled [`crate::util::log`] shim
//! (single-line `key=value` records at info level, filtered by
//! `HBLLM_LOG`); the in-memory message log keeps the compact
//! `[label] done/total item (elapsed)` format callers assert on.

use crate::util::log;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

pub struct Progress {
    pub total: usize,
    done: AtomicUsize,
    started: Instant,
    label: String,
    quiet: bool,
    log: Mutex<Vec<String>>,
}

impl Progress {
    pub fn new(label: &str, total: usize, quiet: bool) -> Progress {
        Progress {
            total,
            done: AtomicUsize::new(0),
            started: Instant::now(),
            label: label.to_string(),
            quiet,
            log: Mutex::new(Vec::new()),
        }
    }

    pub fn tick(&self, item: &str) {
        let done = self.done.fetch_add(1, Ordering::Relaxed) + 1;
        let elapsed = self.started.elapsed().as_secs_f64();
        let msg = format!("[{}] {}/{} {} ({:.1}s)", self.label, done, self.total, item, elapsed);
        if !self.quiet {
            log::info(&format!(
                "event=progress job={} done={done} total={} item={item} elapsed_s={elapsed:.1}",
                self.label, self.total
            ));
        }
        self.log.lock().unwrap().push(msg);
    }

    pub fn done_count(&self) -> usize {
        self.done.load(Ordering::Relaxed)
    }

    pub fn elapsed_s(&self) -> f64 {
        self.started.elapsed().as_secs_f64()
    }

    pub fn messages(&self) -> Vec<String> {
        self.log.lock().unwrap().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_ticks() {
        let p = Progress::new("test", 3, true);
        p.tick("a");
        p.tick("b");
        assert_eq!(p.done_count(), 2);
        assert_eq!(p.messages().len(), 2);
        assert!(p.messages()[0].contains("1/3"));
    }

    #[test]
    fn thread_safe() {
        let p = std::sync::Arc::new(Progress::new("mt", 100, true));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let p = p.clone();
                s.spawn(move || {
                    for _ in 0..25 {
                        p.tick("x");
                    }
                });
            }
        });
        assert_eq!(p.done_count(), 100);
    }
}
