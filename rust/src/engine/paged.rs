//! Paged KV memory: a shared arena of fixed-size token blocks plus
//! per-sequence block tables — the vLLM/PagedAttention idea applied to the
//! packed 1-bit engine, where the weights are tiny (~1.06 bits/weight) and
//! resident memory is dominated by KV state.
//!
//! The flat layout this replaces allocated one worst-case
//! `[n_layers, seq, d]` K and V buffer per lane, so lane count was a hard
//! memory ceiling even when most sequences are short. Here the memory is
//! one [`KvBlockPool`] — a `[n_blocks, n_layers, block_len, d]` arena per
//! side with a free list — and each lane holds a [`PagedKv`]: a block
//! table mapping logical positions to pool blocks, growing one block at a
//! time on demand and releasing every block on eviction or reset. Short
//! sequences hold few blocks, so many more lanes fit in the same arena;
//! when the pool runs dry, allocation fails with the typed [`KvExhausted`]
//! error and the serving scheduler applies backpressure (queue stalls,
//! lowest-progress eviction) instead of OOMing.
//!
//! Invariants (property-tested in this module and, heavier, in
//! `tests/paged_parity.rs`):
//!
//! * a block is owned by at most one live sequence — alloc never hands out
//!   a block that has not been released, release of an unowned block
//!   panics (double-free is a logic error, not a recoverable state);
//! * `free_blocks() + used_blocks() == n_blocks()` at every step;
//! * the logical↔physical mapping round-trips: position `p` lives at
//!   `(table[p / block_len], p % block_len)` and reads back exactly what
//!   was stored.
//!
//! The per-position *arithmetic* of the decode path is unchanged — only
//! the storage layout differs — so paged and flat-configured engines
//! (`block_len == seq_len`, one block per lane) produce byte-identical
//! greedy decodes; `tests/paged_parity.rs` pins that down.

use std::fmt;

/// Default tokens per KV block (CLI `--block-len`). Small enough that a
/// short sequence wastes little, large enough that the block-table
/// indirection stays a rounding error of the attention gather.
pub const DEFAULT_BLOCK_LEN: usize = 16;

/// The shared block pool has no free block for a requested allocation.
///
/// Carried as the typed source of the `anyhow` error the engine returns,
/// so the scheduler can distinguish memory backpressure (evict the
/// lowest-progress sequence, retry) from a genuine decode failure
/// (poison every lane).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvExhausted {
    /// Blocks the failing operation needed.
    pub needed: usize,
    /// Blocks that were actually available.
    pub free: usize,
}

impl fmt::Display for KvExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "kv exhausted: need {} block(s), {} free",
            self.needed, self.free
        )
    }
}

impl std::error::Error for KvExhausted {}

/// Blocks needed to hold `positions` KV rows at the given block length.
pub fn blocks_for(positions: usize, block_len: usize) -> usize {
    debug_assert!(block_len > 0);
    (positions + block_len - 1) / block_len
}

/// One shared arena of fixed-size KV token blocks with a free list.
///
/// Layout per side (K and V): `[n_blocks, n_layers, block_len, d]` f32,
/// allocated once at construction. Blocks are the unit of allocation;
/// a block stores `block_len` consecutive token positions for *all*
/// layers of one sequence.
pub struct KvBlockPool {
    n_layers: usize,
    d: usize,
    block_len: usize,
    n_blocks: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Free-list stack; initialized so blocks are handed out in index
    /// order (deterministic for tests).
    free: Vec<usize>,
    /// Per-block ownership bit — the double-free/alias guard.
    live: Vec<bool>,
    /// High-water mark of concurrently allocated blocks over the pool's
    /// lifetime — the capacity-planning signal surfaced through
    /// `KvStats::used_hwm` and the `hbllm_kv_blocks_used_hwm` gauge.
    used_hwm: usize,
}

impl KvBlockPool {
    /// Allocate an arena of `n_blocks` blocks of `block_len` tokens each
    /// (both clamped to at least 1).
    pub fn new(n_layers: usize, d: usize, n_blocks: usize, block_len: usize) -> KvBlockPool {
        let n_blocks = n_blocks.max(1);
        let block_len = block_len.max(1);
        let elems = n_blocks * n_layers * block_len * d;
        KvBlockPool {
            n_layers,
            d,
            block_len,
            n_blocks,
            k: vec![0.0; elems],
            v: vec![0.0; elems],
            free: (0..n_blocks).rev().collect(),
            live: vec![false; n_blocks],
            used_hwm: 0,
        }
    }

    pub fn block_len(&self) -> usize {
        self.block_len
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    /// Most blocks ever allocated at once (never decreases; 0 until the
    /// first allocation).
    pub fn used_hwm(&self) -> usize {
        self.used_hwm
    }

    /// Total arena bytes (capacity, not fill level) across both sides.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    /// Bytes of one block across both sides.
    pub fn block_bytes(&self) -> usize {
        2 * self.n_layers * self.block_len * self.d * 4
    }

    /// Take a free block. Fails with [`KvExhausted`] when the pool is dry.
    pub fn alloc(&mut self) -> Result<usize, KvExhausted> {
        match self.free.pop() {
            Some(b) => {
                debug_assert!(!self.live[b], "free list handed out a live block");
                self.live[b] = true;
                self.used_hwm = self.used_hwm.max(self.used_blocks());
                Ok(b)
            }
            None => Err(KvExhausted { needed: 1, free: 0 }),
        }
    }

    /// Return a block to the free list. Panics on double-free or an
    /// out-of-range block — both are sequencer logic errors that would
    /// otherwise silently alias KV state across sequences.
    pub fn release(&mut self, block: usize) {
        assert!(block < self.n_blocks, "release of out-of-range kv block {block}");
        assert!(self.live[block], "double free of kv block {block}");
        self.live[block] = false;
        self.free.push(block);
    }

    #[inline]
    fn idx(&self, block: usize, layer: usize, off: usize) -> usize {
        debug_assert!(block < self.n_blocks && layer < self.n_layers && off < self.block_len);
        ((block * self.n_layers + layer) * self.block_len + off) * self.d
    }

    /// Store one position's K/V rows at `(block, layer, off)`.
    pub fn store(&mut self, block: usize, layer: usize, off: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let o = self.idx(block, layer, off);
        self.k[o..o + self.d].copy_from_slice(k_row);
        self.v[o..o + self.d].copy_from_slice(v_row);
    }

    #[inline]
    pub fn key(&self, block: usize, layer: usize, off: usize) -> &[f32] {
        let o = self.idx(block, layer, off);
        &self.k[o..o + self.d]
    }

    #[inline]
    pub fn val(&self, block: usize, layer: usize, off: usize) -> &[f32] {
        let o = self.idx(block, layer, off);
        &self.v[o..o + self.d]
    }
}

/// One sequence's view of the paged KV memory: a block table mapping
/// logical positions to [`KvBlockPool`] blocks, plus the fill level.
///
/// Position `p` lives in table slot `p / block_len` at offset
/// `p % block_len`. The table grows one block at a time through
/// [`PagedKv::ensure_pos`] and releases everything via [`PagedKv::clear`]
/// — a `PagedKv` never outlives its blocks' ownership silently (the pool
/// panics on double-release, and `tests` below cover the interleavings).
pub struct PagedKv {
    /// Logical position cap (the model's `seq_len` — positions beyond it
    /// have no position embedding).
    seq: usize,
    blocks: Vec<usize>,
    len: usize,
}

impl PagedKv {
    /// An empty view (no blocks held) with logical capacity `seq`.
    pub fn new(seq: usize) -> PagedKv {
        PagedKv { seq, blocks: Vec::new(), len: 0 }
    }

    /// Positions filled so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Logical capacity in positions.
    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.seq
    }

    /// Blocks currently held by this sequence.
    pub fn held_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// The block table (pool block index per `block_len` positions).
    pub fn block_table(&self) -> &[usize] {
        &self.blocks
    }

    /// Physical address of logical position `pos`.
    #[inline]
    pub fn physical(&self, pool: &KvBlockPool, pos: usize) -> (usize, usize) {
        let bl = pool.block_len();
        (self.blocks[pos / bl], pos % bl)
    }

    /// Grow the block table (allocating from `pool`) until position `pos`
    /// is addressable. Fails with [`KvExhausted`] when the pool is dry; on
    /// failure the table keeps whatever it grew so far — still a
    /// consistent state, released by the next [`PagedKv::clear`].
    pub fn ensure_pos(&mut self, pool: &mut KvBlockPool, pos: usize) -> Result<(), KvExhausted> {
        debug_assert!(pos < self.seq, "position {pos} beyond seq cap {}", self.seq);
        let need = blocks_for(pos + 1, pool.block_len());
        while self.blocks.len() < need {
            match pool.alloc() {
                Ok(b) => self.blocks.push(b),
                Err(_) => {
                    return Err(KvExhausted {
                        needed: need - self.blocks.len(),
                        free: 0,
                    })
                }
            }
        }
        Ok(())
    }

    /// Store position `pos`'s K/V rows for `layer`. The caller must have
    /// grown the table past `pos` (see [`PagedKv::ensure_pos`]) and bumps
    /// `len` once per position via [`PagedKv::advance`] after all layers.
    pub fn store(
        &self,
        pool: &mut KvBlockPool,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        let bl = pool.block_len();
        pool.store(self.blocks[pos / bl], layer, pos % bl, k_row, v_row);
    }

    #[inline]
    pub fn key<'p>(&self, pool: &'p KvBlockPool, layer: usize, pos: usize) -> &'p [f32] {
        let bl = pool.block_len();
        pool.key(self.blocks[pos / bl], layer, pos % bl)
    }

    #[inline]
    pub fn val<'p>(&self, pool: &'p KvBlockPool, layer: usize, pos: usize) -> &'p [f32] {
        let bl = pool.block_len();
        pool.val(self.blocks[pos / bl], layer, pos % bl)
    }

    pub fn advance(&mut self) {
        debug_assert!(self.len < self.seq, "paged kv overflow");
        self.len += 1;
    }

    /// Roll back to `pos` filled positions, releasing every tail block no
    /// longer needed to address `0..pos` back to `pool`. The speculative
    /// decoder's rejection path: KV rows computed for rejected draft
    /// tokens are dropped and their blocks returned to the free list in
    /// the same call. `pos` must not exceed the current fill level;
    /// `truncate_to(len())` is a no-op that still trims blocks a failed
    /// sweep grew past `len` (see [`PagedKv::ensure_pos`]).
    pub fn truncate_to(&mut self, pool: &mut KvBlockPool, pos: usize) {
        debug_assert!(pos <= self.len, "truncate_to({pos}) beyond fill {}", self.len);
        let keep = blocks_for(pos, pool.block_len());
        for b in self.blocks.drain(keep.min(self.blocks.len())..) {
            pool.release(b);
        }
        self.len = pos.min(self.len);
    }

    /// Logical reset: release every held block back to `pool`.
    pub fn clear(&mut self, pool: &mut KvBlockPool) {
        for b in self.blocks.drain(..) {
            pool.release(b);
        }
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg32;
    use std::collections::BTreeSet;

    #[test]
    fn alloc_release_cycle_and_accounting() {
        let mut pool = KvBlockPool::new(2, 4, 3, 8);
        assert_eq!((pool.n_blocks(), pool.free_blocks(), pool.used_blocks()), (3, 3, 0));
        assert_eq!(pool.used_hwm(), 0, "hwm nonzero before any allocation");
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!((pool.free_blocks(), pool.used_blocks()), (1, 2));
        assert_eq!(pool.used_hwm(), 2);
        pool.release(a);
        assert_eq!(pool.used_hwm(), 2, "hwm must not fall on release");
        let c = pool.alloc().unwrap();
        let d = pool.alloc().unwrap();
        assert_eq!(pool.free_blocks(), 0);
        assert_eq!(pool.used_hwm(), 3, "full arena is the new high water");
        assert_eq!(pool.alloc(), Err(KvExhausted { needed: 1, free: 0 }));
        assert_eq!(c, a, "released block is recycled");
        pool.release(b);
        pool.release(c);
        pool.release(d);
        assert_eq!(pool.free_blocks(), 3);
        assert_eq!(pool.used_hwm(), 3, "hwm survives a full drain");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut pool = KvBlockPool::new(1, 2, 2, 4);
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }

    #[test]
    fn store_and_read_back_via_view() {
        let mut pool = KvBlockPool::new(2, 3, 4, 2);
        let mut kv = PagedKv::new(8);
        for pos in 0..5usize {
            kv.ensure_pos(&mut pool, pos).unwrap();
            for layer in 0..2 {
                let k: Vec<f32> = (0..3).map(|j| (pos * 10 + layer * 100 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                kv.store(&mut pool, layer, pos, &k, &v);
            }
            kv.advance();
        }
        assert_eq!(kv.len(), 5);
        assert_eq!(kv.held_blocks(), 3, "5 positions at block_len 2");
        for pos in 0..5usize {
            for layer in 0..2 {
                let k = kv.key(&pool, layer, pos);
                assert_eq!(k[1], (pos * 10 + layer * 100 + 1) as f32);
                assert_eq!(kv.val(&pool, layer, pos)[0], -((pos * 10 + layer * 100) as f32));
            }
        }
        kv.clear(&mut pool);
        assert_eq!((kv.len(), kv.held_blocks(), pool.free_blocks()), (0, 0, 4));
    }

    #[test]
    fn truncate_to_releases_tail_blocks_and_keeps_live_rows() {
        let mut pool = KvBlockPool::new(1, 2, 4, 2);
        let mut kv = PagedKv::new(8);
        for pos in 0..7usize {
            kv.ensure_pos(&mut pool, pos).unwrap();
            let row = [pos as f32, 0.0];
            kv.store(&mut pool, 0, pos, &row, &row);
            kv.advance();
        }
        assert_eq!((kv.len(), kv.held_blocks(), pool.free_blocks()), (7, 4, 0));
        // roll back to 3 positions: blocks 2 and 3 return to the free list
        kv.truncate_to(&mut pool, 3);
        assert_eq!((kv.len(), kv.held_blocks(), pool.free_blocks()), (3, 2, 2));
        for pos in 0..3usize {
            assert_eq!(kv.key(&pool, 0, pos)[0], pos as f32, "surviving row corrupted");
        }
        // freed blocks are allocatable by a second sequence immediately
        let mut other = PagedKv::new(8);
        other.ensure_pos(&mut pool, 3).unwrap();
        assert_eq!(pool.free_blocks(), 0);
        // truncating to the current length is a no-op
        kv.truncate_to(&mut pool, 3);
        assert_eq!((kv.len(), kv.held_blocks()), (3, 2));
        // truncate to zero == clear
        kv.truncate_to(&mut pool, 0);
        assert_eq!((kv.len(), kv.held_blocks()), (0, 0));
        other.clear(&mut pool);
        assert_eq!(pool.free_blocks(), 4);
    }

    #[test]
    fn ensure_pos_fails_cleanly_when_dry() {
        let mut pool = KvBlockPool::new(1, 2, 2, 2);
        let mut a = PagedKv::new(16);
        let mut b = PagedKv::new(16);
        a.ensure_pos(&mut pool, 3).unwrap(); // 2 blocks
        let err = b.ensure_pos(&mut pool, 0).unwrap_err();
        assert_eq!(err, KvExhausted { needed: 1, free: 0 });
        // pool accounting unharmed; releasing a frees b's path
        a.clear(&mut pool);
        b.ensure_pos(&mut pool, 3).unwrap();
        b.clear(&mut pool);
    }

    #[test]
    fn blocks_for_boundaries() {
        assert_eq!(blocks_for(0, 4), 0);
        assert_eq!(blocks_for(1, 4), 1);
        assert_eq!(blocks_for(4, 4), 1);
        assert_eq!(blocks_for(5, 4), 2);
        assert_eq!(blocks_for(12, 1), 12);
    }

    /// Drive `ops` random alloc-grow/truncate/release steps over `n_seqs`
    /// sequences sharing one pool, verifying after every step: exact
    /// free/used accounting, no block aliased across live sequences, and
    /// `bytes()` constant (the arena never reallocates). Truncation (the
    /// speculative-decode rollback) interleaves with growth and clears so
    /// a partially rolled-back sequence's surviving rows must read back
    /// exactly while its tail blocks are recycled by neighbors.
    fn run_interleaving(seed: u64, n_seqs: usize, n_blocks: usize, block_len: usize, ops: usize) -> Result<(), String> {
        let mut rng = Pcg32::seeded(seed);
        let mut pool = KvBlockPool::new(1, 2, n_blocks, block_len);
        let arena_bytes = pool.bytes();
        let seq_cap = n_blocks * block_len;
        let mut seqs: Vec<PagedKv> = (0..n_seqs).map(|_| PagedKv::new(seq_cap)).collect();
        for step in 0..ops {
            let i = rng.below(n_seqs);
            let dice = rng.f64();
            if dice < 0.6 {
                // grow by one position (may or may not need a block)
                if !seqs[i].is_full() {
                    let pos = seqs[i].len();
                    match seqs[i].ensure_pos(&mut pool, pos) {
                        Ok(()) => {
                            let row = [pos as f32, i as f32];
                            seqs[i].store(&mut pool, 0, pos, &row, &row);
                            seqs[i].advance();
                        }
                        Err(e) => {
                            if pool.free_blocks() != 0 {
                                return Err(format!(
                                    "step {step}: spurious {e} with {} free",
                                    pool.free_blocks()
                                ));
                            }
                        }
                    }
                }
            } else if dice < 0.85 {
                // roll back to a random earlier fill level (spec rejection)
                let pos = rng.below(seqs[i].len() + 1);
                let expect_held = blocks_for(pos, block_len);
                seqs[i].truncate_to(&mut pool, pos);
                if seqs[i].len() != pos {
                    return Err(format!("step {step}: truncate_to({pos}) left len {}", seqs[i].len()));
                }
                if seqs[i].held_blocks() != expect_held {
                    return Err(format!(
                        "step {step}: truncate_to({pos}) holds {} blocks, want {expect_held}",
                        seqs[i].held_blocks()
                    ));
                }
            } else {
                seqs[i].clear(&mut pool);
            }
            // accounting is exact
            let held: usize = seqs.iter().map(|s| s.held_blocks()).sum();
            if held != pool.used_blocks() {
                return Err(format!("step {step}: held {held} != used {}", pool.used_blocks()));
            }
            if pool.free_blocks() + pool.used_blocks() != pool.n_blocks() {
                return Err(format!("step {step}: free+used != total"));
            }
            if pool.bytes() != arena_bytes {
                return Err(format!("step {step}: arena reallocated"));
            }
            // no aliasing across live sequences
            let mut seen: BTreeSet<usize> = BTreeSet::new();
            for s in &seqs {
                for &b in s.block_table() {
                    if !seen.insert(b) {
                        return Err(format!("step {step}: block {b} aliased"));
                    }
                }
            }
            // every sequence's contents survive its neighbors' churn
            for (si, s) in seqs.iter().enumerate() {
                for pos in 0..s.len() {
                    let k = s.key(&pool, 0, pos);
                    if k != [pos as f32, si as f32] {
                        return Err(format!("step {step}: seq {si} pos {pos} corrupted"));
                    }
                }
            }
        }
        Ok(())
    }

    #[test]
    fn prop_interleavings_never_alias_or_leak() {
        check(
            "paged-kv-interleavings",
            40,
            |g| {
                (
                    g.rng.next_u64(),
                    g.size(1, 4),  // sequences
                    g.size(1, 6),  // blocks
                    g.size(1, 5),  // block_len
                    g.size(1, 60), // ops
                )
            },
            |&(seed, n_seqs, n_blocks, block_len, ops)| {
                run_interleaving(seed, n_seqs, n_blocks, block_len, ops)
            },
        );
    }

    /// Heavier version of the interleaving property for the CI `--ignored`
    /// pass: more sequences, more blocks, long op chains.
    #[test]
    #[ignore = "slow: run via cargo test --release -- --ignored"]
    fn prop_interleavings_never_alias_or_leak_heavy() {
        check(
            "paged-kv-interleavings-heavy",
            60,
            |g| {
                (
                    g.rng.next_u64(),
                    g.size(1, 12),
                    g.size(1, 32),
                    g.size(1, 9),
                    g.size(50, 600),
                )
            },
            |&(seed, n_seqs, n_blocks, block_len, ops)| {
                run_interleaving(seed, n_seqs, n_blocks, block_len, ops)
            },
        );
    }

    /// The logical↔physical round-trip law: `physical(p)` is
    /// `(table[p / bl], p % bl)`, every mapped slot is in range, distinct
    /// positions never collide, and stored rows read back exactly.
    #[test]
    fn prop_logical_physical_roundtrip() {
        check(
            "paged-kv-roundtrip",
            40,
            |g| (g.size(1, 7), g.size(1, 40)),
            |&(block_len, positions)| {
                let n_blocks = blocks_for(positions, block_len);
                let mut pool = KvBlockPool::new(1, 1, n_blocks, block_len);
                let mut kv = PagedKv::new(positions);
                let mut phys: BTreeSet<(usize, usize)> = BTreeSet::new();
                for pos in 0..positions {
                    kv.ensure_pos(&mut pool, pos).map_err(|e| e.to_string())?;
                    kv.store(&mut pool, 0, pos, &[pos as f32], &[pos as f32 + 0.5]);
                    kv.advance();
                    let (b, off) = kv.physical(&pool, pos);
                    if b != kv.block_table()[pos / block_len] || off != pos % block_len {
                        return Err(format!("pos {pos}: physical() broke the law"));
                    }
                    if off >= pool.block_len() || b >= pool.n_blocks() {
                        return Err(format!("pos {pos}: ({b}, {off}) out of range"));
                    }
                    if !phys.insert((b, off)) {
                        return Err(format!("pos {pos}: physical slot ({b}, {off}) reused"));
                    }
                }
                for pos in 0..positions {
                    if kv.key(&pool, 0, pos) != [pos as f32]
                        || kv.val(&pool, 0, pos) != [pos as f32 + 0.5]
                    {
                        return Err(format!("pos {pos}: readback mismatch"));
                    }
                }
                Ok(())
            },
        );
    }
}
