//! Paged KV memory: a shared arena of fixed-size token blocks plus
//! per-sequence block tables — the vLLM/PagedAttention idea applied to the
//! packed 1-bit engine, where the weights are tiny (~1.06 bits/weight) and
//! resident memory is dominated by KV state.
//!
//! The flat layout this replaces allocated one worst-case
//! `[n_layers, seq, d]` K and V buffer per lane, so lane count was a hard
//! memory ceiling even when most sequences are short. Here the memory is
//! one [`KvBlockPool`] — a `[n_blocks, n_layers, block_len, d]` arena per
//! side with a free list — and each lane holds a [`PagedKv`]: a block
//! table mapping logical positions to pool blocks, growing one block at a
//! time on demand and releasing every block on eviction or reset. Short
//! sequences hold few blocks, so many more lanes fit in the same arena;
//! when the pool runs dry, allocation fails with the typed [`KvExhausted`]
//! error and the serving scheduler applies backpressure (queue stalls,
//! lowest-progress eviction) instead of OOMing.
//!
//! Blocks are **refcounted** so several sequences can map one physical
//! prefix: [`PagedKv::share_prefix`] retains another table's leading
//! blocks read-only (the serving prompt cache built on top of this skips
//! prefill for the matched positions), and [`PagedKv::ensure_pos`]
//! copy-on-writes the *divergence block* — the first shared block a
//! sequence writes into is cloned to a private block before the write, so
//! a shared prefix is never mutated in place. `release`/`clear`/
//! `truncate_to` decrement instead of free while other references remain.
//!
//! Invariants (property-tested in this module and, heavier, in
//! `tests/paged_parity.rs` / `tests/prefix_parity.rs`):
//!
//! * every block's refcount equals the number of live block-table entries
//!   mapping it — alloc never hands out a referenced block, release of an
//!   unreferenced block panics (double-free is a logic error, not a
//!   recoverable state);
//! * `free_blocks() + used_blocks() == n_blocks()` at every step, where a
//!   block is "used" while its refcount is nonzero;
//! * writes never land in a block with refcount > 1 (copy-on-write runs
//!   first), so sharing is invisible to readers;
//! * the logical↔physical mapping round-trips: position `p` lives at
//!   `(table[p / block_len], p % block_len)` and reads back exactly what
//!   was stored.
//!
//! The per-position *arithmetic* of the decode path is unchanged — only
//! the storage layout differs — so paged and flat-configured engines
//! (`block_len == seq_len`, one block per lane) produce byte-identical
//! greedy decodes; `tests/paged_parity.rs` pins that down.

use std::fmt;

/// Default tokens per KV block (CLI `--block-len`). Small enough that a
/// short sequence wastes little, large enough that the block-table
/// indirection stays a rounding error of the attention gather.
pub const DEFAULT_BLOCK_LEN: usize = 16;

/// The shared block pool has no free block for a requested allocation.
///
/// Carried as the typed source of the `anyhow` error the engine returns,
/// so the scheduler can distinguish memory backpressure (evict the
/// lowest-progress sequence, retry) from a genuine decode failure
/// (poison every lane).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct KvExhausted {
    /// Blocks the failing operation needed.
    pub needed: usize,
    /// Blocks that were actually available.
    pub free: usize,
}

impl fmt::Display for KvExhausted {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "kv exhausted: need {} block(s), {} free",
            self.needed, self.free
        )
    }
}

impl std::error::Error for KvExhausted {}

/// Blocks needed to hold `positions` KV rows at the given block length.
pub fn blocks_for(positions: usize, block_len: usize) -> usize {
    debug_assert!(block_len > 0);
    (positions + block_len - 1) / block_len
}

/// One shared arena of fixed-size KV token blocks with a free list.
///
/// Layout per side (K and V): `[n_blocks, n_layers, block_len, d]` f32,
/// allocated once at construction. Blocks are the unit of allocation;
/// a block stores `block_len` consecutive token positions for *all*
/// layers of one sequence.
pub struct KvBlockPool {
    n_layers: usize,
    d: usize,
    block_len: usize,
    n_blocks: usize,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Free-list stack; initialized so blocks are handed out in index
    /// order (deterministic for tests).
    free: Vec<usize>,
    /// Per-block reference count (0 = free) — the double-free/alias guard
    /// and the prefix-sharing substrate: a block with `refs > 1` is mapped
    /// by several block tables and is read-only until copy-on-write gives
    /// a writer its private clone.
    refs: Vec<u32>,
    /// Blocks currently referenced more than once (maintained O(1) on
    /// retain/release) — surfaced through `KvStats::shared_blocks` and
    /// the `hbllm_shared_blocks` gauge.
    shared: usize,
    /// High-water mark of concurrently allocated blocks over the pool's
    /// lifetime — the capacity-planning signal surfaced through
    /// `KvStats::used_hwm` and the `hbllm_kv_blocks_used_hwm` gauge.
    used_hwm: usize,
    /// High-water mark of `shared` — how much prefill the sharing ever
    /// deduplicated at once (serve shutdown summary).
    shared_hwm: usize,
}

impl KvBlockPool {
    /// Allocate an arena of `n_blocks` blocks of `block_len` tokens each
    /// (both clamped to at least 1).
    pub fn new(n_layers: usize, d: usize, n_blocks: usize, block_len: usize) -> KvBlockPool {
        let n_blocks = n_blocks.max(1);
        let block_len = block_len.max(1);
        let elems = n_blocks * n_layers * block_len * d;
        KvBlockPool {
            n_layers,
            d,
            block_len,
            n_blocks,
            k: vec![0.0; elems],
            v: vec![0.0; elems],
            free: (0..n_blocks).rev().collect(),
            refs: vec![0; n_blocks],
            shared: 0,
            used_hwm: 0,
            shared_hwm: 0,
        }
    }

    pub fn block_len(&self) -> usize {
        self.block_len
    }

    pub fn n_blocks(&self) -> usize {
        self.n_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    pub fn used_blocks(&self) -> usize {
        self.n_blocks - self.free.len()
    }

    /// Most blocks ever allocated at once (never decreases; 0 until the
    /// first allocation).
    pub fn used_hwm(&self) -> usize {
        self.used_hwm
    }

    /// Blocks currently mapped by more than one block table.
    pub fn shared_blocks(&self) -> usize {
        self.shared
    }

    /// Most blocks ever shared at once (never decreases; 0 until the
    /// first [`KvBlockPool::retain`]).
    pub fn shared_hwm(&self) -> usize {
        self.shared_hwm
    }

    /// Current reference count of `block` (0 = free).
    pub fn refs(&self, block: usize) -> u32 {
        assert!(block < self.n_blocks, "refs of out-of-range kv block {block}");
        self.refs[block]
    }

    /// Total arena bytes (capacity, not fill level) across both sides.
    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    /// Bytes of one block across both sides.
    pub fn block_bytes(&self) -> usize {
        2 * self.n_layers * self.block_len * self.d * 4
    }

    /// Take a free block (refcount 1). Fails with [`KvExhausted`] when the
    /// pool is dry.
    pub fn alloc(&mut self) -> Result<usize, KvExhausted> {
        match self.free.pop() {
            Some(b) => {
                debug_assert!(self.refs[b] == 0, "free list handed out a live block");
                self.refs[b] = 1;
                self.used_hwm = self.used_hwm.max(self.used_blocks());
                Ok(b)
            }
            None => Err(KvExhausted { needed: 1, free: 0 }),
        }
    }

    /// Add a reference to an allocated block — the prefix-sharing entry
    /// point ([`PagedKv::share_prefix`] and the serving prompt cache call
    /// this for every block they map). Panics on a free or out-of-range
    /// block: retaining unowned memory would alias whatever sequence is
    /// handed that block next.
    pub fn retain(&mut self, block: usize) {
        assert!(block < self.n_blocks, "retain of out-of-range kv block {block}");
        assert!(self.refs[block] > 0, "retain of free kv block {block}");
        if self.refs[block] == 1 {
            self.shared += 1;
            self.shared_hwm = self.shared_hwm.max(self.shared);
        }
        self.refs[block] += 1;
    }

    /// Drop one reference; the block returns to the free list only when
    /// the last reference goes (sharing holders decrement, they never
    /// free out from under each other). Panics on over-release or an
    /// out-of-range block — both are sequencer logic errors that would
    /// otherwise silently alias KV state across sequences.
    pub fn release(&mut self, block: usize) {
        assert!(block < self.n_blocks, "release of out-of-range kv block {block}");
        assert!(self.refs[block] > 0, "double free of kv block {block}");
        self.refs[block] -= 1;
        match self.refs[block] {
            0 => self.free.push(block),
            1 => self.shared -= 1,
            _ => {}
        }
    }

    #[inline]
    fn idx(&self, block: usize, layer: usize, off: usize) -> usize {
        debug_assert!(block < self.n_blocks && layer < self.n_layers && off < self.block_len);
        ((block * self.n_layers + layer) * self.block_len + off) * self.d
    }

    /// Store one position's K/V rows at `(block, layer, off)`.
    pub fn store(&mut self, block: usize, layer: usize, off: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let o = self.idx(block, layer, off);
        self.k[o..o + self.d].copy_from_slice(k_row);
        self.v[o..o + self.d].copy_from_slice(v_row);
    }

    #[inline]
    pub fn key(&self, block: usize, layer: usize, off: usize) -> &[f32] {
        let o = self.idx(block, layer, off);
        &self.k[o..o + self.d]
    }

    #[inline]
    pub fn val(&self, block: usize, layer: usize, off: usize) -> &[f32] {
        let o = self.idx(block, layer, off);
        &self.v[o..o + self.d]
    }

    /// Copy `src`'s full contents (all layers, all offsets, both sides)
    /// into `dst` — the copy-on-write clone of a divergence block.
    fn copy_block(&mut self, src: usize, dst: usize) {
        debug_assert!(src < self.n_blocks && dst < self.n_blocks && src != dst);
        let n = self.n_layers * self.block_len * self.d;
        self.k.copy_within(src * n..(src + 1) * n, dst * n);
        self.v.copy_within(src * n..(src + 1) * n, dst * n);
    }
}

/// One sequence's view of the paged KV memory: a block table mapping
/// logical positions to [`KvBlockPool`] blocks, plus the fill level.
///
/// Position `p` lives in table slot `p / block_len` at offset
/// `p % block_len`. The table grows one block at a time through
/// [`PagedKv::ensure_pos`] and releases everything via [`PagedKv::clear`]
/// — a `PagedKv` never outlives its blocks' ownership silently (the pool
/// panics on double-release, and `tests` below cover the interleavings).
pub struct PagedKv {
    /// Logical position cap (the model's `seq_len` — positions beyond it
    /// have no position embedding).
    seq: usize,
    blocks: Vec<usize>,
    len: usize,
}

impl PagedKv {
    /// An empty view (no blocks held) with logical capacity `seq`.
    pub fn new(seq: usize) -> PagedKv {
        PagedKv { seq, blocks: Vec::new(), len: 0 }
    }

    /// Positions filled so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Logical capacity in positions.
    pub fn seq(&self) -> usize {
        self.seq
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.seq
    }

    /// Blocks currently held by this sequence.
    pub fn held_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Held blocks whose release would actually hit the free list (sole
    /// reference). Sweep planners count these — not `held_blocks` — when
    /// budgeting how many blocks a [`PagedKv::clear`] frees, since blocks
    /// shared with other holders survive the clear.
    pub fn reclaimable_blocks(&self, pool: &KvBlockPool) -> usize {
        self.blocks.iter().filter(|&&b| pool.refs(b) == 1).count()
    }

    /// The block table (pool block index per `block_len` positions).
    pub fn block_table(&self) -> &[usize] {
        &self.blocks
    }

    /// Physical address of logical position `pos`.
    #[inline]
    pub fn physical(&self, pool: &KvBlockPool, pos: usize) -> (usize, usize) {
        let bl = pool.block_len();
        (self.blocks[pos / bl], pos % bl)
    }

    /// Grow the block table (allocating from `pool`) until position `pos`
    /// is addressable **and writable**: every shared block the coming
    /// writes (`len..=pos`) would land in is copy-on-write cloned to a
    /// private block first, so a prefix mapped by other sequences is never
    /// mutated in place. Fails with [`KvExhausted`] when the pool is dry;
    /// on failure the table keeps whatever it grew or cloned so far —
    /// still a consistent state, released by the next [`PagedKv::clear`].
    pub fn ensure_pos(&mut self, pool: &mut KvBlockPool, pos: usize) -> Result<(), KvExhausted> {
        debug_assert!(pos < self.seq, "position {pos} beyond seq cap {}", self.seq);
        let bl = pool.block_len();
        let need = blocks_for(pos + 1, bl);
        while self.blocks.len() < need {
            match pool.alloc() {
                Ok(b) => self.blocks.push(b),
                Err(_) => {
                    return Err(KvExhausted {
                        needed: need - self.blocks.len(),
                        free: 0,
                    })
                }
            }
        }
        // copy-on-write pass: un-share the divergence block(s). Writes go
        // to positions len..=pos, so only those slots can need a clone;
        // fresh blocks from the loop above are born private (refs == 1).
        for slot in (self.len / bl).min(pos / bl)..=pos / bl {
            if pool.refs(self.blocks[slot]) > 1 {
                let fresh = match pool.alloc() {
                    Ok(b) => b,
                    Err(_) => return Err(KvExhausted { needed: 1, free: 0 }),
                };
                pool.copy_block(self.blocks[slot], fresh);
                pool.release(self.blocks[slot]);
                self.blocks[slot] = fresh;
            }
        }
        Ok(())
    }

    /// Map the leading `positions` of another block table into this empty
    /// view **read-only**, retaining every mapped block. The view starts
    /// at fill level `positions` — prefill for those positions is skipped
    /// entirely — and the first write past the shared prefix triggers the
    /// copy-on-write clone in [`PagedKv::ensure_pos`]. `donor` may be a
    /// live sequence's table or the serving prompt cache's retained copy;
    /// either way the donor keeps its own references.
    pub fn share_prefix(&mut self, pool: &mut KvBlockPool, donor: &[usize], positions: usize) {
        assert!(
            self.blocks.is_empty() && self.len == 0,
            "share_prefix into a non-empty view (clear it first)"
        );
        assert!(positions <= self.seq, "shared prefix {positions} beyond seq cap {}", self.seq);
        let need = blocks_for(positions, pool.block_len());
        assert!(
            need <= donor.len(),
            "donor table holds {} block(s), prefix of {positions} needs {need}",
            donor.len()
        );
        for &b in &donor[..need] {
            pool.retain(b);
            self.blocks.push(b);
        }
        self.len = positions;
    }

    /// Blocks the next write (at position `len`) would have to
    /// copy-on-write clone — 0, or 1 when the fill level sits inside a
    /// shared divergence block. Admission/sweep planners add this to
    /// their block budgets so a metered sweep never discovers mid-write
    /// that the clone has no free block.
    pub fn pending_cow(&self, pool: &KvBlockPool) -> usize {
        let slot = self.len / pool.block_len();
        usize::from(slot < self.blocks.len() && pool.refs(self.blocks[slot]) > 1)
    }

    /// Store position `pos`'s K/V rows for `layer`. The caller must have
    /// grown the table past `pos` (see [`PagedKv::ensure_pos`], which also
    /// copy-on-writes any shared block in the write range) and bumps
    /// `len` once per position via [`PagedKv::advance`] after all layers.
    pub fn store(
        &self,
        pool: &mut KvBlockPool,
        layer: usize,
        pos: usize,
        k_row: &[f32],
        v_row: &[f32],
    ) {
        let bl = pool.block_len();
        let b = self.blocks[pos / bl];
        debug_assert!(
            pool.refs(b) == 1,
            "write into shared kv block {b} (ensure_pos would have cloned it)"
        );
        pool.store(b, layer, pos % bl, k_row, v_row);
    }

    #[inline]
    pub fn key<'p>(&self, pool: &'p KvBlockPool, layer: usize, pos: usize) -> &'p [f32] {
        let bl = pool.block_len();
        pool.key(self.blocks[pos / bl], layer, pos % bl)
    }

    #[inline]
    pub fn val<'p>(&self, pool: &'p KvBlockPool, layer: usize, pos: usize) -> &'p [f32] {
        let bl = pool.block_len();
        pool.val(self.blocks[pos / bl], layer, pos % bl)
    }

    pub fn advance(&mut self) {
        debug_assert!(self.len < self.seq, "paged kv overflow");
        self.len += 1;
    }

    /// Roll back to `pos` filled positions, releasing every tail block no
    /// longer needed to address `0..pos` back to `pool`. The speculative
    /// decoder's rejection path: KV rows computed for rejected draft
    /// tokens are dropped and their blocks returned to the free list in
    /// the same call. `pos` must not exceed the current fill level;
    /// `truncate_to(len())` is a no-op that still trims blocks a failed
    /// sweep grew past `len` (see [`PagedKv::ensure_pos`]).
    pub fn truncate_to(&mut self, pool: &mut KvBlockPool, pos: usize) {
        debug_assert!(pos <= self.len, "truncate_to({pos}) beyond fill {}", self.len);
        let keep = blocks_for(pos, pool.block_len());
        for b in self.blocks.drain(keep.min(self.blocks.len())..) {
            pool.release(b);
        }
        self.len = pos.min(self.len);
    }

    /// Logical reset: drop this view's reference on every held block. A
    /// block mapped by no one else returns to the free list; one still
    /// shared (another sequence or the prompt cache) merely loses this
    /// reference.
    pub fn clear(&mut self, pool: &mut KvBlockPool) {
        for b in self.blocks.drain(..) {
            pool.release(b);
        }
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Pcg32;
    use std::collections::BTreeSet;

    #[test]
    fn alloc_release_cycle_and_accounting() {
        let mut pool = KvBlockPool::new(2, 4, 3, 8);
        assert_eq!((pool.n_blocks(), pool.free_blocks(), pool.used_blocks()), (3, 3, 0));
        assert_eq!(pool.used_hwm(), 0, "hwm nonzero before any allocation");
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!((pool.free_blocks(), pool.used_blocks()), (1, 2));
        assert_eq!(pool.used_hwm(), 2);
        pool.release(a);
        assert_eq!(pool.used_hwm(), 2, "hwm must not fall on release");
        let c = pool.alloc().unwrap();
        let d = pool.alloc().unwrap();
        assert_eq!(pool.free_blocks(), 0);
        assert_eq!(pool.used_hwm(), 3, "full arena is the new high water");
        assert_eq!(pool.alloc(), Err(KvExhausted { needed: 1, free: 0 }));
        assert_eq!(c, a, "released block is recycled");
        pool.release(b);
        pool.release(c);
        pool.release(d);
        assert_eq!(pool.free_blocks(), 3);
        assert_eq!(pool.used_hwm(), 3, "hwm survives a full drain");
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut pool = KvBlockPool::new(1, 2, 2, 4);
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.release(a);
    }

    #[test]
    fn store_and_read_back_via_view() {
        let mut pool = KvBlockPool::new(2, 3, 4, 2);
        let mut kv = PagedKv::new(8);
        for pos in 0..5usize {
            kv.ensure_pos(&mut pool, pos).unwrap();
            for layer in 0..2 {
                let k: Vec<f32> = (0..3).map(|j| (pos * 10 + layer * 100 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                kv.store(&mut pool, layer, pos, &k, &v);
            }
            kv.advance();
        }
        assert_eq!(kv.len(), 5);
        assert_eq!(kv.held_blocks(), 3, "5 positions at block_len 2");
        for pos in 0..5usize {
            for layer in 0..2 {
                let k = kv.key(&pool, layer, pos);
                assert_eq!(k[1], (pos * 10 + layer * 100 + 1) as f32);
                assert_eq!(kv.val(&pool, layer, pos)[0], -((pos * 10 + layer * 100) as f32));
            }
        }
        kv.clear(&mut pool);
        assert_eq!((kv.len(), kv.held_blocks(), pool.free_blocks()), (0, 0, 4));
    }

    #[test]
    fn truncate_to_releases_tail_blocks_and_keeps_live_rows() {
        let mut pool = KvBlockPool::new(1, 2, 4, 2);
        let mut kv = PagedKv::new(8);
        for pos in 0..7usize {
            kv.ensure_pos(&mut pool, pos).unwrap();
            let row = [pos as f32, 0.0];
            kv.store(&mut pool, 0, pos, &row, &row);
            kv.advance();
        }
        assert_eq!((kv.len(), kv.held_blocks(), pool.free_blocks()), (7, 4, 0));
        // roll back to 3 positions: blocks 2 and 3 return to the free list
        kv.truncate_to(&mut pool, 3);
        assert_eq!((kv.len(), kv.held_blocks(), pool.free_blocks()), (3, 2, 2));
        for pos in 0..3usize {
            assert_eq!(kv.key(&pool, 0, pos)[0], pos as f32, "surviving row corrupted");
        }
        // freed blocks are allocatable by a second sequence immediately
        let mut other = PagedKv::new(8);
        other.ensure_pos(&mut pool, 3).unwrap();
        assert_eq!(pool.free_blocks(), 0);
        // truncating to the current length is a no-op
        kv.truncate_to(&mut pool, 3);
        assert_eq!((kv.len(), kv.held_blocks()), (3, 2));
        // truncate to zero == clear
        kv.truncate_to(&mut pool, 0);
        assert_eq!((kv.len(), kv.held_blocks()), (0, 0));
        other.clear(&mut pool);
        assert_eq!(pool.free_blocks(), 4);
    }

    #[test]
    fn ensure_pos_fails_cleanly_when_dry() {
        let mut pool = KvBlockPool::new(1, 2, 2, 2);
        let mut a = PagedKv::new(16);
        let mut b = PagedKv::new(16);
        a.ensure_pos(&mut pool, 3).unwrap(); // 2 blocks
        let err = b.ensure_pos(&mut pool, 0).unwrap_err();
        assert_eq!(err, KvExhausted { needed: 1, free: 0 });
        // pool accounting unharmed; releasing a frees b's path
        a.clear(&mut pool);
        b.ensure_pos(&mut pool, 3).unwrap();
        b.clear(&mut pool);
    }

    #[test]
    fn blocks_for_boundaries() {
        assert_eq!(blocks_for(0, 4), 0);
        assert_eq!(blocks_for(1, 4), 1);
        assert_eq!(blocks_for(4, 4), 1);
        assert_eq!(blocks_for(5, 4), 2);
        assert_eq!(blocks_for(12, 1), 12);
    }

    /// Fill `kv` with `positions` rows tagged `tag` (layer 0, d = 2).
    fn fill(pool: &mut KvBlockPool, kv: &mut PagedKv, positions: usize, tag: f32) {
        for pos in 0..positions {
            kv.ensure_pos(pool, pos).unwrap();
            let row = [pos as f32, tag];
            kv.store(pool, 0, pos, &row, &row);
            kv.advance();
        }
    }

    #[test]
    fn share_prefix_maps_donor_blocks_without_allocating() {
        let mut pool = KvBlockPool::new(1, 2, 4, 2);
        let mut donor = PagedKv::new(8);
        fill(&mut pool, &mut donor, 5, 7.0); // 3 blocks
        let free_before = pool.free_blocks();
        let mut adopter = PagedKv::new(8);
        let table: Vec<usize> = donor.block_table().to_vec();
        adopter.share_prefix(&mut pool, &table, 5);
        // no allocation: the same physical blocks, refcounted
        assert_eq!(pool.free_blocks(), free_before);
        assert_eq!((adopter.len(), adopter.held_blocks()), (5, 3));
        assert_eq!(adopter.block_table(), donor.block_table());
        assert_eq!(pool.shared_blocks(), 3);
        assert_eq!(pool.shared_hwm(), 3);
        for b in donor.block_table() {
            assert_eq!(pool.refs(*b), 2);
        }
        // the adopter reads the donor's rows — prefill skipped entirely
        for pos in 0..5 {
            assert_eq!(adopter.key(&pool, 0, pos), [pos as f32, 7.0]);
        }
        // the divergence block (position 5 lives in half-full block 2) is
        // what the next write would have to clone
        assert_eq!(adopter.pending_cow(&pool), 1);
        adopter.clear(&mut pool);
        donor.clear(&mut pool);
        assert_eq!(pool.free_blocks(), 4);
        assert_eq!(pool.shared_blocks(), 0);
        assert_eq!(pool.shared_hwm(), 3, "shared hwm survives the drain");
    }

    #[test]
    fn cow_clones_divergence_block_on_first_write() {
        let mut pool = KvBlockPool::new(1, 2, 4, 2);
        let mut donor = PagedKv::new(8);
        fill(&mut pool, &mut donor, 5, 7.0);
        let mut adopter = PagedKv::new(8);
        let table: Vec<usize> = donor.block_table().to_vec();
        adopter.share_prefix(&mut pool, &table, 5);
        // first write past the shared prefix: position 5 lands in shared
        // block 2, which must be cloned (one alloc), not written in place
        adopter.ensure_pos(&mut pool, 5).unwrap();
        assert_eq!(pool.free_blocks(), 0, "COW clone did not allocate");
        assert_ne!(adopter.block_table()[2], donor.block_table()[2], "divergence block not cloned");
        assert_eq!(adopter.block_table()[..2], donor.block_table()[..2], "full blocks stay shared");
        assert_eq!(pool.shared_blocks(), 2);
        adopter.store(&mut pool, 0, 5, &[5.0, 9.0], &[5.0, 9.0]);
        adopter.advance();
        // the clone carried position 4's row across, and the donor's copy
        // of position 4 (and its whole block) is untouched
        assert_eq!(adopter.key(&pool, 0, 4), [4.0, 7.0]);
        assert_eq!(adopter.key(&pool, 0, 5), [5.0, 9.0]);
        assert_eq!(donor.key(&pool, 0, 4), [4.0, 7.0]);
        assert_eq!(donor.len(), 5);
        adopter.clear(&mut pool);
        donor.clear(&mut pool);
        assert_eq!(pool.free_blocks(), 4);
    }

    #[test]
    fn release_decrements_and_frees_only_the_last_reference() {
        let mut pool = KvBlockPool::new(1, 2, 3, 2);
        let mut donor = PagedKv::new(6);
        fill(&mut pool, &mut donor, 4, 3.0); // 2 full blocks
        let mut adopter = PagedKv::new(6);
        let table: Vec<usize> = donor.block_table().to_vec();
        adopter.share_prefix(&mut pool, &table, 4);
        // the donor leaving (evicted lane) must not free blocks the
        // adopter still maps
        donor.clear(&mut pool);
        assert_eq!(pool.free_blocks(), 1, "shared blocks freed under the adopter");
        assert_eq!(pool.shared_blocks(), 0);
        for pos in 0..4 {
            assert_eq!(adopter.key(&pool, 0, pos), [pos as f32, 3.0]);
        }
        // truncate decrements the tail reference; with the donor gone the
        // tail block really frees
        adopter.truncate_to(&mut pool, 2);
        assert_eq!(pool.free_blocks(), 2);
        adopter.clear(&mut pool);
        assert_eq!(pool.free_blocks(), 3, "pool did not drain to empty");
    }

    #[test]
    fn pending_cow_is_zero_for_block_aligned_prefixes() {
        let mut pool = KvBlockPool::new(1, 2, 4, 2);
        let mut donor = PagedKv::new(8);
        fill(&mut pool, &mut donor, 4, 1.0); // exactly 2 blocks
        let mut adopter = PagedKv::new(8);
        let table: Vec<usize> = donor.block_table().to_vec();
        adopter.share_prefix(&mut pool, &table, 4);
        // fill level sits on a block boundary: the next write opens a
        // fresh private block, nothing to clone
        assert_eq!(adopter.pending_cow(&pool), 0);
        adopter.ensure_pos(&mut pool, 4).unwrap();
        adopter.store(&mut pool, 0, 4, &[4.0, 2.0], &[4.0, 2.0]);
        adopter.advance();
        assert_eq!(donor.key(&pool, 0, 3), [3.0, 1.0], "aligned share mutated the donor");
        assert_eq!(pool.shared_blocks(), 2, "full blocks stay shared after the write");
        adopter.clear(&mut pool);
        donor.clear(&mut pool);
    }

    #[test]
    #[should_panic(expected = "retain of free")]
    fn retain_of_free_block_panics() {
        let mut pool = KvBlockPool::new(1, 2, 2, 4);
        let a = pool.alloc().unwrap();
        pool.release(a);
        pool.retain(a);
    }

    /// Drive `ops` random grow/share/truncate/release steps over `n_seqs`
    /// sequences sharing one pool, verifying after every step: **exact
    /// refcount accounting** (every block's refcount equals the number of
    /// live block-table entries mapping it — this is simultaneously the
    /// no-alias, no-leak, and no-double-free check), `free + used ==
    /// total`, `shared_blocks` consistency, and `bytes()` constant (the
    /// arena never reallocates). Sharing (`share_prefix`, the prompt-cache
    /// substrate) interleaves with growth (which copy-on-writes divergence
    /// blocks), truncation (spec-rejection rollback, decrementing shared
    /// tails), and clears — and every sequence's contents must read back
    /// exactly per a shadow model, so a COW write can never leak into a
    /// sequence still mapping the original block.
    fn run_interleaving(seed: u64, n_seqs: usize, n_blocks: usize, block_len: usize, ops: usize) -> Result<(), String> {
        let mut rng = Pcg32::seeded(seed);
        let mut pool = KvBlockPool::new(1, 2, n_blocks, block_len);
        let arena_bytes = pool.bytes();
        let seq_cap = n_blocks * block_len;
        let mut seqs: Vec<PagedKv> = (0..n_seqs).map(|_| PagedKv::new(seq_cap)).collect();
        // shadow model: the row each (sequence, position) must read back —
        // an adopted prefix inherits the donor's rows until a write
        // diverges it
        let mut expect: Vec<Vec<[f32; 2]>> = vec![Vec::new(); n_seqs];
        for step in 0..ops {
            let i = rng.below(n_seqs);
            let dice = rng.f64();
            if dice < 0.5 {
                // grow by one position (may need a fresh block and/or a
                // copy-on-write clone of a shared divergence block)
                if !seqs[i].is_full() {
                    let pos = seqs[i].len();
                    let want = blocks_for(pos + 1, block_len)
                        .saturating_sub(seqs[i].held_blocks())
                        + seqs[i].pending_cow(&pool);
                    match seqs[i].ensure_pos(&mut pool, pos) {
                        Ok(()) => {
                            let row = [pos as f32, i as f32];
                            seqs[i].store(&mut pool, 0, pos, &row, &row);
                            seqs[i].advance();
                            expect[i].push(row);
                        }
                        Err(e) => {
                            if pool.free_blocks() >= want {
                                return Err(format!(
                                    "step {step}: spurious {e} with {} free ({want} needed)",
                                    pool.free_blocks()
                                ));
                            }
                        }
                    }
                }
            } else if dice < 0.65 {
                // adopt a neighbor's prefix read-only (the prompt-cache
                // path): reset, then map a random prefix of j's fill
                let j = rng.below(n_seqs);
                if j != i {
                    let positions = rng.below(seqs[j].len() + 1);
                    seqs[i].clear(&mut pool);
                    let donor: Vec<usize> = seqs[j].block_table().to_vec();
                    seqs[i].share_prefix(&mut pool, &donor, positions);
                    expect[i] = expect[j][..positions].to_vec();
                }
            } else if dice < 0.85 {
                // roll back to a random earlier fill level (spec
                // rejection); a shared tail block decrements, not frees
                let pos = rng.below(seqs[i].len() + 1);
                let expect_held = blocks_for(pos, block_len);
                seqs[i].truncate_to(&mut pool, pos);
                if seqs[i].len() != pos {
                    return Err(format!("step {step}: truncate_to({pos}) left len {}", seqs[i].len()));
                }
                if seqs[i].held_blocks() != expect_held {
                    return Err(format!(
                        "step {step}: truncate_to({pos}) holds {} blocks, want {expect_held}",
                        seqs[i].held_blocks()
                    ));
                }
                expect[i].truncate(pos);
            } else {
                seqs[i].clear(&mut pool);
                expect[i].clear();
            }
            // exact refcount accounting — refs[b] must equal the number
            // of live table entries mapping b (no alias, no leak, no
            // double-free, all in one identity)
            let mut counts = vec![0u32; n_blocks];
            for s in &seqs {
                for &b in s.block_table() {
                    counts[b] += 1;
                }
            }
            for (b, &c) in counts.iter().enumerate() {
                if pool.refs(b) != c {
                    return Err(format!(
                        "step {step}: block {b} refcount {} != {c} live references",
                        pool.refs(b)
                    ));
                }
            }
            let used = counts.iter().filter(|&&c| c > 0).count();
            if used != pool.used_blocks() {
                return Err(format!("step {step}: {used} referenced != used {}", pool.used_blocks()));
            }
            if pool.free_blocks() + pool.used_blocks() != pool.n_blocks() {
                return Err(format!("step {step}: free+used != total"));
            }
            let shared = counts.iter().filter(|&&c| c > 1).count();
            if shared != pool.shared_blocks() {
                return Err(format!(
                    "step {step}: {shared} multi-ref blocks != shared_blocks {}",
                    pool.shared_blocks()
                ));
            }
            if pool.bytes() != arena_bytes {
                return Err(format!("step {step}: arena reallocated"));
            }
            // every sequence reads back exactly its shadow-model rows —
            // shared prefixes see the donor's rows, COW writes never leak
            // into a neighbor still mapping the original block
            for (si, s) in seqs.iter().enumerate() {
                if s.len() != expect[si].len() {
                    return Err(format!(
                        "step {step}: seq {si} fill {} != model {}",
                        s.len(),
                        expect[si].len()
                    ));
                }
                for pos in 0..s.len() {
                    let k = s.key(&pool, 0, pos);
                    if k != expect[si][pos] {
                        return Err(format!("step {step}: seq {si} pos {pos} corrupted"));
                    }
                }
            }
        }
        Ok(())
    }

    #[test]
    fn prop_interleavings_never_alias_or_leak() {
        check(
            "paged-kv-interleavings",
            40,
            |g| {
                (
                    g.rng.next_u64(),
                    g.size(1, 4),  // sequences
                    g.size(1, 6),  // blocks
                    g.size(1, 5),  // block_len
                    g.size(1, 60), // ops
                )
            },
            |&(seed, n_seqs, n_blocks, block_len, ops)| {
                run_interleaving(seed, n_seqs, n_blocks, block_len, ops)
            },
        );
    }

    /// Heavier version of the interleaving property for the CI `--ignored`
    /// pass: more sequences, more blocks, long op chains.
    #[test]
    #[ignore = "slow: run via cargo test --release -- --ignored"]
    fn prop_interleavings_never_alias_or_leak_heavy() {
        check(
            "paged-kv-interleavings-heavy",
            60,
            |g| {
                (
                    g.rng.next_u64(),
                    g.size(1, 12),
                    g.size(1, 32),
                    g.size(1, 9),
                    g.size(50, 600),
                )
            },
            |&(seed, n_seqs, n_blocks, block_len, ops)| {
                run_interleaving(seed, n_seqs, n_blocks, block_len, ops)
            },
        );
    }

    /// The logical↔physical round-trip law: `physical(p)` is
    /// `(table[p / bl], p % bl)`, every mapped slot is in range, distinct
    /// positions never collide, and stored rows read back exactly.
    #[test]
    fn prop_logical_physical_roundtrip() {
        check(
            "paged-kv-roundtrip",
            40,
            |g| (g.size(1, 7), g.size(1, 40)),
            |&(block_len, positions)| {
                let n_blocks = blocks_for(positions, block_len);
                let mut pool = KvBlockPool::new(1, 1, n_blocks, block_len);
                let mut kv = PagedKv::new(positions);
                let mut phys: BTreeSet<(usize, usize)> = BTreeSet::new();
                for pos in 0..positions {
                    kv.ensure_pos(&mut pool, pos).map_err(|e| e.to_string())?;
                    kv.store(&mut pool, 0, pos, &[pos as f32], &[pos as f32 + 0.5]);
                    kv.advance();
                    let (b, off) = kv.physical(&pool, pos);
                    if b != kv.block_table()[pos / block_len] || off != pos % block_len {
                        return Err(format!("pos {pos}: physical() broke the law"));
                    }
                    if off >= pool.block_len() || b >= pool.n_blocks() {
                        return Err(format!("pos {pos}: ({b}, {off}) out of range"));
                    }
                    if !phys.insert((b, off)) {
                        return Err(format!("pos {pos}: physical slot ({b}, {off}) reused"));
                    }
                }
                for pos in 0..positions {
                    if kv.key(&pool, 0, pos) != [pos as f32]
                        || kv.val(&pool, 0, pos) != [pos as f32 + 0.5]
                    {
                        return Err(format!("pos {pos}: readback mismatch"));
                    }
                }
                Ok(())
            },
        );
    }
}
