//! Frequency-cascade speculative decoding: the draft model that lives
//! *inside* the HBLLM artifact.
//!
//! HBLLM stores every linear as Haar-domain sign bits with per-band
//! (α, μ). The deepest low band is, by construction, a coarse
//! low-frequency approximation of the full weight matrix — so a
//! low-band-only forward ([`Linear::gemv_low`](super::Linear::gemv_low))
//! is a draft model that costs roughly half the binary dots and **zero**
//! extra weight storage: it reads the same packed sign words, skipping
//! the high-band bit range and scales.
//!
//! The cascade works the standard speculative-decoding way, specialized
//! to greedy decoding:
//!
//! 1. a [`DraftLane`] runs the cheap low-band forward over its own small
//!    flat KV state and greedily proposes `k` draft bytes;
//! 2. the full packed model *verifies* them in one batched sweep
//!    (`NativeBackend::decode_batch_spec`): the `k + 1` positions — plus
//!    however much prefill the lane still owed — go through every packed
//!    linear as one `gemv_batch`, so the bit-unpack/weight-traffic cost
//!    that dominates 1-bit serving is paid once per round instead of once
//!    per token;
//! 3. the accept scan commits the longest draft prefix the full model
//!    agrees with, plus one verified token (the correction on rejection,
//!    a free bonus token on full acceptance) — so every round commits
//!    between 1 and `k + 1` bytes and the output is **byte-identical** to
//!    plain greedy decoding; rejected draft positions are rolled back
//!    with [`PagedKv::truncate_to`](super::paged::PagedKv::truncate_to).
//!
//! This module holds the shared types ([`SpecConfig`], [`SpecRound`],
//! [`SpecStats`]) and the draft-side state machine; the verify sweep
//! lives in `engine::native` next to the plain decode path it mirrors.
//! Both the draft's low-band dots and the verify sweep's full dots route
//! through the same runtime-dispatched kernel
//! ([`pack::kernels::active`](crate::pack::kernels::active)) as plain
//! decode — the SIMD paths accelerate all three at once, and their
//! bit-identity pin is what keeps the accept scan, and therefore the
//! byte-identical-output guarantee, kernel-independent.

use super::kv::Arena;
use super::model::PackedModel;
use crate::model::{gelu_tanh, rmsnorm};

/// Speculative-decoding configuration, threaded from the CLI (`--spec-k`)
/// through the serving scheduler to the backend.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SpecConfig {
    /// Draft tokens proposed per round; a round commits `1..=k+1` bytes.
    pub k: usize,
    /// Whether greedy lanes should decode speculatively. Sampling lanes
    /// (`temperature > 0`) always take the plain path — the byte-identical
    /// guarantee only holds for argmax decoding.
    pub enabled: bool,
}

impl SpecConfig {
    pub fn disabled() -> SpecConfig {
        SpecConfig { k: 0, enabled: false }
    }

    /// Enabled iff `k > 0`.
    pub fn with_k(k: usize) -> SpecConfig {
        SpecConfig { k, enabled: k > 0 }
    }
}

/// One lane's outcome of a speculative round: the committed bytes (always
/// at least one — rejection falls back to the verified token) plus the
/// accept/reject bookkeeping behind them.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpecRound {
    /// Verified bytes to append to the sequence, in order. Length is
    /// `accepted + 1`: the accepted draft prefix, then either the
    /// verifier's correction (on rejection) or its bonus token (on full
    /// acceptance).
    pub bytes: Vec<u8>,
    /// Draft tokens proposed this round (0 when the window left no room).
    pub drafted: usize,
    /// Length of the accepted draft prefix (`<= drafted`).
    pub accepted: usize,
}

/// Cumulative acceptance counters — the `kv_stats`-style snapshot for the
/// speculative path, surfaced via `Backend::spec_stats`. Counters are
/// per-service (they survive lane resets between sequences) but drop with
/// the lanes on `set_lanes`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SpecStats {
    /// Configured draft width.
    pub k: usize,
    pub enabled: bool,
    /// Speculative rounds executed across all lanes.
    pub rounds: u64,
    /// Draft tokens proposed across all lanes.
    pub drafted: u64,
    /// Draft tokens accepted across all lanes.
    pub accepted: u64,
    /// Per-lane drafted counters (`lane_drafted[i]` is lane `i`).
    pub lane_drafted: Vec<u64>,
    /// Per-lane accepted counters.
    pub lane_accepted: Vec<u64>,
    /// Bytes allocated for draft-side flat K/V buffers across all lanes
    /// (lazily allocated, only for lanes that have actually drafted).
    /// This memory sits *outside* the paged arena `kv_stats` reports —
    /// budget for it when capping `--kv-blocks`.
    pub draft_kv_bytes: usize,
}

impl SpecStats {
    /// Fraction of drafted tokens the verifier accepted (0 when nothing
    /// has been drafted yet).
    pub fn acceptance(&self) -> f64 {
        if self.drafted == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafted as f64
        }
    }

    /// Draft tokens the verifier rejected — the complement of
    /// [`SpecStats::acceptance`], surfaced as its own counter
    /// (`hbllm_spec_rejected_total`) so dashboards can rate-derive both
    /// sides without subtraction across scrapes.
    pub fn rejected(&self) -> u64 {
        self.drafted - self.accepted
    }
}

/// Draft-side state for one KV lane: a flat `[n_layers, seq, d]` K/V
/// buffer (the draft is one lane's half-cost shadow — paging it would
/// buy nothing), the bytes behind it, and the lane's cumulative
/// acceptance counters. The K/V buffer is allocated **lazily on the
/// first draft step**, so lanes that never speculate (sampling clients
/// in a mixed batch) cost only the small arena — and the allocated total
/// is surfaced as [`SpecStats::draft_kv_bytes`], since this memory sits
/// outside the paged arena `kv_stats` meters.
///
/// The draft forward mirrors `NativeBackend::step_lanes` op for op, with
/// every linear routed through the low-band view. Draft output quality
/// only affects the acceptance rate — never correctness: every proposed
/// byte is checked against the full model before it is committed.
pub struct DraftLane {
    keys: Vec<f32>,
    vals: Vec<f32>,
    /// Positions filled so far (rows `0..len` are valid).
    len: usize,
    /// Bytes whose K/V rows fill positions `0..len`.
    prefix: Vec<u8>,
    /// Prefix length the current `arena.logits` row corresponds to (the
    /// staleness guard for fully-cached syncs after a rollback).
    logits_len: usize,
    arena: Arena,
    /// Low-band adjoint scratch.
    zlow: Vec<f32>,
    /// Cumulative counters, aggregated into [`SpecStats`].
    pub rounds: u64,
    pub drafted: u64,
    pub accepted: u64,
}

impl DraftLane {
    /// The K/V buffer is not allocated here — see the type docs.
    pub fn new(cfg: &crate::model::ModelConfig) -> DraftLane {
        DraftLane {
            keys: Vec::new(),
            vals: Vec::new(),
            len: 0,
            prefix: Vec::new(),
            logits_len: 0,
            arena: Arena::new(cfg),
            zlow: Vec::new(),
            rounds: 0,
            drafted: 0,
            accepted: 0,
        }
    }

    /// Drop the draft's decode state (counters and the lazily-allocated
    /// K/V buffer survive — the former are service stats, the latter is
    /// reused by the lane's next speculating sequence).
    pub fn clear(&mut self) {
        self.len = 0;
        self.prefix.clear();
        self.logits_len = 0;
    }

    /// Bytes currently allocated for this lane's draft K/V buffer (zero
    /// until the lane first drafts).
    pub fn kv_bytes(&self) -> usize {
        (self.keys.len() + self.vals.len()) * 4
    }

    /// One low-band decode step: embed `byte` at the next position, run
    /// every block through [`Linear::gemv_low`](super::Linear::gemv_low),
    /// leave the draft's next-token logits in the arena. Same op order as
    /// the full engine's `step_lanes`, so the draft is the full forward
    /// with the high band muted — nothing else differs.
    fn step(&mut self, model: &PackedModel, byte: u8) {
        let cfg = &model.config;
        let (d, heads, dh, seq) = (cfg.d_model, cfg.n_heads, cfg.d_head(), cfg.seq_len);
        let scale = 1.0 / (dh as f32).sqrt();
        let t = self.len;
        debug_assert!(t < seq, "draft kv overflow");
        let DraftLane { keys, vals, arena, zlow, .. } = self;
        if keys.is_empty() {
            // first draft step on this lane: allocate the flat K/V buffer
            let n = model.config.n_layers * seq * d;
            keys.resize(n, 0.0);
            vals.resize(n, 0.0);
        }
        let Arena { x, h, q, k, v, attn, proj, ff, probs, logits } = arena;
        let te = model.tok_emb.row(byte as usize);
        let pe = model.pos_emb.row(t);
        for j in 0..d {
            x[j] = te[j] + pe[j];
        }
        for (li, layer) in model.layers.iter().enumerate() {
            rmsnorm(x, &layer.ln1, h);
            layer.wq.gemv_low(h, q, zlow);
            layer.wk.gemv_low(h, k, zlow);
            layer.wv.gemv_low(h, v, zlow);
            let base = (li * seq + t) * d;
            keys[base..base + d].copy_from_slice(k);
            vals[base..base + d].copy_from_slice(v);
            {
                let krows: &[f32] = keys;
                let vrows: &[f32] = vals;
                super::attend_position(
                    heads,
                    dh,
                    scale,
                    t,
                    q,
                    probs,
                    attn,
                    |u| &krows[(li * seq + u) * d..][..d],
                    |u| &vrows[(li * seq + u) * d..][..d],
                );
            }
            layer.wo.gemv_low(attn, proj, zlow);
            for j in 0..d {
                x[j] += proj[j];
            }
            rmsnorm(x, &layer.ln2, h);
            layer.w1.gemv_low(h, ff, zlow);
            for vv in ff.iter_mut() {
                *vv = gelu_tanh(*vv);
            }
            layer.w2.gemv_low(ff, proj, zlow);
            for j in 0..d {
                x[j] += proj[j];
            }
        }
        rmsnorm(x, &model.ln_f, h);
        model.unemb.gemv_low(h, logits, zlow);
        self.len += 1;
        self.logits_len = self.len;
        self.prefix.push(byte);
    }

    /// Catch the draft up to `window`, then greedily propose `k` draft
    /// bytes. Incremental: the longest cached prefix still matching
    /// `window` is kept (a flat-KV rollback is just a length cut — this
    /// is where rejected drafts from the previous round are discarded);
    /// only the unseen suffix and the `k − 1` intermediate drafts run
    /// through the low-band forward.
    ///
    /// Requires `window.len() + k <= seq` — the caller clamps `k` to the
    /// window headroom, exactly as the verifier does.
    pub fn draft(&mut self, model: &PackedModel, window: &[u8], k: usize) -> Vec<u8> {
        debug_assert!(!window.is_empty(), "draft window must be non-empty");
        debug_assert!(window.len() + k <= model.config.seq_len, "draft past the window");
        let mut keep = 0;
        let cap = self.len.min(self.prefix.len()).min(window.len());
        while keep < cap && self.prefix[keep] == window[keep] {
            keep += 1;
        }
        if keep == window.len() && self.logits_len != keep {
            // fully cached but the logits row belongs to a longer,
            // since-rolled-back prefix: re-step the last byte so the
            // proposal conditions on exactly `window`
            keep -= 1;
        }
        self.len = keep;
        self.prefix.truncate(keep);
        if self.logits_len > keep {
            self.logits_len = 0; // stale until the next step
        }
        for &b in &window[keep..] {
            self.step(model, b);
        }
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let next = super::greedy_token(&self.arena.logits) as u8;
            out.push(next);
            if i + 1 < k {
                self.step(model, next);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::PackedModel as EngineModel;
    use crate::model::testing::micro_weights;

    fn packed(seed: u64) -> EngineModel {
        EngineModel::from_weights(&micro_weights(seed), true).unwrap()
    }

    #[test]
    fn draft_is_deterministic_and_incremental() {
        let m = packed(51);
        let mut a = DraftLane::new(&m.config);
        let mut b = DraftLane::new(&m.config);
        let w: &[u8] = b"ta kivo";
        let d1 = a.draft(&m, w, 3);
        let d2 = b.draft(&m, w, 3);
        assert_eq!(d1, d2, "draft not deterministic");
        assert_eq!(d1.len(), 3);
        // re-drafting the same window proposes the same bytes (the
        // staleness guard re-steps the last byte after the rollback)
        let d3 = a.draft(&m, w, 3);
        assert_eq!(d1, d3, "incremental re-draft diverged");
        // extending the window keeps the cached prefix and still matches
        // a from-scratch draft
        let mut longer = w.to_vec();
        longer.push(d1[0]);
        let inc = a.draft(&m, &longer, 2);
        let mut fresh = DraftLane::new(&m.config);
        let full = fresh.draft(&m, &longer, 2);
        assert_eq!(inc, full, "incremental draft diverged from fresh");
    }

    #[test]
    fn draft_rolls_back_divergent_prefixes() {
        let m = packed(52);
        let mut lane = DraftLane::new(&m.config);
        let drafts = lane.draft(&m, b"ab", 4);
        // pretend the verifier rejected everything: the next window
        // shares only the original bytes plus a different continuation
        let mut window = b"ab".to_vec();
        window.push(drafts[0].wrapping_add(1));
        let inc = lane.draft(&m, &window, 2);
        let mut fresh = DraftLane::new(&m.config);
        let full = fresh.draft(&m, &window, 2);
        assert_eq!(inc, full, "rollback left stale draft state behind");
    }

    #[test]
    fn spec_config_and_stats_basics() {
        assert_eq!(SpecConfig::with_k(0), SpecConfig::disabled());
        assert!(SpecConfig::with_k(4).enabled);
        let st = SpecStats { drafted: 8, accepted: 6, ..Default::default() };
        assert!((st.acceptance() - 0.75).abs() < 1e-12);
        assert_eq!(st.rejected(), 2);
        assert_eq!(SpecStats::default().acceptance(), 0.0);
        assert_eq!(SpecStats::default().rejected(), 0);
    }
}
