//! Engine weight storage: every transformer linear as a GEMV-ready layer in
//! paper orientation `[out, in]` (y = W x), plus the small fp32 residue
//! (embeddings and norm gains) kept dense.
//!
//! A [`Linear`] is either `Packed` — the HBLLM deployment form, Haar-domain
//! sign bits + per-row per-band (α, μ) — or `Dense` fp32, so the same
//! engine serves both the quantized model and the full-precision reference.
//! Row-parallel GEMV lives here: above a work threshold the rows are split
//! across scoped std threads (rayon is unavailable offline).

use crate::model::{ModelConfig, Tensor, Weights};
use crate::pack::{format, HaarPackedLinear};
use crate::tensor::Matrix;
use anyhow::{anyhow, bail, ensure, Result};

/// Minimum rows × cols before a GEMV fans out across threads; below this the
/// spawn cost dominates the dot products.
const PAR_MIN_WORK: usize = 1 << 20;

/// One GEMV-executable linear layer, `[out, in]` orientation.
pub enum Linear {
    /// fp32 rows (reference / non-quantized serving).
    Dense(Matrix),
    /// 1-bit Haar-packed rows (HBLLM deployment form).
    Packed(HaarPackedLinear),
}

impl Linear {
    pub fn rows(&self) -> usize {
        match self {
            Linear::Dense(m) => m.rows,
            Linear::Packed(p) => p.bits.rows,
        }
    }

    pub fn cols(&self) -> usize {
        match self {
            Linear::Dense(m) => m.cols,
            Linear::Packed(p) => p.bits.cols,
        }
    }

    /// Weight-payload bytes (signs + scales for packed, f32 for dense).
    pub fn storage_bytes(&self) -> usize {
        match self {
            Linear::Dense(m) => m.data.len() * 4,
            // fp16 (α, μ) per row per band + sign words
            Linear::Packed(p) => p.bits.storage_bytes() + p.bits.rows * 2 * 2 * 2,
        }
    }

    /// Dense reconstruction `[out, in]` (the dequantized reference).
    pub fn to_dense(&self) -> Matrix {
        match self {
            Linear::Dense(m) => m.clone(),
            Linear::Packed(p) => p.to_dense(),
        }
    }

    /// y = W x. Allocates the packed path's adjoint scratch; the engine hot
    /// loop uses [`Linear::gemv_scratch`] with an arena buffer instead.
    pub fn gemv(&self, x: &[f32], y: &mut [f32], threads: usize) {
        let mut z = Vec::new();
        self.gemv_scratch(x, y, &mut z, threads);
    }

    /// y = W x with a caller-provided adjoint-activation scratch (`z`, only
    /// touched by the packed path; resized to the layer's input width).
    /// Rows fan out across scoped threads when the layer is big enough and
    /// `threads > 1` — the spawn cost is bounded by PAR_MIN_WORK to stay a
    /// small fraction of the dot-product work.
    pub fn gemv_scratch(&self, x: &[f32], y: &mut [f32], z: &mut Vec<f32>, threads: usize) {
        debug_assert_eq!(x.len(), self.cols());
        debug_assert_eq!(y.len(), self.rows());
        let n = self.rows();
        let par = threads.min(n).max(1);
        if par <= 1 || n * self.cols() < PAR_MIN_WORK {
            match self {
                Linear::Dense(m) => dense_gemv_rows(m, x, 0, y),
                Linear::Packed(p) => {
                    let (sum_lo, sum_hi) = p.prepare_activation_into(x, z);
                    p.gemv_rows(z, sum_lo, sum_hi, 0, y);
                }
            }
            return;
        }
        let chunk = (n + par - 1) / par;
        match self {
            Linear::Dense(m) => {
                std::thread::scope(|s| {
                    for (ci, yc) in y.chunks_mut(chunk).enumerate() {
                        s.spawn(move || dense_gemv_rows(m, x, ci * chunk, yc));
                    }
                });
            }
            Linear::Packed(p) => {
                let (sum_lo, sum_hi) = p.prepare_activation_into(x, z);
                let z: &[f32] = z;
                std::thread::scope(|s| {
                    for (ci, yc) in y.chunks_mut(chunk).enumerate() {
                        s.spawn(move || p.gemv_rows(z, sum_lo, sum_hi, ci * chunk, yc));
                    }
                });
            }
        }
    }

    /// Low-band draft GEMV: `y ≈ W x` using only the Haar low band of a
    /// packed layer (see [`HaarPackedLinear::gemv_rows_low`]) — the
    /// frequency-cascade draft model's per-layer kernel. It reads the same
    /// sign words as the full GEMV, skipping the high-band bit range and
    /// scales, so the draft needs no extra weight storage. Dense layers
    /// have no band structure and execute in full (a dense draft is
    /// exact). Single-threaded by design: the draft runs at half the dot
    /// count of the verifier and stays off the thread pool.
    pub fn gemv_low(&self, x: &[f32], y: &mut [f32], z: &mut Vec<f32>) {
        debug_assert_eq!(x.len(), self.cols());
        debug_assert_eq!(y.len(), self.rows());
        match self {
            Linear::Dense(m) => dense_gemv_rows(m, x, 0, y),
            Linear::Packed(p) => {
                let sum_lo = p.prepare_activation_low(x, z);
                p.gemv_rows_low(z, sum_lo, 0, y);
            }
        }
    }

    /// Multi-lane GEMV: `io[l] = (x_l, y_l)` computes `y_l = W x_l` for
    /// every lane in one sweep of the weight rows. The packed path
    /// adjoint-transforms each lane's activation once into `z` (lane `l` at
    /// `[l*cols, (l+1)*cols)`), then every row's sign words are fetched
    /// once and dotted against all lanes — amortizing the bit-unpack and
    /// weight-traffic cost that dominates 1-bit serving. Per-lane
    /// arithmetic is identical to [`Linear::gemv_scratch`] (to which a
    /// single-lane call delegates), so batched and sequential decoding
    /// produce bit-identical results.
    pub fn gemv_batch(
        &self,
        io: &mut [(&[f32], &mut [f32])],
        z: &mut Vec<f32>,
        threads: usize,
    ) {
        let lanes = io.len();
        if lanes == 0 {
            return;
        }
        if lanes == 1 {
            let (x, y) = &mut io[0];
            self.gemv_scratch(x, y, z, threads);
            return;
        }
        let (n, m) = (self.rows(), self.cols());
        for (x, y) in io.iter() {
            debug_assert_eq!(x.len(), m);
            debug_assert_eq!(y.len(), n);
        }
        // packed prologue: every lane's adjoint activation, side by side
        let mut sums: Vec<(f32, f32)> = Vec::with_capacity(lanes);
        if let Linear::Packed(p) = self {
            z.resize(lanes * m, 0.0);
            for (l, (x, _)) in io.iter().enumerate() {
                sums.push(p.prepare_activation_slice(x, &mut z[l * m..(l + 1) * m]));
            }
        }
        let par = threads.min(n).max(1);
        if par <= 1 || n * m * lanes < PAR_MIN_WORK {
            let mut xs: Vec<&[f32]> = Vec::with_capacity(lanes);
            let mut ys: Vec<&mut [f32]> = Vec::with_capacity(lanes);
            for (x, y) in io.iter_mut() {
                xs.push(*x);
                ys.push(&mut **y);
            }
            match self {
                Linear::Dense(mat) => dense_gemv_rows_lanes(mat, &xs, 0, &mut ys),
                Linear::Packed(p) => p.gemv_rows_lanes(z, &sums, 0, &mut ys),
            }
            return;
        }
        // row-parallel: split every lane's output at the same row
        // boundaries, so each thread sweeps a row range across all lanes
        let chunk = (n + par - 1) / par;
        let n_chunks = (n + chunk - 1) / chunk;
        let mut chunks: Vec<Vec<&mut [f32]>> =
            (0..n_chunks).map(|_| Vec::with_capacity(lanes)).collect();
        let mut xs: Vec<&[f32]> = Vec::with_capacity(lanes);
        for (x, y) in io.iter_mut() {
            xs.push(*x);
            let mut rest: &mut [f32] = y;
            for slot in chunks.iter_mut() {
                let take = chunk.min(rest.len());
                let (head, tail) = rest.split_at_mut(take);
                slot.push(head);
                rest = tail;
            }
        }
        let xs = &xs;
        let sums = &sums;
        let z: &[f32] = z;
        std::thread::scope(|s| {
            for (ci, mut ys) in chunks.into_iter().enumerate() {
                match self {
                    Linear::Dense(mat) => {
                        s.spawn(move || dense_gemv_rows_lanes(mat, xs, ci * chunk, &mut ys));
                    }
                    Linear::Packed(p) => {
                        s.spawn(move || p.gemv_rows_lanes(z, sums, ci * chunk, &mut ys));
                    }
                }
            }
        });
    }
}

/// Name → record index over a loaded HBQ1 artifact's records.
type ArtifactRecs<'a> = std::collections::BTreeMap<&'a str, &'a format::Record>;

fn artifact_rec<'a>(recs: &ArtifactRecs<'a>, name: &str) -> Result<&'a format::Record> {
    recs.get(name)
        .copied()
        .ok_or_else(|| anyhow!("artifact missing record {name:?}"))
}

fn artifact_vec1(recs: &ArtifactRecs<'_>, name: &str, expect: usize) -> Result<Vec<f32>> {
    match artifact_rec(recs, name)? {
        format::Record::Dense { data, .. } => {
            ensure!(
                data.len() == expect,
                "record {name:?}: {} values do not match config length {expect}",
                data.len()
            );
            Ok(data.clone())
        }
        format::Record::Packed(_) => bail!("record {name:?} is packed, expected an fp32 vector"),
    }
}

fn artifact_mat(recs: &ArtifactRecs<'_>, name: &str, rows: usize, cols: usize) -> Result<Matrix> {
    match artifact_rec(recs, name)? {
        format::Record::Dense { rows: r, cols: c, data } => {
            ensure!(
                (*r, *c) == (rows, cols),
                "record {name:?}: {r}x{c} does not match config {rows}x{cols}"
            );
            Ok(Matrix::from_vec(*r, *c, data.clone()))
        }
        format::Record::Packed(_) => bail!("record {name:?} is packed, expected fp32"),
    }
}

/// Artifact linears: packed records are stored in paper orientation
/// `[out, in]` (ready to execute as-is), dense ones in model orientation
/// `[in, out]` (transposed here, as `PackedModel::from_weights` does).
fn artifact_linear(recs: &ArtifactRecs<'_>, cfg: &ModelConfig, name: &str) -> Result<Linear> {
    let sh = cfg
        .param_shapes
        .get(name)
        .ok_or_else(|| anyhow!("config has no shape for {name:?}"))?;
    ensure!(sh.len() == 2, "config shape for {name:?} is not 2-D");
    let (n_in, n_out) = (sh[0], sh[1]);
    match artifact_rec(recs, name)? {
        format::Record::Packed(p) => {
            ensure!(
                (p.bits.rows, p.bits.cols) == (n_out, n_in),
                "record {name:?}: packed {}x{} does not match config [out={n_out}, in={n_in}]",
                p.bits.rows,
                p.bits.cols
            );
            Ok(Linear::Packed(p.clone()))
        }
        format::Record::Dense { .. } => {
            Ok(Linear::Dense(artifact_mat(recs, name, n_in, n_out)?.transpose()))
        }
    }
}

fn dense_gemv_rows(m: &Matrix, x: &[f32], i0: usize, y: &mut [f32]) {
    for (k, out) in y.iter_mut().enumerate() {
        *out = m
            .row(i0 + k)
            .iter()
            .zip(x.iter())
            .map(|(&a, &b)| a * b)
            .sum();
    }
}

/// Multi-lane variant of [`dense_gemv_rows`]: each weight row is fetched
/// once and dotted against every lane's activation. The per-lane dot uses
/// the exact expression of the single-lane path, so results are
/// bit-identical.
fn dense_gemv_rows_lanes(m: &Matrix, xs: &[&[f32]], i0: usize, ys: &mut [&mut [f32]]) {
    let rows = ys.first().map_or(0, |y| y.len());
    for k in 0..rows {
        let row = m.row(i0 + k);
        for (x, y) in xs.iter().zip(ys.iter_mut()) {
            y[k] = row.iter().zip(x.iter()).map(|(&a, &b)| a * b).sum();
        }
    }
}

/// One transformer block's engine weights.
pub struct LayerWeights {
    pub ln1: Vec<f32>,
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub ln2: Vec<f32>,
    pub w1: Linear,
    pub w2: Linear,
}

/// The whole model in serving form: packed (or dense) linears + fp32 residue.
pub struct PackedModel {
    pub config: ModelConfig,
    /// [vocab, d]
    pub tok_emb: Matrix,
    /// [seq, d]
    pub pos_emb: Matrix,
    pub layers: Vec<LayerWeights>,
    pub ln_f: Vec<f32>,
    /// [vocab, d] — transposed from the model's `[d, vocab]` unembed.
    pub unemb: Linear,
}

impl PackedModel {
    /// Build from model weights. With `pack = true` every linear (attention
    /// projections, FFN, unembed) is refit into the Haar-packed 1-bit form;
    /// with `pack = false` the linears stay dense fp32 (reference engine).
    ///
    /// Note packing is itself a (re-)quantization: pass already-quantized
    /// weights to serve a PTQ model, and compare against [`Self::to_weights`]
    /// — the engine's own dequantized reference — for parity checks.
    pub fn from_weights(w: &Weights, pack: bool) -> Result<PackedModel> {
        let cfg = w.config.clone();
        ensure!(cfg.d_model % 2 == 0, "engine needs even d_model (row Haar)");
        ensure!(cfg.d_ff % 2 == 0, "engine needs even d_ff (row Haar)");
        let linear = |name: &str| -> Result<Linear> {
            // model stores [in, out] (x @ W); the engine wants [out, in].
            // Packing can fail with a typed `OddWidth` — unreachable after
            // the even d_model/d_ff guards above, but propagated rather
            // than asserted so the invariant lives in one place (pack/).
            let t = w.get(name).as_mat().transpose();
            Ok(if pack {
                Linear::Packed(HaarPackedLinear::from_dense(&t)?)
            } else {
                Linear::Dense(t)
            })
        };
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |k: &str| format!("l{i}.{k}");
            layers.push(LayerWeights {
                ln1: w.get(&p("ln1")).as_vec().to_vec(),
                wq: linear(&p("wq"))?,
                wk: linear(&p("wk"))?,
                wv: linear(&p("wv"))?,
                wo: linear(&p("wo"))?,
                ln2: w.get(&p("ln2")).as_vec().to_vec(),
                w1: linear(&p("w1"))?,
                w2: linear(&p("w2"))?,
            });
        }
        Ok(PackedModel {
            tok_emb: w.get("tok_emb").as_mat().clone(),
            pos_emb: w.get("pos_emb").as_mat().clone(),
            layers,
            ln_f: w.get("ln_f").as_vec().to_vec(),
            unemb: linear("unemb")?,
            config: cfg,
        })
    }

    /// Build the serving model straight from a saved HBQ1 artifact
    /// (`docs/FORMAT.md`): packed linear records execute as-is — no
    /// dequantize→requantize round trip, so serving from disk is
    /// bit-identical to serving the model that was saved — and dense
    /// records fill the fp32 residue. The artifact stores no model
    /// config; the caller supplies it (the CLI reads it from the
    /// artifacts manifest) and every record's shape is validated against
    /// it before anything is built.
    pub fn from_artifact(cfg: &ModelConfig, art: &format::PackedModel) -> Result<PackedModel> {
        ensure!(cfg.d_model % 2 == 0, "engine needs even d_model (row Haar)");
        ensure!(cfg.d_ff % 2 == 0, "engine needs even d_ff (row Haar)");
        let mut recs: ArtifactRecs<'_> = std::collections::BTreeMap::new();
        for (name, rec) in &art.records {
            recs.insert(name.as_str(), rec);
        }
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for i in 0..cfg.n_layers {
            let p = |k: &str| format!("l{i}.{k}");
            layers.push(LayerWeights {
                ln1: artifact_vec1(&recs, &p("ln1"), cfg.d_model)?,
                wq: artifact_linear(&recs, cfg, &p("wq"))?,
                wk: artifact_linear(&recs, cfg, &p("wk"))?,
                wv: artifact_linear(&recs, cfg, &p("wv"))?,
                wo: artifact_linear(&recs, cfg, &p("wo"))?,
                ln2: artifact_vec1(&recs, &p("ln2"), cfg.d_model)?,
                w1: artifact_linear(&recs, cfg, &p("w1"))?,
                w2: artifact_linear(&recs, cfg, &p("w2"))?,
            });
        }
        Ok(PackedModel {
            tok_emb: artifact_mat(&recs, "tok_emb", cfg.vocab, cfg.d_model)?,
            pos_emb: artifact_mat(&recs, "pos_emb", cfg.seq_len, cfg.d_model)?,
            layers,
            ln_f: artifact_vec1(&recs, "ln_f", cfg.d_model)?,
            unemb: artifact_linear(&recs, cfg, "unemb")?,
            config: cfg.clone(),
        })
    }

    /// Dequantized reference: a `Weights` whose linears are the dense
    /// reconstruction of this model's layers. `model::forward` over the
    /// result is the ground truth the engine's packed forward must match.
    pub fn to_weights(&self) -> Weights {
        let mut tensors = std::collections::BTreeMap::new();
        tensors.insert("tok_emb".to_string(), Tensor::Mat(self.tok_emb.clone()));
        tensors.insert("pos_emb".to_string(), Tensor::Mat(self.pos_emb.clone()));
        for (i, l) in self.layers.iter().enumerate() {
            let p = |k: &str| format!("l{i}.{k}");
            tensors.insert(p("ln1"), Tensor::Vec1(l.ln1.clone()));
            tensors.insert(p("wq"), Tensor::Mat(l.wq.to_dense().transpose()));
            tensors.insert(p("wk"), Tensor::Mat(l.wk.to_dense().transpose()));
            tensors.insert(p("wv"), Tensor::Mat(l.wv.to_dense().transpose()));
            tensors.insert(p("wo"), Tensor::Mat(l.wo.to_dense().transpose()));
            tensors.insert(p("ln2"), Tensor::Vec1(l.ln2.clone()));
            tensors.insert(p("w1"), Tensor::Mat(l.w1.to_dense().transpose()));
            tensors.insert(p("w2"), Tensor::Mat(l.w2.to_dense().transpose()));
        }
        tensors.insert("ln_f".to_string(), Tensor::Vec1(self.ln_f.clone()));
        tensors.insert("unemb".to_string(), Tensor::Mat(self.unemb.to_dense().transpose()));
        Weights { config: self.config.clone(), tensors }
    }

    /// Total linear-layer weight payload (the memory-traffic argument).
    pub fn linear_bytes(&self) -> usize {
        let mut total = self.unemb.storage_bytes();
        for l in &self.layers {
            for lin in [&l.wq, &l.wk, &l.wv, &l.wo, &l.w1, &l.w2] {
                total += lin.storage_bytes();
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testing::micro_weights;
    use crate::util::rng::Pcg32;

    #[test]
    fn dense_linear_gemv_matches_matvec() {
        let mut rng = Pcg32::seeded(1);
        let m = Matrix::from_fn(13, 16, |_, _| rng.normal_f32());
        let x: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let lin = Linear::Dense(m.clone());
        let mut y = vec![0.0; 13];
        lin.gemv(&x, &mut y, 4);
        assert_eq!(y, m.matvec(&x));
    }

    #[test]
    fn packed_linear_gemv_matches_pack_gemv() {
        let mut rng = Pcg32::seeded(2);
        let m = Matrix::from_fn(9, 64, |_, _| rng.normal_f32());
        let p = HaarPackedLinear::from_dense(&m).unwrap();
        let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let mut want = vec![0.0; 9];
        p.gemv(&x, &mut want);
        let lin = Linear::Packed(p);
        let mut y = vec![0.0; 9];
        lin.gemv(&x, &mut y, 3);
        assert_eq!(y, want);
    }

    #[test]
    fn gemv_batch_matches_per_lane_gemv() {
        let mut rng = Pcg32::seeded(7);
        let dense = Linear::Dense(Matrix::from_fn(11, 32, |_, _| rng.normal_f32()));
        let packed = Linear::Packed(
            HaarPackedLinear::from_dense(&Matrix::from_fn(11, 32, |_, _| rng.normal_f32()))
                .unwrap(),
        );
        for lin in [&dense, &packed] {
            let xs: Vec<Vec<f32>> = (0..3)
                .map(|_| (0..32).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut want: Vec<Vec<f32>> = Vec::new();
            for x in &xs {
                let mut y = vec![0.0; 11];
                lin.gemv(x, &mut y, 1);
                want.push(y);
            }
            let mut got: Vec<Vec<f32>> = (0..3).map(|_| vec![0.0; 11]).collect();
            let mut io: Vec<(&[f32], &mut [f32])> = xs
                .iter()
                .zip(got.iter_mut())
                .map(|(x, y)| (x.as_slice(), y.as_mut_slice()))
                .collect();
            let mut z = Vec::new();
            lin.gemv_batch(&mut io, &mut z, 2);
            drop(io);
            assert_eq!(got, want, "multi-lane gemv diverged from per-lane");
        }
    }

    #[test]
    fn linear_gemv_low_matches_pack_low_and_dense_full() {
        let mut rng = Pcg32::seeded(3);
        let m = Matrix::from_fn(9, 64, |_, _| rng.normal_f32());
        let p = HaarPackedLinear::from_dense(&m).unwrap();
        let x: Vec<f32> = (0..64).map(|_| rng.normal_f32()).collect();
        let mut want = vec![0.0; 9];
        p.gemv_low(&x, &mut want);
        let lin = Linear::Packed(p);
        let mut y = vec![0.0; 9];
        let mut z = Vec::new();
        lin.gemv_low(&x, &mut y, &mut z);
        assert_eq!(y, want);
        // a dense layer has no bands: its draft view is the full GEMV
        let d = Linear::Dense(m.clone());
        let mut yd = vec![0.0; 9];
        d.gemv_low(&x, &mut yd, &mut z);
        assert_eq!(yd, m.matvec(&x));
    }

    #[test]
    fn from_artifact_roundtrip_is_deterministic_and_validates() {
        let w = micro_weights(42);
        let art = format::PackedModel::from_weights(&w);
        let loaded = format::PackedModel::from_bytes(&art.to_bytes()).unwrap();
        let pm = PackedModel::from_artifact(&w.config, &loaded).unwrap();
        assert_eq!(pm.layers.len(), w.config.n_layers);
        assert_eq!((pm.unemb.rows(), pm.unemb.cols()), (256, 16));
        assert!(matches!(pm.layers[0].wq, Linear::Packed(_)), "linears load packed");
        // packed records execute as-is: re-loading the same bytes yields a
        // bit-identical engine (fp16 scale quantization is idempotent)
        let loaded2 = format::PackedModel::from_bytes(&loaded.to_bytes()).unwrap();
        let pm2 = PackedModel::from_artifact(&w.config, &loaded2).unwrap();
        let x: Vec<f32> = (0..16).map(|i| (i as f32) * 0.1 - 0.5).collect();
        let mut y1 = vec![0.0; 16];
        let mut y2 = vec![0.0; 16];
        pm.layers[0].wq.gemv(&x, &mut y1, 1);
        pm2.layers[0].wq.gemv(&x, &mut y2, 1);
        assert_eq!(y1, y2);
        // a vector record of the wrong length is a load-time error, not a
        // mid-request rmsnorm panic (format::from_bytes only checks the
        // record against its own header, not against the model config)
        let mut short = format::PackedModel::from_bytes(&loaded2.to_bytes()).unwrap();
        for (n, r) in short.records.iter_mut() {
            if n == "ln_f" {
                *r = format::Record::Dense { rows: 1, cols: 4, data: vec![1.0; 4] };
            }
        }
        assert!(PackedModel::from_artifact(&w.config, &short).is_err(), "short ln_f accepted");
        // a missing record is a load error, not a panic
        let mut broken = format::PackedModel { records: loaded.records };
        broken.records.retain(|(n, _)| n != "ln_f");
        assert!(PackedModel::from_artifact(&w.config, &broken).is_err());
    }

    #[test]
    fn from_weights_shapes() {
        let w = micro_weights(3);
        let pm = PackedModel::from_weights(&w, true).unwrap();
        assert_eq!(pm.layers.len(), w.config.n_layers);
        let l0 = &pm.layers[0];
        assert_eq!((l0.wq.rows(), l0.wq.cols()), (16, 16));
        assert_eq!((l0.w1.rows(), l0.w1.cols()), (32, 16));
        assert_eq!((l0.w2.rows(), l0.w2.cols()), (16, 32));
        assert_eq!((pm.unemb.rows(), pm.unemb.cols()), (256, 16));
        // 1-bit packing shrinks the linear payload (at micro dims the
        // per-row scale + word padding overhead keeps it far from 1/32)
        let dense = PackedModel::from_weights(&w, false).unwrap();
        assert!(pm.linear_bytes() < dense.linear_bytes());
    }

    #[test]
    fn to_weights_roundtrips_dense_exactly() {
        let w = micro_weights(4);
        let pm = PackedModel::from_weights(&w, false).unwrap();
        let back = pm.to_weights();
        for name in w.config.linear_names() {
            let a = w.get(&name).as_mat();
            let b = back.get(&name).as_mat();
            assert!(a.mse(b) < 1e-12, "{name}");
        }
        assert_eq!(back.get("ln_f").as_vec(), w.get("ln_f").as_vec());
    }
}
