//! XLA backend: the existing PJRT runners behind the [`Backend`] trait.
//!
//! `nll`/`logits` delegate to the AOT HLO entry points with device-resident
//! weights. `decode_step` has no KV cache — the AOT module is fixed-shape —
//! so each step re-forwards the whole window; it exists as the baseline the
//! native engine's incremental path is benchmarked against.
//!
//! Multi-lane decoding uses the trait's default single-lane fallback:
//! `decode_step` is stateless (the window is rebuilt from the text every
//! call), so `decode_batch` simply re-forwards each `(lane, text)` pair
//! sequentially and `reset`/`reset_lane` are no-ops. The generation
//! scheduler still works against this backend — it just gets no
//! weight-sweep amortization.

use super::Backend;
use crate::data::ByteTokenizer;
use crate::runtime::{LogitsRunner, NllRunner};
use anyhow::{anyhow, ensure, Result};

pub struct XlaBackend {
    nll: NllRunner,
    /// Present only when built via `Session::gen_backend` (the logits HLO
    /// entry is a separate compile; scoring-only callers skip it).
    generator: Option<LogitsRunner>,
}

impl XlaBackend {
    pub fn new(nll: NllRunner, generator: Option<LogitsRunner>) -> XlaBackend {
        XlaBackend { nll, generator }
    }

    fn generator(&self) -> Result<&LogitsRunner> {
        self.generator
            .as_ref()
            .ok_or_else(|| anyhow!("xla backend built without the logits entry (scoring-only)"))
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> String {
        "xla".to_string()
    }

    fn batch(&self) -> usize {
        self.nll.batch
    }

    fn seq(&self) -> usize {
        self.nll.seq
    }

    fn vocab(&self) -> usize {
        self.generator.as_ref().map(|g| g.vocab).unwrap_or(ByteTokenizer::VOCAB)
    }

    fn nll(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.nll.nll(tokens)
    }

    fn logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.generator()?.logits(tokens)
    }

    fn decode_step(&mut self, text: &[u8]) -> Result<Vec<f32>> {
        let gen = self.generator()?;
        let (b, s, v) = (gen.batch(), gen.seq(), gen.vocab);
        ensure!(s >= 2, "seq too short for decoding");
        // same windowing as LogitsRunner::generate, with the empty text
        // seeded by the pad byte
        let window: &[u8] = if text.is_empty() {
            const SEED: [u8; 1] = [ByteTokenizer::PAD];
            &SEED
        } else {
            &text[text.len().saturating_sub(s - 1)..]
        };
        let pos = window.len() - 1;
        let mut tokens = vec![ByteTokenizer::PAD as i32; b * s];
        for (c, &byte) in window.iter().enumerate() {
            tokens[c] = byte as i32;
        }
        let logits = gen.logits(&tokens)?;
        Ok(logits[pos * v..(pos + 1) * v].to_vec())
    }

    fn reset(&mut self) {}
}
