//! KV state for the incremental decode path: single-sequence caches, the
//! per-position scratch arena, and the multi-sequence lane pool that backs
//! continuous-batching generation.
//!
//! `KvCache` holds the per-layer attention keys/values as one flat
//! `[n_layers, seq, d_model]` f32 buffer each, allocated once at backend
//! construction. A decode step writes row `len` for every layer, attends
//! over rows `0..=len`, and bumps `len` — no per-token allocation.
//!
//! `Arena` is the matching scratch space: every intermediate of the
//! per-position forward (norm outputs, q/k/v, attention mix, FFN hidden,
//! logits) lives in a preallocated buffer, so after startup the decode hot
//! loop's only allocation is the logits row each `decode_step` hands back
//! to the caller.
//!
//! `KvPool` is N independent `Lane`s (cache + arena + consumed prefix)
//! over one shared model: each concurrently-decoding sequence owns a lane,
//! while the packed weights are swept once per token across all active
//! lanes (see `NativeBackend::decode_batch`).

use crate::model::ModelConfig;

/// Per-layer attention K/V rows for positions `0..len`.
pub struct KvCache {
    pub n_layers: usize,
    pub seq: usize,
    pub d: usize,
    /// Positions filled so far (uniform across layers).
    pub len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(n_layers: usize, seq: usize, d: usize) -> KvCache {
        KvCache {
            n_layers,
            seq,
            d,
            len: 0,
            k: vec![0.0; n_layers * seq * d],
            v: vec![0.0; n_layers * seq * d],
        }
    }

    /// Logical reset; the buffers are reused, not zeroed.
    pub fn clear(&mut self) {
        self.len = 0;
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.seq
    }

    #[inline]
    fn idx(&self, layer: usize, pos: usize) -> usize {
        debug_assert!(layer < self.n_layers && pos < self.seq);
        (layer * self.seq + pos) * self.d
    }

    /// Store the K/V rows for `pos` in `layer` (callers bump `len` once per
    /// position via [`KvCache::advance`] after all layers stored).
    pub fn store(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let o = self.idx(layer, pos);
        self.k[o..o + self.d].copy_from_slice(k_row);
        self.v[o..o + self.d].copy_from_slice(v_row);
    }

    pub fn advance(&mut self) {
        debug_assert!(self.len < self.seq, "kv cache overflow");
        self.len += 1;
    }

    #[inline]
    pub fn key(&self, layer: usize, pos: usize) -> &[f32] {
        let o = self.idx(layer, pos);
        &self.k[o..o + self.d]
    }

    #[inline]
    pub fn val(&self, layer: usize, pos: usize) -> &[f32] {
        let o = self.idx(layer, pos);
        &self.v[o..o + self.d]
    }

    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

/// Preallocated scratch buffers for one decode position.
pub struct Arena {
    /// residual stream `[d]`
    pub x: Vec<f32>,
    /// rmsnorm output `[d]`
    pub h: Vec<f32>,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// attention mix `[d]`
    pub attn: Vec<f32>,
    /// wo / w2 output, added back into the residual `[d]`
    pub proj: Vec<f32>,
    /// FFN hidden `[d_ff]`
    pub ff: Vec<f32>,
    /// attention probabilities `[seq]`
    pub probs: Vec<f32>,
    /// next-token logits `[vocab]`
    pub logits: Vec<f32>,
}

impl Arena {
    pub fn new(cfg: &ModelConfig) -> Arena {
        let d = cfg.d_model;
        Arena {
            x: vec![0.0; d],
            h: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            attn: vec![0.0; d],
            proj: vec![0.0; d],
            ff: vec![0.0; cfg.d_ff],
            probs: vec![0.0; cfg.seq_len],
            logits: vec![0.0; cfg.vocab],
        }
    }
}

/// One decode lane: an independent KV sequence + per-position scratch +
/// the bytes currently materialized in the cache.
pub struct Lane {
    pub cache: KvCache,
    pub arena: Arena,
    /// Bytes whose K/V rows fill `cache` positions `0..cache.len`.
    pub prefix: Vec<u8>,
}

impl Lane {
    pub fn new(cfg: &ModelConfig) -> Lane {
        Lane {
            cache: KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model),
            arena: Arena::new(cfg),
            prefix: Vec::new(),
        }
    }

    /// Logical reset (buffers reused, not reallocated).
    pub fn clear(&mut self) {
        self.cache.clear();
        self.prefix.clear();
    }
}

/// N independent KV lanes over one shared model — the state side of
/// continuous batching. Lane `i` hosts one sequence; admission/eviction is
/// the scheduler's job (`coordinator::scheduler::GenScheduler`), the pool
/// just owns the memory.
pub struct KvPool {
    pub lanes: Vec<Lane>,
}

impl KvPool {
    /// Allocate `n` lanes (at least one). Each lane owns its own KV buffer
    /// (`2 × n_layers × seq × d_model` f32) and scratch arena.
    pub fn new(cfg: &ModelConfig, n: usize) -> KvPool {
        KvPool { lanes: (0..n.max(1)).map(|_| Lane::new(cfg)).collect() }
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    pub fn clear_all(&mut self) {
        for lane in &mut self.lanes {
            lane.clear();
        }
    }

    /// Total KV-cache bytes across lanes (capacity, not fill level).
    pub fn bytes(&self) -> usize {
        self.lanes.iter().map(|l| l.cache.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testing::micro_weights;

    #[test]
    fn kv_store_and_read_back() {
        let mut c = KvCache::new(2, 4, 3);
        let k0 = [1.0, 2.0, 3.0];
        let v0 = [4.0, 5.0, 6.0];
        c.store(1, 0, &k0, &v0);
        c.advance();
        assert_eq!(c.key(1, 0), &k0);
        assert_eq!(c.val(1, 0), &v0);
        assert_eq!(c.len, 1);
        c.clear();
        assert_eq!(c.len, 0);
        assert!(!c.is_full());
    }

    #[test]
    fn kv_full_detection() {
        let mut c = KvCache::new(1, 2, 1);
        c.store(0, 0, &[0.0], &[0.0]);
        c.advance();
        c.store(0, 1, &[0.0], &[0.0]);
        c.advance();
        assert!(c.is_full());
    }

    #[test]
    fn pool_allocates_independent_lanes() {
        let cfg = micro_weights(1).config;
        let mut pool = KvPool::new(&cfg, 3);
        assert_eq!(pool.len(), 3);
        assert_eq!(pool.bytes(), 3 * pool.lanes[0].cache.bytes());
        let zeros = vec![0.0; cfg.d_model];
        pool.lanes[1].cache.store(0, 0, &zeros, &zeros);
        pool.lanes[1].cache.advance();
        pool.lanes[1].prefix.push(7);
        assert_eq!(pool.lanes[0].cache.len, 0, "lanes share state");
        pool.clear_all();
        assert_eq!(pool.lanes[1].cache.len, 0);
        assert!(pool.lanes[1].prefix.is_empty());
    }

    #[test]
    fn pool_never_empty() {
        let cfg = micro_weights(2).config;
        assert_eq!(KvPool::new(&cfg, 0).len(), 1);
    }
}
