//! KV cache and scratch arena for the incremental decode path.
//!
//! `KvCache` holds the per-layer attention keys/values as one flat
//! `[n_layers, seq, d_model]` f32 buffer each, allocated once at backend
//! construction. A decode step writes row `len` for every layer, attends
//! over rows `0..=len`, and bumps `len` — no per-token allocation.
//!
//! `Arena` is the matching scratch space: every intermediate of the
//! per-position forward (norm outputs, q/k/v, attention mix, FFN hidden,
//! the GEMV adjoint scratch, logits) lives in a preallocated buffer, so
//! after startup the decode hot loop's only allocation is the logits row
//! each `decode_step` hands back to the caller.

use crate::model::ModelConfig;

/// Per-layer attention K/V rows for positions `0..len`.
pub struct KvCache {
    pub n_layers: usize,
    pub seq: usize,
    pub d: usize,
    /// Positions filled so far (uniform across layers).
    pub len: usize,
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvCache {
    pub fn new(n_layers: usize, seq: usize, d: usize) -> KvCache {
        KvCache {
            n_layers,
            seq,
            d,
            len: 0,
            k: vec![0.0; n_layers * seq * d],
            v: vec![0.0; n_layers * seq * d],
        }
    }

    /// Logical reset; the buffers are reused, not zeroed.
    pub fn clear(&mut self) {
        self.len = 0;
    }

    pub fn is_full(&self) -> bool {
        self.len >= self.seq
    }

    #[inline]
    fn idx(&self, layer: usize, pos: usize) -> usize {
        debug_assert!(layer < self.n_layers && pos < self.seq);
        (layer * self.seq + pos) * self.d
    }

    /// Store the K/V rows for `pos` in `layer` (callers bump `len` once per
    /// position via [`KvCache::advance`] after all layers stored).
    pub fn store(&mut self, layer: usize, pos: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let o = self.idx(layer, pos);
        self.k[o..o + self.d].copy_from_slice(k_row);
        self.v[o..o + self.d].copy_from_slice(v_row);
    }

    pub fn advance(&mut self) {
        debug_assert!(self.len < self.seq, "kv cache overflow");
        self.len += 1;
    }

    #[inline]
    pub fn key(&self, layer: usize, pos: usize) -> &[f32] {
        let o = self.idx(layer, pos);
        &self.k[o..o + self.d]
    }

    #[inline]
    pub fn val(&self, layer: usize, pos: usize) -> &[f32] {
        let o = self.idx(layer, pos);
        &self.v[o..o + self.d]
    }

    pub fn bytes(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }
}

/// Preallocated scratch buffers for one decode position.
pub struct Arena {
    /// residual stream [d]
    pub x: Vec<f32>,
    /// rmsnorm output [d]
    pub h: Vec<f32>,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// attention mix [d]
    pub attn: Vec<f32>,
    /// wo / w2 output, added back into the residual [d]
    pub proj: Vec<f32>,
    /// FFN hidden [d_ff]
    pub ff: Vec<f32>,
    /// attention probabilities [seq]
    pub probs: Vec<f32>,
    /// packed-GEMV adjoint-activation scratch [max(d, d_ff)]
    pub zbuf: Vec<f32>,
    /// next-token logits [vocab]
    pub logits: Vec<f32>,
}

impl Arena {
    pub fn new(cfg: &ModelConfig) -> Arena {
        let d = cfg.d_model;
        Arena {
            x: vec![0.0; d],
            h: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            attn: vec![0.0; d],
            proj: vec![0.0; d],
            ff: vec![0.0; cfg.d_ff],
            probs: vec![0.0; cfg.seq_len],
            zbuf: vec![0.0; d.max(cfg.d_ff)],
            logits: vec![0.0; cfg.vocab],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_store_and_read_back() {
        let mut c = KvCache::new(2, 4, 3);
        let k0 = [1.0, 2.0, 3.0];
        let v0 = [4.0, 5.0, 6.0];
        c.store(1, 0, &k0, &v0);
        c.advance();
        assert_eq!(c.key(1, 0), &k0);
        assert_eq!(c.val(1, 0), &v0);
        assert_eq!(c.len, 1);
        c.clear();
        assert_eq!(c.len, 0);
        assert!(!c.is_full());
    }

    #[test]
    fn kv_full_detection() {
        let mut c = KvCache::new(1, 2, 1);
        c.store(0, 0, &[0.0], &[0.0]);
        c.advance();
        c.store(0, 1, &[0.0], &[0.0]);
        c.advance();
        assert!(c.is_full());
    }
}
