//! KV state for the incremental decode path: the per-position scratch
//! arena and the multi-sequence lane pool that backs continuous-batching
//! generation, built on the paged block memory in [`super::paged`].
//!
//! A [`Lane`] no longer owns a flat worst-case `[n_layers, seq, d]`
//! buffer; it holds a [`PagedKv`] *view* — a block table into the pool's
//! shared [`KvBlockPool`] arena — so lane count is bounded by traffic, not
//! by a hard per-lane allocation. A decode step writes row `len` for every
//! layer through the view, attends over rows `0..=len`, and bumps `len`;
//! blocks are allocated one at a time as the sequence grows and all
//! released on eviction/reset.
//!
//! `Arena` is the matching scratch space: every intermediate of the
//! per-position forward (norm outputs, q/k/v, attention mix, FFN hidden,
//! logits) lives in a preallocated buffer, so after startup the decode hot
//! loop's only allocation is the logits row each `decode_step` hands back
//! to the caller (plus at most one KV block grab per `block_len` tokens).
//!
//! `KvPool` is N lanes (view + arena + consumed prefix) plus the one
//! shared block arena, over one shared model: each concurrently-decoding
//! sequence owns a lane, while the packed weights are swept once per token
//! across all active lanes (see `NativeBackend::decode_batch`).

use super::paged::{blocks_for, KvBlockPool, PagedKv, DEFAULT_BLOCK_LEN};
use super::KvStats;
use crate::model::ModelConfig;

/// Preallocated scratch buffers for one decode position.
pub struct Arena {
    /// residual stream `[d]`
    pub x: Vec<f32>,
    /// rmsnorm output `[d]`
    pub h: Vec<f32>,
    pub q: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// attention mix `[d]`
    pub attn: Vec<f32>,
    /// wo / w2 output, added back into the residual `[d]`
    pub proj: Vec<f32>,
    /// FFN hidden `[d_ff]`
    pub ff: Vec<f32>,
    /// attention probabilities `[seq]`
    pub probs: Vec<f32>,
    /// next-token logits `[vocab]`
    pub logits: Vec<f32>,
}

impl Arena {
    pub fn new(cfg: &ModelConfig) -> Arena {
        let d = cfg.d_model;
        Arena {
            x: vec![0.0; d],
            h: vec![0.0; d],
            q: vec![0.0; d],
            k: vec![0.0; d],
            v: vec![0.0; d],
            attn: vec![0.0; d],
            proj: vec![0.0; d],
            ff: vec![0.0; cfg.d_ff],
            probs: vec![0.0; cfg.seq_len],
            logits: vec![0.0; cfg.vocab],
        }
    }
}

/// One decode lane: a paged view of the shared KV arena + per-position
/// scratch + the bytes currently materialized behind the view.
pub struct Lane {
    pub kv: PagedKv,
    pub arena: Arena,
    /// Bytes whose K/V rows fill positions `0..kv.len()`.
    pub prefix: Vec<u8>,
}

impl Lane {
    pub fn new(cfg: &ModelConfig) -> Lane {
        Lane {
            kv: PagedKv::new(cfg.seq_len),
            arena: Arena::new(cfg),
            prefix: Vec::new(),
        }
    }

    /// Logical reset: releases every KV block back to `blocks` (the
    /// scratch arena is reused, not reallocated).
    pub fn clear(&mut self, blocks: &mut KvBlockPool) {
        self.kv.clear(blocks);
        self.prefix.clear();
    }
}

/// N KV lanes plus the shared block arena they page into — the state side
/// of continuous batching. Lane `i` hosts one sequence; admission/eviction
/// is the scheduler's job (`coordinator::scheduler::GenScheduler`), the
/// pool just owns the memory.
pub struct KvPool {
    /// The shared paged block arena every lane's [`PagedKv`] maps into.
    pub blocks: KvBlockPool,
    pub lanes: Vec<Lane>,
}

impl KvPool {
    /// Allocate `n` lanes (at least one) over a worst-case arena: enough
    /// blocks of [`DEFAULT_BLOCK_LEN`] tokens for every lane to hold a
    /// full `seq_len` window — the memory-equivalent of the old flat
    /// layout, so unconfigured callers never see `KvExhausted`.
    pub fn new(cfg: &ModelConfig, n: usize) -> KvPool {
        let (n_blocks, bl) = KvPool::worst_case_geometry(cfg, n, None);
        KvPool::with_paging(cfg, n, n_blocks, bl)
    }

    /// The worst-case arena geometry `(n_blocks, block_len)` for `n`
    /// lanes: `block_len` (defaulting to [`DEFAULT_BLOCK_LEN`] clamped to
    /// the window) and enough blocks for every lane to hold a full
    /// `seq_len` window. The single source of the default sizing —
    /// [`KvPool::new`] and backend rebuilds both derive from it.
    pub fn worst_case_geometry(
        cfg: &ModelConfig,
        n: usize,
        block_len: Option<usize>,
    ) -> (usize, usize) {
        let bl = block_len
            .unwrap_or(DEFAULT_BLOCK_LEN.min(cfg.seq_len.max(1)))
            .max(1);
        (n.max(1) * blocks_for(cfg.seq_len, bl), bl)
    }

    /// Allocate `n` lanes (at least one) over an explicit arena of
    /// `n_blocks` blocks of `block_len` tokens (both clamped to >= 1).
    /// Sizing below `n * ceil(seq_len / block_len)` is the point: lanes
    /// then share a smaller arena and the serving scheduler turns block
    /// exhaustion into admission backpressure.
    pub fn with_paging(cfg: &ModelConfig, n: usize, n_blocks: usize, block_len: usize) -> KvPool {
        KvPool {
            blocks: KvBlockPool::new(cfg.n_layers, cfg.d_model, n_blocks, block_len),
            lanes: (0..n.max(1)).map(|_| Lane::new(cfg)).collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }

    pub fn clear_all(&mut self) {
        let KvPool { blocks, lanes } = self;
        for lane in lanes.iter_mut() {
            lane.clear(blocks);
        }
    }

    /// Drop one lane's decode state, releasing its KV blocks.
    pub fn reset_lane(&mut self, lane: usize) {
        let KvPool { blocks, lanes } = self;
        if let Some(l) = lanes.get_mut(lane) {
            l.clear(blocks);
        }
    }

    /// Total KV arena bytes (capacity, not fill level).
    pub fn bytes(&self) -> usize {
        self.blocks.bytes()
    }

    /// Occupancy snapshot for the `Backend::kv_stats` surface.
    pub fn stats(&self) -> KvStats {
        KvStats {
            block_len: self.blocks.block_len(),
            total_blocks: self.blocks.n_blocks(),
            free_blocks: self.blocks.free_blocks(),
            used_hwm: self.blocks.used_hwm(),
            shared_blocks: self.blocks.shared_blocks(),
            shared_hwm: self.blocks.shared_hwm(),
            lane_blocks: self.lanes.iter().map(|l| l.kv.held_blocks()).collect(),
            arena_bytes: self.blocks.bytes(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testing::micro_weights;

    #[test]
    fn pool_allocates_independent_lanes() {
        let cfg = micro_weights(1).config;
        let mut pool = KvPool::new(&cfg, 3);
        assert_eq!(pool.len(), 3);
        let zeros = vec![0.0; cfg.d_model];
        let KvPool { blocks, lanes } = &mut pool;
        lanes[1].kv.ensure_pos(blocks, 0).unwrap();
        lanes[1].kv.store(blocks, 0, 0, &zeros, &zeros);
        lanes[1].kv.advance();
        lanes[1].prefix.push(7);
        assert_eq!(pool.lanes[0].kv.len(), 0, "lanes share state");
        assert_eq!(pool.blocks.used_blocks(), 1);
        pool.clear_all();
        assert_eq!(pool.lanes[1].kv.len(), 0);
        assert!(pool.lanes[1].prefix.is_empty());
        assert_eq!(pool.blocks.used_blocks(), 0, "blocks leaked on clear");
    }

    #[test]
    fn worst_case_default_never_exhausts() {
        let cfg = micro_weights(2).config;
        let mut pool = KvPool::new(&cfg, 2);
        let row = vec![0.0; cfg.d_model];
        let KvPool { blocks, lanes } = &mut pool;
        for lane in lanes.iter_mut() {
            for pos in 0..cfg.seq_len {
                lane.kv.ensure_pos(blocks, pos).expect("worst-case sizing exhausted");
                for layer in 0..cfg.n_layers {
                    lane.kv.store(blocks, layer, pos, &row, &row);
                }
                lane.kv.advance();
            }
            assert!(lane.kv.is_full());
        }
    }

    #[test]
    fn undersized_pool_exhausts_and_recovers() {
        let cfg = micro_weights(3).config;
        // one block of 4 tokens total, two lanes contending
        let mut pool = KvPool::with_paging(&cfg, 2, 1, 4);
        let KvPool { blocks, lanes } = &mut pool;
        lanes[0].kv.ensure_pos(blocks, 0).unwrap();
        assert!(lanes[1].kv.ensure_pos(blocks, 0).is_err(), "no backpressure signal");
        pool.reset_lane(0);
        let KvPool { blocks, lanes } = &mut pool;
        lanes[1].kv.ensure_pos(blocks, 0).unwrap();
    }

    #[test]
    fn stats_track_occupancy() {
        let cfg = micro_weights(4).config;
        let mut pool = KvPool::with_paging(&cfg, 2, 4, 4);
        let st = pool.stats();
        assert_eq!((st.total_blocks, st.free_blocks, st.block_len), (4, 4, 4));
        assert_eq!(st.lane_blocks, vec![0, 0]);
        assert_eq!(st.arena_bytes, pool.bytes());
        let KvPool { blocks, lanes } = &mut pool;
        lanes[1].kv.ensure_pos(blocks, 5).unwrap(); // 2 blocks
        let st = pool.stats();
        assert_eq!(st.free_blocks, 2);
        assert_eq!(st.lane_blocks, vec![0, 2]);
    }

    #[test]
    fn pool_never_empty() {
        let cfg = micro_weights(2).config;
        assert_eq!(KvPool::new(&cfg, 0).len(), 1);
    }
}
