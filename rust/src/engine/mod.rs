//! Native packed-weight inference engine — the paper's §4.5 deployment
//! story as an executable serving path, plus the backend abstraction that
//! makes the rest of the system (eval, serving, CLI, examples) agnostic to
//! *how* a model is executed.
//!
//! # The packed forward
//!
//! [`PackedModel`] holds every transformer linear (attention projections,
//! FFN, unembed) as a [`Linear`] in paper orientation `[out, in]`: either
//! the HBLLM deployment form — Haar-domain sign bits packed 64/word with
//! per-row per-band (α, μ) — or dense fp32 for reference serving. The
//! embeddings and norm gains stay fp32 (they are a rounding error of the
//! parameter budget). A packed GEMV transforms the activation once with the
//! Haar synthesis adjoint (O(m) butterfly), then every row is a plain
//! binary dot product in the Haar domain; rows are fanned out across scoped
//! threads when the layer is large enough.
//!
//! The binary dot itself is runtime-dispatched
//! ([`pack::kernels`](crate::pack::kernels)): one kernel — scalar
//! reference, AVX2, or NEON, selected once per process by CPU feature
//! detection (`HBLLM_KERNEL` overrides) — serves plain decode, the
//! low-band draft, and the multi-position verify sweep, and every kernel
//! is pinned bit-identical to the scalar path, so the parity guarantees
//! below hold whichever one runs.
//!
//! # KV memory layout
//!
//! KV state is **paged** ([`paged`]): one shared
//! `[n_blocks, n_layers, block_len, d]` arena per side
//! ([`KvBlockPool`](paged::KvBlockPool)) and a per-sequence block table
//! ([`PagedKv`](paged::PagedKv)) mapping logical positions to blocks —
//! grown one block at a time as the sequence lengthens, fully released on
//! eviction. Decode position `t` writes row `t` in every layer through the
//! view and attends over rows `0..=t`, so per-token cost is one GEMV
//! sweep + O(t·d) attention instead of the full-window re-forward the
//! fixed-shape XLA path pays. All intermediates live in a preallocated
//! [`Arena`](kv::Arena). For multi-sequence serving a
//! [`KvPool`](kv::KvPool) holds N lanes (view + arena + consumed prefix)
//! over the one shared [`PackedModel`]; a [`Backend::decode_batch`] step
//! sweeps every packed linear once per token across all active lanes,
//! amortizing the bit-unpack/GEMV cost that dominates 1-bit serving.
//! Sizing the arena below worst case (`serve --kv-blocks/--block-len`) is
//! supported: allocation failure surfaces as the typed
//! [`KvExhausted`](paged::KvExhausted) error and the scheduler converts it
//! into admission backpressure / lowest-progress eviction instead of an
//! OOM.
//!
//! # Speculative decoding
//!
//! The Haar decomposition gives the artifact a *free draft model*: the
//! deepest low band of every packed linear is a coarse approximation of
//! the full matrix, readable from the same sign words at half the dot
//! cost. [`spec`] drafts `k` tokens per round with that low-band forward
//! and `NativeBackend::decode_batch_spec` verifies them in one
//! multi-position sweep of the full packed model — greedy output stays
//! byte-identical to plain decoding (rejections fall back to the verified
//! token and roll the paged KV back via
//! [`PagedKv::truncate_to`](paged::PagedKv::truncate_to)), while the
//! dominant weight-traffic cost is paid once per round instead of once
//! per token. `serve --spec-k` / `generate --spec-k` switch it on.
//!
//! # The Backend trait
//!
//! [`Backend`] is the serving contract: batched scoring (`nll`), full
//! logits (`logits`), incremental decoding (`decode_step`), and
//! multi-lane decoding (`lanes`/`set_lanes`/`reset_lane`/`decode_batch` —
//! stateless backends get a sequential single-lane fallback for free). Two
//! implementations exist — [`XlaBackend`] (the PJRT/XLA runners over
//! dequantized fp32 weights) and [`NativeBackend`] (this engine, executing
//! the packed form directly). `coordinator::serve`, `eval`, the CLI
//! (`--backend {xla,native}`) and the examples all run against the trait.
//!
//! The on-disk form of the packed layers this engine executes is specified
//! in `docs/FORMAT.md` at the repository root.

pub mod kv;
pub mod model;
pub mod native;
pub mod paged;
pub mod spec;
pub mod xla;

pub use kv::{Arena, KvPool, Lane};
pub use model::{LayerWeights, Linear, PackedModel};
pub use native::NativeBackend;
pub use paged::{KvBlockPool, KvExhausted, PagedKv};
pub use spec::{DraftLane, SpecConfig, SpecRound, SpecStats};
pub use xla::XlaBackend;

use crate::data::ByteTokenizer;
use crate::util::rng::Pcg32;
use anyhow::{bail, Result};

/// Occupancy snapshot of a backend's paged KV memory — the capacity
/// surface the serving scheduler meters admission against.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct KvStats {
    /// Tokens per KV block.
    pub block_len: usize,
    /// Blocks in the shared arena.
    pub total_blocks: usize,
    /// Blocks currently on the free list.
    pub free_blocks: usize,
    /// High-water mark of concurrently allocated blocks over the arena's
    /// lifetime — the capacity-planning signal: an arena whose high water
    /// never nears `total_blocks` can be shrunk without backpressure.
    pub used_hwm: usize,
    /// Blocks currently mapped by more than one block table (a lane
    /// sharing a prefix with another lane or with the serving prompt
    /// cache) — prefill work the sharing path is deduplicating right now.
    pub shared_blocks: usize,
    /// High-water mark of `shared_blocks` over the arena's lifetime (the
    /// serve shutdown summary's "was the cache earning its memory?"
    /// signal).
    pub shared_hwm: usize,
    /// Blocks currently held by each decode lane (`lane_blocks[i]` is
    /// lane `i`; sums to `total_blocks - free_blocks` while no blocks are
    /// shared — a shared block is counted by every lane mapping it).
    pub lane_blocks: Vec<usize>,
    /// Total bytes of the shared block arena (capacity, not fill level).
    pub arena_bytes: usize,
}

/// A model execution backend: batched scoring + incremental decoding.
///
/// Token arrays are `[batch * seq]` row-major byte tokens, mirroring the
/// PJRT entry points; `nll` returns `batch × (seq − 1)` per-position values
/// and `logits` returns `batch × seq × vocab` values.
pub trait Backend {
    fn name(&self) -> String;
    fn batch(&self) -> usize;
    fn seq(&self) -> usize;
    fn vocab(&self) -> usize;

    /// Per-position next-token NLL for a `[batch, seq]` token batch.
    fn nll(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Full logits for a `[batch, seq]` token batch.
    fn logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// Next-token logits after consuming `text` (its last `seq`-ish bytes).
    /// Incremental where the backend supports it: the native engine only
    /// processes bytes beyond the prefix it has already cached.
    fn decode_step(&mut self, text: &[u8]) -> Result<Vec<f32>>;

    /// Drop incremental decode state (KV cache / consumed prefix) for
    /// every lane.
    fn reset(&mut self);

    /// Number of independent decode lanes (concurrently-cached sequences)
    /// this backend hosts. Stateless backends report one.
    fn lanes(&self) -> usize {
        1
    }

    /// Ask for `n` decode lanes; returns the number actually available.
    /// The default (stateless / single-sequence backends) keeps one
    /// logical lane — the continuous-batching scheduler adapts to
    /// whatever this returns.
    fn set_lanes(&mut self, n: usize) -> usize {
        let _ = n;
        self.lanes()
    }

    /// Drop one lane's decode state (used on admission/eviction). The
    /// default resets everything — correct for backends with a single
    /// lane or no decode state at all.
    fn reset_lane(&mut self, lane: usize) {
        let _ = lane;
        self.reset();
    }

    /// Paged-KV occupancy, if this backend meters KV memory. `None` (the
    /// default, for stateless backends like [`XlaBackend`]) means KV
    /// memory is unmetered and the scheduler admits freely.
    fn kv_stats(&self) -> Option<KvStats> {
        None
    }

    /// Reconfigure the paged KV arena: total block count and block length
    /// in tokens (`None` = the backend's worst-case default). Drops all
    /// decode state on metered backends and returns the resulting stats;
    /// unmetered backends ignore the request and return `None`.
    ///
    /// Sizing below worst case (`n_blocks < lanes × ceil(seq/block_len)`)
    /// is the intended use — the serving scheduler turns block exhaustion
    /// into admission backpressure and lowest-progress eviction.
    fn set_kv_blocks(
        &mut self,
        n_blocks: Option<usize>,
        block_len: Option<usize>,
    ) -> Option<KvStats> {
        let _ = (n_blocks, block_len);
        None
    }

    /// Retain the KV blocks covering `lane`'s first `positions` cached
    /// positions on behalf of an external holder (the serving prompt
    /// cache): every returned block's refcount is bumped, so the blocks
    /// outlive the lane's eviction until [`Self::kv_release_blocks`]
    /// drops them again. `None` on unmetered backends (the default), or
    /// when the lane holds fewer than `positions` cached positions.
    fn kv_retain_prefix(&mut self, lane: usize, positions: usize) -> Option<Vec<usize>> {
        let _ = (lane, positions);
        None
    }

    /// Drop references previously taken by [`Self::kv_retain_prefix`].
    /// No-op on unmetered backends.
    fn kv_release_blocks(&mut self, blocks: &[usize]) {
        let _ = blocks;
    }

    /// Map a retained prefix into `lane` read-only: the lane is reset,
    /// then starts at fill level `positions` over the shared `blocks`
    /// with `prefix` as its consumed text — so the lane's next decode
    /// sweep prefills only the bytes *beyond* the match, and its first
    /// write into a shared block copy-on-writes a private clone
    /// ([`PagedKv::share_prefix`](paged::PagedKv::share_prefix)). Returns
    /// `false` (lane untouched) on unmetered backends, the default.
    fn kv_adopt_prefix(
        &mut self,
        lane: usize,
        blocks: &[usize],
        positions: usize,
        prefix: &[u8],
    ) -> bool {
        let _ = (lane, blocks, positions, prefix);
        false
    }

    /// Next-token logits for several `(lane, text)` pairs in one step
    /// (pairs must be sorted by lane, without duplicates). The default is
    /// the single-lane fallback: each pair runs through [`Self::decode_step`]
    /// sequentially — correct for stateless backends like [`XlaBackend`]
    /// that re-forward the window from the text alone. [`NativeBackend`]
    /// overrides it to sweep each packed linear once across all lanes.
    ///
    /// On KV-metered backends, a sweep that would need more blocks than
    /// the arena has free fails *before touching any lane* with an error
    /// downcastable to [`KvExhausted`] — the scheduler's cue to evict the
    /// lowest-progress sequence and retry rather than poison every lane.
    fn decode_batch(&mut self, reqs: &[(usize, &[u8])]) -> Result<Vec<Vec<f32>>> {
        reqs.iter().map(|&(_, text)| self.decode_step(text)).collect()
    }

    /// Configure speculative decoding (the frequency cascade, [`spec`]).
    /// Returns the *effective* config: backends without a draft path (the
    /// default, e.g. [`XlaBackend`]) report it disabled, and the serving
    /// scheduler adapts to whatever comes back — so `--spec-k` on a
    /// non-speculative backend degrades to plain decoding, never an error.
    fn set_spec(&mut self, cfg: SpecConfig) -> SpecConfig {
        let _ = cfg;
        SpecConfig::disabled()
    }

    /// Cumulative speculative acceptance counters (the `kv_stats`-style
    /// snapshot for the draft path). `None` on backends without one.
    fn spec_stats(&self) -> Option<SpecStats> {
        None
    }

    /// Greedy speculative decode: advance each `(lane, text)` pair by one
    /// *round* — up to `k` drafted tokens verified against the full
    /// model, committing between 1 and `k + 1` bytes per lane (see
    /// [`SpecRound`]). Byte-identical to [`Self::decode_batch`] + greedy
    /// argmax; only the schedule differs. Greedy-only by construction —
    /// the scheduler keeps sampling lanes (`temperature > 0`) on the
    /// plain path.
    ///
    /// The default is the degenerate cascade for backends without a draft
    /// view: one plain `decode_batch` sweep, argmax, zero drafts — so the
    /// speculative serve loop runs unchanged on any backend. KV-metered
    /// implementations fail with a downcastable [`KvExhausted`] before
    /// touching any lane, exactly like `decode_batch`.
    fn decode_batch_spec(&mut self, reqs: &[(usize, &[u8])], k: usize) -> Result<Vec<SpecRound>> {
        let _ = k;
        let rows = self.decode_batch(reqs)?;
        Ok(rows
            .into_iter()
            .map(|row| SpecRound {
                bytes: vec![greedy_token(&row) as u8],
                drafted: 0,
                accepted: 0,
            })
            .collect())
    }

    /// Cumulative forward sweeps this backend has executed — the join
    /// key the trace subsystem stamps on sweep spans so a scheduler-side
    /// timeline lines up with engine-side counters. Backends that don't
    /// count sweeps report 0 (spans still record wall-clock intervals).
    fn sweeps_executed(&self) -> u64 {
        0
    }
}

/// Which backend to construct (CLI `--backend {xla,native}`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// PJRT/XLA over dequantized fp32 weights; `pallas` picks the
    /// Pallas-attention HLO entry.
    Xla { pallas: bool },
    /// Pure-Rust engine; `pack` refits linears into the Haar-packed 1-bit
    /// deployment form (false = dense fp32 reference serving).
    Native { pack: bool },
}

impl BackendKind {
    /// Parse a CLI `--backend` value. `pallas`/`pack` qualify the kind.
    pub fn parse(name: &str, pallas: bool, pack: bool) -> Result<BackendKind> {
        match name {
            "xla" => Ok(BackendKind::Xla { pallas }),
            "native" => Ok(BackendKind::Native { pack }),
            other => bail!("unknown backend {other:?} (expected xla|native)"),
        }
    }
}

/// One decode position's causal attention over cached KV rows `0..=t`:
/// per head, score the query against every key, softmax with the
/// max-subtracted accumulation order used everywhere in this crate, and
/// mix the values into `attn`. `key(u)`/`val(u)` hand back row `u`
/// (length ≥ `heads * dh`) from whatever storage the caller uses.
///
/// This is *the* copy of the decode attention inner loop: the plain path
/// (`NativeBackend::step_lanes`), the speculative verify sweep
/// (`sweep_positions`) and the low-band draft (`DraftLane::step`) all
/// call it with their own accessors — paged gather vs flat offset — so
/// the bit-parity between those paths is structural, not maintained by
/// keeping hand-copies in sync.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attend_position<'a>(
    heads: usize,
    dh: usize,
    scale: f32,
    t: usize,
    q: &[f32],
    probs: &mut [f32],
    attn: &mut [f32],
    key: impl Fn(usize) -> &'a [f32],
    val: impl Fn(usize) -> &'a [f32],
) {
    for hd in 0..heads {
        let c0 = hd * dh;
        let mut maxv = f32::NEG_INFINITY;
        for u in 0..=t {
            let krow = key(u);
            let mut dot = 0f32;
            for j in 0..dh {
                dot += q[c0 + j] * krow[c0 + j];
            }
            let l = dot * scale;
            probs[u] = l;
            maxv = maxv.max(l);
        }
        let mut z = 0f32;
        for u in 0..=t {
            probs[u] = (probs[u] - maxv).exp();
            z += probs[u];
        }
        let inv_z = 1.0 / z;
        for j in 0..dh {
            let mut acc = 0f32;
            for u in 0..=t {
                acc += probs[u] * inv_z * val(u)[c0 + j];
            }
            attn[c0 + j] = acc;
        }
    }
}

/// Greedy argmax over a logits row — the single source of greedy
/// tie-breaking (last maximum wins, per `Iterator::max_by`), shared by
/// [`sample_logits`], the speculative verifier's accept scan and the
/// stateless [`Backend::decode_batch_spec`] fallback, so every decode
/// path picks the same byte from the same row.
pub fn greedy_token(row: &[f32]) -> usize {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Sample a token from a logits row: argmax at `temperature <= 0`, else
/// softmax sampling at the given temperature.
pub fn sample_logits(row: &[f32], temperature: f32, rng: &mut Pcg32) -> usize {
    if temperature <= 0.0 {
        return greedy_token(row);
    }
    let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
    let probs: Vec<f64> = row
        .iter()
        .map(|&x| (((x - maxv) / temperature) as f64).exp())
        .collect();
    let z: f64 = probs.iter().sum();
    let mut u = rng.f64() * z;
    let mut pick = row.len() - 1;
    for (i, p) in probs.iter().enumerate() {
        if u < *p {
            pick = i;
            break;
        }
        u -= p;
    }
    pick
}

/// Backend-generic generation: greedy/temperature sampling via
/// [`Backend::decode_step`]. An empty prompt is seeded with the pad byte so
/// the first step has a position to condition on.
pub fn generate(
    be: &mut dyn Backend,
    prompt: &[u8],
    n_new: usize,
    temperature: f32,
    rng: &mut Pcg32,
) -> Result<Vec<u8>> {
    let mut text: Vec<u8> = prompt.to_vec();
    if text.is_empty() {
        text.push(ByteTokenizer::PAD);
    }
    be.reset();
    for _ in 0..n_new {
        let row = be.decode_step(&text)?;
        let next = sample_logits(&row, temperature, rng);
        text.push(next as u8);
    }
    Ok(text)
}

/// Backend-generic *speculative* greedy generation via
/// [`Backend::decode_batch_spec`]: each round commits every verified byte
/// (1 to `k + 1` of them), clamped so exactly `n_new` bytes are produced.
/// Byte-identical to [`generate`] at temperature 0 — speculation changes
/// the schedule, never the output (`tests/spec_parity.rs`). `k = 0`, or a
/// backend without a draft path, degenerates to one byte per round.
pub fn generate_spec(be: &mut dyn Backend, prompt: &[u8], n_new: usize, k: usize) -> Result<Vec<u8>> {
    let mut text: Vec<u8> = prompt.to_vec();
    if text.is_empty() {
        text.push(ByteTokenizer::PAD);
    }
    be.reset();
    let mut produced = 0usize;
    while produced < n_new {
        // never draft past the byte budget: a round commits <= k + 1
        let k_round = k.min(n_new - produced - 1);
        let round = be
            .decode_batch_spec(&[(0, text.as_slice())], k_round)?
            .pop()
            .expect("one lane in, one round out");
        for &b in round.bytes.iter().take(n_new - produced) {
            text.push(b);
            produced += 1;
        }
    }
    Ok(text)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testing::micro_weights;

    #[test]
    fn backend_kind_parse() {
        assert_eq!(
            BackendKind::parse("xla", true, false).unwrap(),
            BackendKind::Xla { pallas: true }
        );
        assert_eq!(
            BackendKind::parse("native", false, true).unwrap(),
            BackendKind::Native { pack: true }
        );
        assert!(BackendKind::parse("cuda", false, false).is_err());
    }

    #[test]
    fn sample_logits_greedy_and_tempered() {
        let row = vec![0.0f32, 5.0, 1.0];
        let mut rng = Pcg32::seeded(1);
        assert_eq!(sample_logits(&row, 0.0, &mut rng), 1);
        // at tiny temperature the argmax dominates overwhelmingly
        for _ in 0..20 {
            assert_eq!(sample_logits(&row, 0.05, &mut rng), 1);
        }
        // samples stay in range at high temperature
        for _ in 0..50 {
            assert!(sample_logits(&row, 10.0, &mut rng) < 3);
        }
    }

    #[test]
    fn generate_greedy_is_deterministic_and_incremental() {
        let w = micro_weights(31);
        let mk = || {
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1)
        };
        let mut rng = Pcg32::seeded(7);
        let mut be = mk();
        let a = generate(&mut be, b"ta ", 8, 0.0, &mut rng).unwrap();
        let mut be2 = mk();
        let b = generate(&mut be2, b"ta ", 8, 0.0, &mut rng).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 3 + 8);
    }

    #[test]
    fn generate_empty_prompt_does_not_panic() {
        let w = micro_weights(32);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, false).unwrap(), 1, 1);
        let mut rng = Pcg32::seeded(3);
        let out = generate(&mut be, b"", 4, 0.8, &mut rng).unwrap();
        // the seeded pad byte + 4 sampled bytes
        assert_eq!(out.len(), 5);
    }
}
