//! The native packed-weight backend: a pure-Rust byte-level transformer
//! forward that executes directly from `engine::PackedModel` layers, with
//! one KV lane per concurrently-decoding sequence.
//!
//! The hot path is `step_lanes`: one decode step advances every active
//! lane by one byte, sweeping each packed linear (6 per block + unembed)
//! *once* across all lanes via `Linear::gemv_batch` — the
//! weight words are fetched once per row and dotted against every lane's
//! activation, so the bit-unpack/weight-traffic cost of 1-bit serving is
//! amortized over the batch. Attention stays per-lane (each lane has its
//! own KV history length). Per-lane arithmetic is identical to the
//! single-lane path, so batched and sequential greedy decoding produce
//! byte-identical outputs — the invariant `tests/serve_gen.rs` pins down.
//!
//! Op-for-op the math mirrors `model::forward` (same rmsnorm, same
//! per-head softmax accumulation order), so a dense-mode engine reproduces
//! the reference logits to float rounding, and a packed-mode engine matches
//! `model::forward` over [`PackedModel::to_weights`] — the invariant the
//! `engine_parity` integration test pins down.

use super::kv::{Arena, KvCache, KvPool, Lane};
use super::model::PackedModel;
use super::Backend;
use crate::data::ByteTokenizer;
use crate::model::{gelu_tanh, rmsnorm};
use anyhow::{ensure, Result};

pub struct NativeBackend {
    model: PackedModel,
    pool: KvPool,
    /// Multi-lane GEMV adjoint scratch, `[n_active * max(d, d_ff)]`.
    zpool: Vec<f32>,
    batch: usize,
    threads: usize,
}

/// Per-lane view of one decode position: the lane's cache plus disjoint
/// mutable borrows of every arena buffer, so the batched step can hand
/// (input, output) pairs of *different* lanes to one `gemv_batch` sweep.
struct LaneStep<'a> {
    cache: &'a mut KvCache,
    t: usize,
    x: &'a mut [f32],
    h: &'a mut [f32],
    q: &'a mut [f32],
    k: &'a mut [f32],
    v: &'a mut [f32],
    attn: &'a mut [f32],
    proj: &'a mut [f32],
    ff: &'a mut [f32],
    probs: &'a mut [f32],
    logits: &'a mut [f32],
}

impl NativeBackend {
    pub fn new(model: PackedModel, batch: usize) -> NativeBackend {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8);
        NativeBackend::with_threads(model, batch, threads)
    }

    pub fn with_threads(model: PackedModel, batch: usize, threads: usize) -> NativeBackend {
        let pool = KvPool::new(&model.config, 1);
        NativeBackend {
            pool,
            zpool: Vec::new(),
            model,
            batch: batch.max(1),
            threads: threads.max(1),
        }
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// Advance the given lanes by one byte each: embed `byte` at each
    /// lane's next position, run every block sweeping each linear once
    /// across all lanes, leave each lane's next-token logits in its arena.
    /// `active` must be sorted by lane index, without duplicates.
    fn step_lanes(&mut self, active: &[(usize, u8)]) -> Result<()> {
        if active.is_empty() {
            return Ok(());
        }
        let n_lanes = self.pool.len();
        let NativeBackend { model, pool, zpool, threads, .. } = self;
        let threads = *threads;
        let cfg = &model.config;
        let (d, heads, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();

        // disjoint &mut Lane for the active set (ascending, unique)
        let mut lanes: Vec<&mut Lane> = Vec::with_capacity(active.len());
        {
            let mut rest: &mut [Lane] = &mut pool.lanes;
            let mut consumed = 0usize;
            for &(idx, _) in active {
                ensure!(
                    idx >= consumed,
                    "decode lanes must be sorted and unique (lane {idx})"
                );
                ensure!(idx < n_lanes, "lane {idx} out of range ({n_lanes} lanes)");
                let (head, tail) = rest.split_at_mut(idx - consumed + 1);
                lanes.push(head.last_mut().unwrap());
                consumed = idx + 1;
                rest = tail;
            }
        }

        // embed + per-lane step contexts
        let mut ctxs: Vec<LaneStep> = Vec::with_capacity(lanes.len());
        for (lane, &(_, byte)) in lanes.into_iter().zip(active) {
            ensure!(!lane.cache.is_full(), "kv cache full (seq {})", lane.cache.seq);
            let t = lane.cache.len;
            let Lane { cache, arena, .. } = lane;
            let Arena { x, h, q, k, v, attn, proj, ff, probs, logits } = arena;
            let te = model.tok_emb.row(byte as usize);
            let pe = model.pos_emb.row(t);
            for j in 0..d {
                x[j] = te[j] + pe[j];
            }
            ctxs.push(LaneStep {
                cache,
                t,
                x: &mut x[..],
                h: &mut h[..],
                q: &mut q[..],
                k: &mut k[..],
                v: &mut v[..],
                attn: &mut attn[..],
                proj: &mut proj[..],
                ff: &mut ff[..],
                probs: &mut probs[..],
                logits: &mut logits[..],
            });
        }

        for (li, layer) in model.layers.iter().enumerate() {
            // --- attention ---
            for c in ctxs.iter_mut() {
                rmsnorm(c.x, &layer.ln1, c.h);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.h, &mut *c.q)).collect();
                layer.wq.gemv_batch(&mut io, zpool, threads);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.h, &mut *c.k)).collect();
                layer.wk.gemv_batch(&mut io, zpool, threads);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.h, &mut *c.v)).collect();
                layer.wv.gemv_batch(&mut io, zpool, threads);
            }
            for c in ctxs.iter_mut() {
                c.cache.store(li, c.t, c.k, c.v);
                for hd in 0..heads {
                    let c0 = hd * dh;
                    let mut maxv = f32::NEG_INFINITY;
                    for u in 0..=c.t {
                        let krow = c.cache.key(li, u);
                        let mut dot = 0f32;
                        for j in 0..dh {
                            dot += c.q[c0 + j] * krow[c0 + j];
                        }
                        let l = dot * scale;
                        c.probs[u] = l;
                        maxv = maxv.max(l);
                    }
                    let mut z = 0f32;
                    for u in 0..=c.t {
                        c.probs[u] = (c.probs[u] - maxv).exp();
                        z += c.probs[u];
                    }
                    let inv_z = 1.0 / z;
                    for j in 0..dh {
                        let mut acc = 0f32;
                        for u in 0..=c.t {
                            acc += c.probs[u] * inv_z * c.cache.val(li, u)[c0 + j];
                        }
                        c.attn[c0 + j] = acc;
                    }
                }
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.attn, &mut *c.proj)).collect();
                layer.wo.gemv_batch(&mut io, zpool, threads);
            }
            for c in ctxs.iter_mut() {
                for j in 0..d {
                    c.x[j] += c.proj[j];
                }
            }

            // --- MLP ---
            for c in ctxs.iter_mut() {
                rmsnorm(c.x, &layer.ln2, c.h);
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.h, &mut *c.ff)).collect();
                layer.w1.gemv_batch(&mut io, zpool, threads);
            }
            for c in ctxs.iter_mut() {
                for vv in c.ff.iter_mut() {
                    *vv = gelu_tanh(*vv);
                }
            }
            {
                let mut io: Vec<(&[f32], &mut [f32])> =
                    ctxs.iter_mut().map(|c| (&*c.ff, &mut *c.proj)).collect();
                layer.w2.gemv_batch(&mut io, zpool, threads);
            }
            for c in ctxs.iter_mut() {
                for j in 0..d {
                    c.x[j] += c.proj[j];
                }
            }
        }

        for c in ctxs.iter_mut() {
            rmsnorm(c.x, &model.ln_f, c.h);
        }
        {
            let mut io: Vec<(&[f32], &mut [f32])> =
                ctxs.iter_mut().map(|c| (&*c.h, &mut *c.logits)).collect();
            model.unemb.gemv_batch(&mut io, zpool, threads);
        }
        for c in ctxs.iter_mut() {
            c.cache.advance();
        }
        Ok(())
    }

    fn check_token(&self, tok: i32) -> Result<u8> {
        ensure!(
            (0..self.model.config.vocab as i32).contains(&tok),
            "token {tok} out of byte vocab"
        );
        Ok(tok as u8)
    }

    /// NLL of the next token under lane 0's current logits (same formula as
    /// `model::nll_from_logits`).
    fn nll_of_next(&self, next: u8) -> f32 {
        let row = &self.pool.lanes[0].arena.logits;
        let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let logz: f32 = maxv + row.iter().map(|&v| (v - maxv).exp()).sum::<f32>().ln();
        logz - row[next as usize]
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> String {
        "native".to_string()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.model.config.seq_len
    }

    fn vocab(&self) -> usize {
        self.model.config.vocab
    }

    fn lanes(&self) -> usize {
        self.pool.len()
    }

    /// Reallocate the lane pool. Drops all decode state (every lane's KV
    /// cache and prefix); the scheduler resets lanes on admission anyway.
    fn set_lanes(&mut self, n: usize) -> usize {
        self.pool = KvPool::new(&self.model.config, n);
        self.pool.len()
    }

    fn nll(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, s) = (self.batch, self.model.config.seq_len);
        ensure!(tokens.len() == b * s, "expected {}x{} tokens, got {}", b, s, tokens.len());
        let per_row = s - 1;
        let mut out: Vec<f32> = Vec::with_capacity(b * per_row);
        for r in 0..b {
            // eval batches pad by repeating rows; unlike the fixed-shape XLA
            // entry, the sequential engine can just reuse the previous result
            if r > 0 && tokens[r * s..(r + 1) * s] == tokens[(r - 1) * s..r * s] {
                let prev = out.len() - per_row;
                out.extend_from_within(prev..);
                continue;
            }
            self.reset_lane(0);
            for t in 0..s {
                let byte = self.check_token(tokens[r * s + t])?;
                self.step_lanes(&[(0, byte)])?;
                if t + 1 < s {
                    let next = self.check_token(tokens[r * s + t + 1])?;
                    out.push(self.nll_of_next(next));
                }
            }
        }
        self.reset_lane(0);
        Ok(out)
    }

    fn logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, s, v) = (self.batch, self.model.config.seq_len, self.model.config.vocab);
        ensure!(tokens.len() == b * s, "expected {}x{} tokens, got {}", b, s, tokens.len());
        let mut out: Vec<f32> = Vec::with_capacity(b * s * v);
        for r in 0..b {
            if r > 0 && tokens[r * s..(r + 1) * s] == tokens[(r - 1) * s..r * s] {
                let prev = out.len() - s * v;
                out.extend_from_within(prev..);
                continue;
            }
            self.reset_lane(0);
            for t in 0..s {
                let byte = self.check_token(tokens[r * s + t])?;
                self.step_lanes(&[(0, byte)])?;
                out.extend_from_slice(&self.pool.lanes[0].arena.logits);
            }
        }
        self.reset_lane(0);
        Ok(out)
    }

    fn decode_step(&mut self, text: &[u8]) -> Result<Vec<f32>> {
        Ok(self.decode_batch(&[(0, text)])?.pop().unwrap())
    }

    /// Multi-sequence decode: each `(lane, text)` pair is advanced to the
    /// end of its text, incrementally where the lane's cached prefix still
    /// matches. Lanes march in lock step — per sub-step, the next byte of
    /// every lane that still has pending bytes is processed in one
    /// `step_lanes` sweep — so a freshly admitted lane prefills its
    /// prompt while established lanes decode, and the packed-weight sweep
    /// is always shared across whatever is active (continuous batching).
    fn decode_batch(&mut self, reqs: &[(usize, &[u8])]) -> Result<Vec<Vec<f32>>> {
        let s = self.model.config.seq_len;
        const SEED: [u8; 1] = [ByteTokenizer::PAD];
        let mut windows: Vec<&[u8]> = Vec::with_capacity(reqs.len());
        let mut done: Vec<usize> = Vec::with_capacity(reqs.len());
        for (ri, &(lane, text)) in reqs.iter().enumerate() {
            ensure!(lane < self.pool.len(), "lane {lane} out of range ({} lanes)", self.pool.len());
            ensure!(
                ri == 0 || reqs[ri - 1].0 < lane,
                "decode_batch lanes must be sorted and unique"
            );
            // last `seq` bytes are the visible window; an empty text is
            // seeded with the pad byte so position 0 always exists
            let window: &[u8] = if text.is_empty() {
                &SEED
            } else {
                &text[text.len().saturating_sub(s)..]
            };
            let lane_ref = &mut self.pool.lanes[lane];
            let keep = lane_ref.prefix.len();
            // incremental only when the cache really holds the recorded
            // prefix (scoring calls share lane 0 and reset it, and a failed
            // nll can leave a partial fill) — otherwise re-prefill
            if lane_ref.cache.len == keep
                && window.len() >= keep
                && window[..keep] == lane_ref.prefix[..]
            {
                // pure incremental: only the unseen suffix runs through
                done.push(keep);
            } else {
                // window slid (or context switched): re-prefill from scratch
                lane_ref.cache.clear();
                done.push(0);
            }
            windows.push(window);
        }
        // lock-step advance over the pending suffixes
        let mut active: Vec<(usize, u8)> = Vec::with_capacity(reqs.len());
        let mut stepped: Vec<usize> = Vec::with_capacity(reqs.len());
        loop {
            active.clear();
            stepped.clear();
            for (ri, &(lane, _)) in reqs.iter().enumerate() {
                if done[ri] < windows[ri].len() {
                    active.push((lane, windows[ri][done[ri]]));
                    stepped.push(ri);
                }
            }
            if active.is_empty() {
                break;
            }
            self.step_lanes(&active)?;
            for &ri in &stepped {
                done[ri] += 1;
            }
        }
        // commit prefixes + hand back each lane's logits
        let mut out = Vec::with_capacity(reqs.len());
        for (ri, &(lane, _)) in reqs.iter().enumerate() {
            let lane_ref = &mut self.pool.lanes[lane];
            lane_ref.prefix.clear();
            lane_ref.prefix.extend_from_slice(windows[ri]);
            out.push(lane_ref.arena.logits.clone());
        }
        Ok(out)
    }

    fn reset(&mut self) {
        self.pool.clear_all();
    }

    fn reset_lane(&mut self, lane: usize) {
        if let Some(l) = self.pool.lanes.get_mut(lane) {
            l.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testing::micro_weights;
    use crate::model::{forward, nll_from_logits};

    fn tokens_for(window: &[u8], batch: usize) -> Vec<i32> {
        let mut t = Vec::with_capacity(batch * window.len());
        for _ in 0..batch {
            t.extend(window.iter().map(|&b| b as i32));
        }
        t
    }

    #[test]
    fn dense_engine_matches_reference_forward() {
        let w = micro_weights(21);
        let seq = w.config.seq_len;
        let window: Vec<u8> = (0..seq as u8).map(|i| i.wrapping_mul(37)).collect();
        let logits = forward(&w, &window, None);
        let want = nll_from_logits(&logits, &window);

        let pm = PackedModel::from_weights(&w, false).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        let got = be.nll(&tokens_for(&window, 1)).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, r) in got.iter().zip(&want) {
            assert!((g - r).abs() < 1e-4, "{g} vs {r}");
        }
    }

    #[test]
    fn decode_step_is_incremental_and_consistent() {
        let w = micro_weights(22);
        let pm = PackedModel::from_weights(&w, true).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        let text = b"ab cd";
        let inc = be.decode_step(text).unwrap();
        // cache now holds the text; a fresh backend fed at once must agree
        let pm2 = PackedModel::from_weights(&w, true).unwrap();
        let mut fresh = NativeBackend::with_threads(pm2, 1, 1);
        let full = fresh.decode_step(text).unwrap();
        assert_eq!(inc, full);
        // extend by one byte: only the suffix is processed, same result as
        // a from-scratch forward over the longer text
        let longer = b"ab cde";
        let inc2 = be.decode_step(longer).unwrap();
        fresh.reset();
        let full2 = fresh.decode_step(longer).unwrap();
        assert_eq!(inc2, full2);
    }

    #[test]
    fn duplicate_batch_rows_reuse_results() {
        // padded eval batches repeat rows; the reuse path must return the
        // same values the recompute would
        let w = micro_weights(26);
        let window: Vec<u8> = (0..12u8).map(|i| i.wrapping_mul(19)).collect();
        let mut single =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        let one = single.nll(&tokens_for(&window, 1)).unwrap();
        let mut batched =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 2, 1);
        let two = batched.nll(&tokens_for(&window, 2)).unwrap();
        let per = window.len() - 1;
        assert_eq!(two.len(), 2 * per);
        assert_eq!(&two[..per], &one[..]);
        assert_eq!(&two[per..], &one[..]);
    }

    #[test]
    fn decode_step_empty_text_is_seeded() {
        let w = micro_weights(23);
        let pm = PackedModel::from_weights(&w, true).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        let row = be.decode_step(&[]).unwrap();
        assert_eq!(row.len(), 256);
        assert!(row.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_step_slides_past_seq_len() {
        let w = micro_weights(24);
        let seq = w.config.seq_len;
        let pm = PackedModel::from_weights(&w, true).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        // text longer than the window: must not overflow the cache
        let text: Vec<u8> = (0..(seq as u8 + 5)).map(|i| i.wrapping_mul(13)).collect();
        let mut cur = text[..3].to_vec();
        while cur.len() < text.len() {
            let row = be.decode_step(&cur).unwrap();
            assert!(row.iter().all(|v| v.is_finite()));
            cur.push(text[cur.len()]);
        }
    }

    #[test]
    fn nll_rejects_bad_shapes_and_tokens() {
        let w = micro_weights(25);
        let pm = PackedModel::from_weights(&w, false).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        assert!(be.nll(&[0i32; 3]).is_err());
        let seq = be.seq();
        let mut toks = vec![0i32; seq];
        toks[2] = 999; // out of byte range
        assert!(be.nll(&toks).is_err());
    }

    #[test]
    fn set_lanes_reallocates_pool() {
        let w = micro_weights(27);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        assert_eq!(be.lanes(), 1);
        assert_eq!(be.set_lanes(3), 3);
        assert_eq!(be.lanes(), 3);
        assert_eq!(be.set_lanes(0), 1, "pool never drops below one lane");
    }

    #[test]
    fn decode_batch_rejects_bad_lane_sets() {
        let w = micro_weights(28);
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(2);
        let t: &[u8] = b"ab";
        assert!(be.decode_batch(&[(2, t)]).is_err(), "out of range");
        assert!(be.decode_batch(&[(1, t), (0, t)]).is_err(), "unsorted");
        assert!(be.decode_batch(&[(0, t), (0, t)]).is_err(), "duplicate");
        // and a valid call still works afterwards
        assert_eq!(be.decode_batch(&[(0, t), (1, t)]).unwrap().len(), 2);
    }

    #[test]
    fn scoring_between_decode_steps_self_heals_lane0() {
        // serve interleaves nll scoring (which clobbers lane 0) with
        // generation; the next decode must re-prefill and match an
        // uninterrupted run exactly
        let w = micro_weights(30);
        let mk = || NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        let mut clean = mk();
        let a = clean.decode_step(b"ta ki").unwrap();
        let b = clean.decode_step(b"ta kiv").unwrap();

        let mut mixed = mk();
        let a2 = mixed.decode_step(b"ta ki").unwrap();
        let window: Vec<i32> = (0..mixed.seq() as i32).collect();
        mixed.nll(&window).unwrap(); // scoring call resets lane 0
        let b2 = mixed.decode_step(b"ta kiv").unwrap();
        assert_eq!(a, a2);
        assert_eq!(b, b2, "lane 0 did not recover from interleaved scoring");
    }

    #[test]
    fn decode_batch_matches_decode_step_per_lane() {
        // same prompts through (a) two independent single-lane backends and
        // (b) one two-lane backend — logits must be bit-identical
        let w = micro_weights(29);
        let texts: [&[u8]; 2] = [b"ta ki", b"vo"];
        let mut want = Vec::new();
        for t in texts {
            let mut be =
                NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
            want.push(be.decode_step(t).unwrap());
        }
        let mut be =
            NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        be.set_lanes(2);
        let got = be.decode_batch(&[(0, texts[0]), (1, texts[1])]).unwrap();
        assert_eq!(got, want);
    }
}
