//! The native packed-weight backend: a pure-Rust byte-level transformer
//! forward that executes directly from `engine::PackedModel` layers.
//!
//! The hot path is [`NativeBackend::step`]: one decode position costs one
//! GEMV sweep over the packed linears (6 per block + unembed) plus O(t·d)
//! attention against the KV cache — no full-window re-forward, and no
//! per-token allocation beyond the logits row handed back to the caller
//! (every intermediate, including the GEMV adjoint scratch, lives in the
//! preallocated [`Arena`]).
//!
//! Op-for-op the math mirrors `model::forward` (same rmsnorm, same
//! per-head softmax accumulation order), so a dense-mode engine reproduces
//! the reference logits to float rounding, and a packed-mode engine matches
//! `model::forward` over [`PackedModel::to_weights`] — the invariant the
//! `engine_parity` integration test pins down.

use super::kv::{Arena, KvCache};
use super::model::PackedModel;
use super::Backend;
use crate::data::ByteTokenizer;
use crate::model::{gelu_tanh, rmsnorm};
use anyhow::{ensure, Result};

pub struct NativeBackend {
    model: PackedModel,
    cache: KvCache,
    arena: Arena,
    /// Bytes currently materialized in the cache (positions `0..cache.len`).
    prefix: Vec<u8>,
    batch: usize,
    threads: usize,
}

impl NativeBackend {
    pub fn new(model: PackedModel, batch: usize) -> NativeBackend {
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8);
        NativeBackend::with_threads(model, batch, threads)
    }

    pub fn with_threads(model: PackedModel, batch: usize, threads: usize) -> NativeBackend {
        let cfg = &model.config;
        let cache = KvCache::new(cfg.n_layers, cfg.seq_len, cfg.d_model);
        let arena = Arena::new(cfg);
        NativeBackend {
            cache,
            arena,
            model,
            prefix: Vec::new(),
            batch: batch.max(1),
            threads: threads.max(1),
        }
    }

    pub fn model(&self) -> &PackedModel {
        &self.model
    }

    /// Advance the cache by one position: embed `byte` at position
    /// `cache.len`, run every block against the cached K/V, leave the
    /// next-token logits in `arena.logits`.
    fn step(&mut self, byte: u8) -> Result<()> {
        ensure!(!self.cache.is_full(), "kv cache full (seq {})", self.cache.seq);
        let NativeBackend { model, cache, arena, threads, .. } = self;
        let threads = *threads;
        let cfg = &model.config;
        let (d, heads, dh) = (cfg.d_model, cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();
        let t = cache.len;
        let Arena { x, h, q, k, v, attn, proj, ff, probs, zbuf, logits } = arena;

        let te = model.tok_emb.row(byte as usize);
        let pe = model.pos_emb.row(t);
        for j in 0..d {
            x[j] = te[j] + pe[j];
        }

        for (li, layer) in model.layers.iter().enumerate() {
            // --- attention ---
            rmsnorm(x, &layer.ln1, h);
            layer.wq.gemv_scratch(h, q, zbuf, threads);
            layer.wk.gemv_scratch(h, k, zbuf, threads);
            layer.wv.gemv_scratch(h, v, zbuf, threads);
            cache.store(li, t, k, v);
            for hd in 0..heads {
                let c0 = hd * dh;
                let mut maxv = f32::NEG_INFINITY;
                for u in 0..=t {
                    let krow = cache.key(li, u);
                    let mut dot = 0f32;
                    for j in 0..dh {
                        dot += q[c0 + j] * krow[c0 + j];
                    }
                    let l = dot * scale;
                    probs[u] = l;
                    maxv = maxv.max(l);
                }
                let mut z = 0f32;
                for u in 0..=t {
                    probs[u] = (probs[u] - maxv).exp();
                    z += probs[u];
                }
                let inv_z = 1.0 / z;
                for j in 0..dh {
                    let mut acc = 0f32;
                    for u in 0..=t {
                        acc += probs[u] * inv_z * cache.val(li, u)[c0 + j];
                    }
                    attn[c0 + j] = acc;
                }
            }
            layer.wo.gemv_scratch(attn, proj, zbuf, threads);
            for j in 0..d {
                x[j] += proj[j];
            }

            // --- MLP ---
            rmsnorm(x, &layer.ln2, h);
            layer.w1.gemv_scratch(h, ff, zbuf, threads);
            for vv in ff.iter_mut() {
                *vv = gelu_tanh(*vv);
            }
            layer.w2.gemv_scratch(ff, proj, zbuf, threads);
            for j in 0..d {
                x[j] += proj[j];
            }
        }

        rmsnorm(x, &model.ln_f, h);
        model.unemb.gemv_scratch(h, logits, zbuf, threads);
        cache.advance();
        Ok(())
    }

    fn check_token(&self, tok: i32) -> Result<u8> {
        ensure!(
            (0..self.model.config.vocab as i32).contains(&tok),
            "token {tok} out of byte vocab"
        );
        Ok(tok as u8)
    }

    /// NLL of `row[t+1]` under the logits currently in the arena (same
    /// formula as `model::nll_from_logits`).
    fn nll_of_next(&self, next: u8) -> f32 {
        let row = &self.arena.logits;
        let maxv = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let logz: f32 = maxv + row.iter().map(|&v| (v - maxv).exp()).sum::<f32>().ln();
        logz - row[next as usize]
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> String {
        "native".to_string()
    }

    fn batch(&self) -> usize {
        self.batch
    }

    fn seq(&self) -> usize {
        self.model.config.seq_len
    }

    fn vocab(&self) -> usize {
        self.model.config.vocab
    }

    fn nll(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, s) = (self.batch, self.model.config.seq_len);
        ensure!(tokens.len() == b * s, "expected {}x{} tokens, got {}", b, s, tokens.len());
        let per_row = s - 1;
        let mut out: Vec<f32> = Vec::with_capacity(b * per_row);
        for r in 0..b {
            // eval batches pad by repeating rows; unlike the fixed-shape XLA
            // entry, the sequential engine can just reuse the previous result
            if r > 0 && tokens[r * s..(r + 1) * s] == tokens[(r - 1) * s..r * s] {
                let prev = out.len() - per_row;
                out.extend_from_within(prev..);
                continue;
            }
            self.reset();
            for t in 0..s {
                let byte = self.check_token(tokens[r * s + t])?;
                self.step(byte)?;
                if t + 1 < s {
                    let next = self.check_token(tokens[r * s + t + 1])?;
                    out.push(self.nll_of_next(next));
                }
            }
        }
        self.reset();
        Ok(out)
    }

    fn logits(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let (b, s, v) = (self.batch, self.model.config.seq_len, self.model.config.vocab);
        ensure!(tokens.len() == b * s, "expected {}x{} tokens, got {}", b, s, tokens.len());
        let mut out: Vec<f32> = Vec::with_capacity(b * s * v);
        for r in 0..b {
            if r > 0 && tokens[r * s..(r + 1) * s] == tokens[(r - 1) * s..r * s] {
                let prev = out.len() - s * v;
                out.extend_from_within(prev..);
                continue;
            }
            self.reset();
            for t in 0..s {
                let byte = self.check_token(tokens[r * s + t])?;
                self.step(byte)?;
                out.extend_from_slice(&self.arena.logits);
            }
        }
        self.reset();
        Ok(out)
    }

    fn decode_step(&mut self, text: &[u8]) -> Result<Vec<f32>> {
        let s = self.model.config.seq_len;
        // last `seq` bytes are the visible window; an empty text is seeded
        // with the pad byte so position 0 always exists
        let window: &[u8] = if text.is_empty() {
            const SEED: [u8; 1] = [ByteTokenizer::PAD];
            &SEED
        } else {
            &text[text.len().saturating_sub(s)..]
        };
        let keep = self.prefix.len();
        if window.len() >= keep && window[..keep] == self.prefix[..] {
            // pure incremental: only the unseen suffix runs through the model
            for i in keep..window.len() {
                self.step(window[i])?;
            }
        } else {
            // window slid (or context switched): re-prefill from scratch
            self.cache.clear();
            for &b in window {
                self.step(b)?;
            }
        }
        self.prefix.clear();
        self.prefix.extend_from_slice(window);
        Ok(self.arena.logits.clone())
    }

    fn reset(&mut self) {
        self.cache.clear();
        self.prefix.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testing::micro_weights;
    use crate::model::{forward, nll_from_logits};

    fn tokens_for(window: &[u8], batch: usize) -> Vec<i32> {
        let mut t = Vec::with_capacity(batch * window.len());
        for _ in 0..batch {
            t.extend(window.iter().map(|&b| b as i32));
        }
        t
    }

    #[test]
    fn dense_engine_matches_reference_forward() {
        let w = micro_weights(21);
        let seq = w.config.seq_len;
        let window: Vec<u8> = (0..seq as u8).map(|i| i.wrapping_mul(37)).collect();
        let logits = forward(&w, &window, None);
        let want = nll_from_logits(&logits, &window);

        let pm = PackedModel::from_weights(&w, false).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        let got = be.nll(&tokens_for(&window, 1)).unwrap();
        assert_eq!(got.len(), want.len());
        for (g, r) in got.iter().zip(&want) {
            assert!((g - r).abs() < 1e-4, "{g} vs {r}");
        }
    }

    #[test]
    fn decode_step_is_incremental_and_consistent() {
        let w = micro_weights(22);
        let pm = PackedModel::from_weights(&w, true).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        let text = b"ab cd";
        let inc = be.decode_step(text).unwrap();
        // cache now holds the text; a fresh backend fed at once must agree
        let pm2 = PackedModel::from_weights(&w, true).unwrap();
        let mut fresh = NativeBackend::with_threads(pm2, 1, 1);
        let full = fresh.decode_step(text).unwrap();
        assert_eq!(inc, full);
        // extend by one byte: only the suffix is processed, same result as
        // a from-scratch forward over the longer text
        let longer = b"ab cde";
        let inc2 = be.decode_step(longer).unwrap();
        fresh.reset();
        let full2 = fresh.decode_step(longer).unwrap();
        assert_eq!(inc2, full2);
    }

    #[test]
    fn duplicate_batch_rows_reuse_results() {
        // padded eval batches repeat rows; the reuse path must return the
        // same values the recompute would
        let w = micro_weights(26);
        let window: Vec<u8> = (0..12u8).map(|i| i.wrapping_mul(19)).collect();
        let mut single = NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 1, 1);
        let one = single.nll(&tokens_for(&window, 1)).unwrap();
        let mut batched = NativeBackend::with_threads(PackedModel::from_weights(&w, true).unwrap(), 2, 1);
        let two = batched.nll(&tokens_for(&window, 2)).unwrap();
        let per = window.len() - 1;
        assert_eq!(two.len(), 2 * per);
        assert_eq!(&two[..per], &one[..]);
        assert_eq!(&two[per..], &one[..]);
    }

    #[test]
    fn decode_step_empty_text_is_seeded() {
        let w = micro_weights(23);
        let pm = PackedModel::from_weights(&w, true).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        let row = be.decode_step(&[]).unwrap();
        assert_eq!(row.len(), 256);
        assert!(row.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn decode_step_slides_past_seq_len() {
        let w = micro_weights(24);
        let seq = w.config.seq_len;
        let pm = PackedModel::from_weights(&w, true).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        // text longer than the window: must not overflow the cache
        let text: Vec<u8> = (0..(seq as u8 + 5)).map(|i| i.wrapping_mul(13)).collect();
        let mut cur = text[..3].to_vec();
        while cur.len() < text.len() {
            let row = be.decode_step(&cur).unwrap();
            assert!(row.iter().all(|v| v.is_finite()));
            cur.push(text[cur.len()]);
        }
    }

    #[test]
    fn nll_rejects_bad_shapes_and_tokens() {
        let w = micro_weights(25);
        let pm = PackedModel::from_weights(&w, false).unwrap();
        let mut be = NativeBackend::with_threads(pm, 1, 1);
        assert!(be.nll(&[0i32; 3]).is_err());
        let seq = be.seq();
        let mut toks = vec![0i32; seq];
        toks[2] = 999; // out of byte range
        assert!(be.nll(&toks).is_err());
    }
}
